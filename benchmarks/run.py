"""Benchmark aggregator: one section per paper table/figure + the
roofline report.  ``python -m benchmarks.run [section ...]``"""

from __future__ import annotations

import sys
import time
import traceback

SECTIONS = [
    ("table1_forwarding", "paper Table 1: native vs forwarding x N"),
    ("fig4_pushdown", "paper Fig 3/4: pushdown vs client-side queries"),
    ("objsize_sweep", "paper §3.1: object size tradeoff"),
    ("composability", "paper §3.2: decomposable / holistic / approx"),
    ("ingest_fused", "paper §2.2: codec offload on the train input path"),
    ("recovery", "failure management + elastic resize"),
    ("roofline", "dry-run roofline table (reads cached cell records)"),
    ("bench_pushdown", "perf trajectory: writes BENCH_pushdown.json "
                       "(fabric ops / bytes / wall_s + codec micro-bench)"),
]


def main() -> None:
    want = set(sys.argv[1:])
    failures = []
    for name, desc in SECTIONS:
        if want and name not in want:
            continue
        print(f"\n=== {name} — {desc} " + "=" * max(0, 40 - len(name)))
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"[{name}: {time.perf_counter() - t0:.1f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("\nFAILED sections:", failures)
        raise SystemExit(1)
    print("\nall benchmark sections passed")


if __name__ == "__main__":
    main()
