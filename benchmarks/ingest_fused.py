"""Paper §2 goal 2 (codec offload) on the training input path.

Compares the bytes entering the device program for one train step:
  plain  — tokens + labels as int32
  fused  — planar-bitpacked words, unpacked + labels derived in-step

and times the host-side loader fetch for both (the packed path also
skips OSD-side decode via select_packed).  The in-graph unpack cost and
the argument-bytes reduction are read from the compiled step.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import GlobalVOL, make_store
from repro.core.partition import PartitionPolicy
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.fused_ingest import device_stream, make_fused_train_step
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step


def _hlo_flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns a 1-elem list of dicts
        ca = ca[0] if ca else {}
    return ca.get("flops", 0)


def main() -> None:
    store = make_store(6, replicas=2)
    vol = GlobalVOL(store)
    spec = CorpusSpec(n_seqs=512, seq_len=256, vocab_size=100_000, seed=3)
    build_corpus(vol, spec, policy=PartitionPolicy(
        target_object_bytes=256 << 10, max_object_bytes=4 << 20))

    cfg = get_config("yi_9b", smoke=True)
    model = build_model(cfg, remat="none")
    state = init_train_state(model, jax.random.PRNGKey(0))
    base = make_train_step(model, OptConfig())
    B = 16

    plain_ld = ObjectDataLoader(vol, "corpus", global_batch=B, prefetch=0)
    packed_ld = ObjectDataLoader(vol, "corpus", global_batch=B,
                                 prefetch=0, packed=True)

    t0 = time.perf_counter()
    for s in range(8):
        pb = plain_ld.make_batch(s)
    plain_fetch = (time.perf_counter() - t0) / 8
    t0 = time.perf_counter()
    for s in range(8):
        kb = packed_ld.make_batch(s)
    packed_fetch = (time.perf_counter() - t0) / 8

    # streamed: windowed loader + device lookahead (the full pipeline —
    # per-OSD frames assemble batches early, next batch's words land on
    # device while the caller works on the current one)
    stream_ld = ObjectDataLoader(vol, "corpus", global_batch=B,
                                 prefetch=2, packed=True, window_steps=4)
    stream = device_stream(stream_ld, lookahead=1)
    next(stream)  # warm the first window
    t0 = time.perf_counter()
    for _ in range(8):
        next(stream)
    stream_fetch = (time.perf_counter() - t0) / 8
    stream_ld.close()

    plain_step = jax.jit(base)
    fused_step = jax.jit(make_fused_train_step(base))
    c_plain = plain_step.lower(
        state, {k: jnp.asarray(v) for k, v in pb.items()}).compile()
    c_fused = fused_step.lower(state, jnp.asarray(kb["tokens_packed"])) \
        .compile()

    a_plain = pb["tokens"].nbytes + pb["labels"].nbytes
    a_fused = kb["tokens_packed"].nbytes
    print("ingest_fused (B=16, S=256, vocab=100k -> 17-bit packing)")
    print(f"{'path':<8}{'batch_KB':>10}{'fetch_ms':>10}{'hlo_flops':>12}")
    print(f"{'plain':<8}{a_plain / 1024:>10.1f}{plain_fetch * 1e3:>10.1f}"
          f"{_hlo_flops(c_plain):>12.3e}")
    print(f"{'fused':<8}{a_fused / 1024:>10.1f}{packed_fetch * 1e3:>10.1f}"
          f"{_hlo_flops(c_fused):>12.3e}")
    print(f"{'stream':<8}{a_fused / 1024:>10.1f}"
          f"{stream_fetch * 1e3:>10.1f}{'(fused, windowed)':>12}")
    print(f"input-bytes reduction: {a_plain / a_fused:.2f}x "
          f"(theoretical {64 / 17:.2f}x for 17-bit tokens+derived labels)")
    # numerical equivalence of the two steps
    s1, m1 = plain_step(state, {k: jnp.asarray(v) for k, v in pb.items()})
    s2, m2 = fused_step(state, jnp.asarray(kb["tokens_packed"]))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    print("loss(plain) == loss(fused) -> OK")


if __name__ == "__main__":
    main()
