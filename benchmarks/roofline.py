"""Render the roofline table from the dry-run cell records.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun)
and prints, per (arch x shape x mesh x variant): the three roofline terms
in seconds, the dominant term, peak HBM, MODEL_FLOPS/HLO_FLOPS, and the
roofline fraction.  This is a pure reporting pass — no compilation.
"""

from __future__ import annotations

import json
import pathlib

DIR = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def load(pattern: str = "*.json") -> list[dict]:
    recs = [json.loads(p.read_text()) for p in sorted(DIR.glob(pattern))]
    return recs


def fmt_row(r: dict) -> str:
    key = f"{r['arch']}.{r['shape']}.{r['mesh']}"
    if r.get("variant", "baseline") != "baseline":
        key += f".{r['variant']}"
    if r.get("skipped"):
        return f"{key:<58}SKIP ({r['reason'][:40]})"
    if not r.get("ok"):
        return f"{key:<58}FAIL {r.get('error', '')[:60]}"
    rl = r["roofline"]
    return (f"{key:<58}"
            f"{rl['compute_s']:>9.3f}{rl['memory_s']:>9.3f}"
            f"{rl['collective_s']:>9.3f}  {rl['dominant']:<10}"
            f"{r['memory']['peak_hbm_bytes'] / 2**30:>7.2f}"
            f"{r['useful_flops_ratio']:>7.2f}"
            f"{rl['roofline_fraction']:>7.2%}")


def main() -> None:
    recs = load()
    if not recs:
        print("no dry-run records; run: PYTHONPATH=src python -m "
              "repro.launch.dryrun --all --mesh both")
        return
    print(f"{'cell':<58}{'comp_s':>9}{'mem_s':>9}{'coll_s':>9}"
          f"  {'dominant':<10}{'HBM_GiB':>7}{'useful':>7}{'frac':>7}")
    n_ok = n_fail = n_skip = 0
    for r in recs:
        print(fmt_row(r))
        if r.get("skipped"):
            n_skip += 1
        elif r.get("ok"):
            n_ok += 1
        else:
            n_fail += 1
    print(f"\n{n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(long_500k on full-attention archs)")


if __name__ == "__main__":
    main()
