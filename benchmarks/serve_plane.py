"""Hot-data serve plane under many-client fan-in (ROADMAP item 1).

The paper's case for in-storage computation is that offloaded
access-library operations ride the storage cluster's own load
balancing and elasticity — but without server-local result caching,
offload cost scales with CLIENTS instead of with data once thousands
of them hit the same hot objects.  This benchmark drives a zipf-skewed
client population over hot/cold datasets through the full serve plane
(per-OSD result caches + ScanSession single-flight/coalescing) and
measures what the plane buys:

  * hot-scan speedup vs an identical uncached cluster (same data, same
    clients, same seed), p50/p99 per-scan latency, hit rate, fabric ops
  * single-flight collapse: N identical concurrent scans cost exactly
    the fabric ops of ONE scan, result fanned out bit-identically
  * coherence: every result bit-exact vs an uncached reference, and a
    concurrent version-bumping writer never yields a stale/mixed byte

Writes ``BENCH_serve.json`` at the repo root.  ``--smoke`` (or
``BENCH_SMOKE=1``) runs a smaller shape and asserts the same gates —
cheap enough for per-PR CI:

  * cache_hits > 0 and single-flight dedup observed
  * p99 (and wall clock, >= 2x full / 1.5x smoke) under the no-cache
    baseline
  * every scan result bit-exact vs the uncached reference, including
    under the concurrent writer
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import threading
import time

import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.session import ScanSession
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serve.json"

SCAN_BW = 40e6          # modeled per-OSD decode bandwidth (bytes/s)
CACHE_BYTES = 8 << 20   # per-OSD result cache (small: cold churn evicts)


# --------------------------------------------------------------- world
def build_world(*, cache_bytes: int, scan_bw: float | None,
                n_hot: int, n_cold: int):
    """Two datasets on one 4-OSD cluster: a small hot table the skewed
    clients hammer and a larger cold one that churns the cache."""
    store = make_store(4, replicas=2, scan_bw=scan_bw,
                       cache_bytes=cache_bytes)
    vol = GlobalVOL(store)
    rng = np.random.default_rng(11)
    tables = {}
    for name, n in (("hot", n_hot), ("cold", n_cold)):
        tbl = {"run": np.arange(n, dtype=np.int64),
               "e_pt": rng.normal(size=n),
               "eta": rng.uniform(-3, 3, n),
               "phi": rng.uniform(-3.2, 3.2, n)}
        ds = LogicalDataset(
            name, (Column("run", "int64"), Column("e_pt", "float64"),
                   Column("eta", "float64"), Column("phi", "float64")),
            n, 512)
        omap = vol.create(ds, PartitionPolicy(
            target_object_bytes=128 << 10, max_object_bytes=4 << 20))
        vol.write(omap, tbl)
        tables[name] = tbl
    return store, vol, tables


def make_templates(n_hot: int, n_cold: int) -> list[tuple]:
    """Scan templates ``(dataset, lo, hi, cols | ("agg", fn, col))``,
    hottest first (the zipf weights follow list order)."""
    cols = ("e_pt", "eta", "phi")
    out: list[tuple] = []
    for k in range(20):  # hot: overlapping narrow run windows
        lo = (k * 997) % (n_hot - 4000)
        out.append(("hot", lo, lo + 4000,
                    tuple(cols[i] for i in ((k % 3,), (0, 1), (1, 2),
                                            (0, 1, 2))[k % 4])))
    out.append(("hot", 0, n_hot, ("agg", "sum", "e_pt")))
    out.append(("hot", 0, n_hot, ("agg", "count", "run")))
    for k in range(8):  # cold tail: wide scans that churn the cache
        lo = (k * 4999) % (n_cold - 12000)
        out.append(("cold", lo, lo + 12000, (cols[k % 3], "run")))
    return out


def template_scan(vol, tpl):
    ds, lo, hi, spec = tpl
    s = vol.scan(ds).filter("run", ">=", lo).filter("run", "<", hi)
    if spec[0] == "agg":
        return s.agg(spec[1], spec[2])
    return s.project(*spec)


def results_equal(a, b) -> bool:
    if isinstance(a, dict) != isinstance(b, dict):
        return False
    if isinstance(a, dict):
        return set(a) == set(b) and all(
            np.array_equal(a[c], b[c]) for c in a)
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


# ------------------------------------------------------------ workload
def run_workload(store, vol, templates, expected, *, n_threads: int,
                 scans_per_thread: int, seed: int) -> dict:
    """The zipf-skewed client population: every thread draws templates
    from the same skewed distribution and bit-checks every result
    against the uncached reference."""
    session = ScanSession(vol)
    weights = 1.0 / np.arange(1, len(templates) + 1) ** 1.2
    weights /= weights.sum()
    lat: list[list[float]] = [[] for _ in range(n_threads)]
    errors: list[BaseException] = []
    bar = threading.Barrier(n_threads)

    def client(t: int) -> None:
        rng = np.random.default_rng(seed + t)
        picks = rng.choice(len(templates), size=scans_per_thread,
                           p=weights)
        bar.wait()
        for k in picks:
            t0 = time.perf_counter()
            try:
                res, _ = session.execute(
                    template_scan(vol, templates[k]))
            except BaseException as e:  # noqa: BLE001 — reported below
                errors.append(e)
                return
            lat[t].append(time.perf_counter() - t0)
            if not results_equal(res, expected[k]):
                errors.append(AssertionError(
                    f"result mismatch on template {k}: {templates[k]}"))
                return

    before = store.fabric.snapshot()
    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    after = store.fabric.snapshot()
    all_lat = np.array([x for l in lat for x in l])
    hits = after["cache_hits"] - before["cache_hits"]
    misses = after["cache_misses"] - before["cache_misses"]
    return {
        "scans": int(all_lat.size),
        "wall_s": wall,
        "p50_ms": float(np.percentile(all_lat, 50) * 1e3),
        "p99_ms": float(np.percentile(all_lat, 99) * 1e3),
        "fabric_ops": after["ops"] - before["ops"],
        "local_bytes": after["local_bytes"] - before["local_bytes"],
        "cache_hits": hits,
        "cache_misses": misses,
        "hit_rate": hits / max(hits + misses, 1),
        "cache_evictions": (after["cache_evictions"]
                            - before["cache_evictions"]),
        "queue_wait_s": after["queue_wait_s"] - before["queue_wait_s"],
        "session": dict(session.stats),
    }


def bench_hot_scans(*, smoke: bool) -> dict:
    n_hot, n_cold = 65_536, 131_072
    n_threads = 8 if smoke else 16
    per_thread = 30 if smoke else 125
    templates = make_templates(n_hot, n_cold)

    # uncached reference: no modeled decode time, no cache — ground
    # truth for BOTH runs (per-OSD fold order is deterministic, so
    # reference results are bit-identical to a live uncached cluster's)
    _, ref_vol, _ = build_world(cache_bytes=0, scan_bw=None,
                                n_hot=n_hot, n_cold=n_cold)
    expected = [template_scan(ref_vol, t).execute()[0]
                for t in templates]

    print(f"hot-scan fan-in: {n_threads} clients x {per_thread} scans, "
          f"{len(templates)} templates (zipf), scan_bw="
          f"{SCAN_BW / 1e6:.0f} MB/s")
    out = {}
    for label, cache in (("uncached", 0), ("cached", CACHE_BYTES)):
        store, vol, _ = build_world(cache_bytes=cache, scan_bw=SCAN_BW,
                                    n_hot=n_hot, n_cold=n_cold)
        out[label] = run_workload(
            store, vol, templates, expected, n_threads=n_threads,
            scans_per_thread=per_thread, seed=23)
        r = out[label]
        print(f"  {label:9s}: wall {r['wall_s']:.2f}s  "
              f"p50 {r['p50_ms']:.1f}ms  p99 {r['p99_ms']:.1f}ms  "
              f"hit_rate {r['hit_rate']:.2f}  ops {r['fabric_ops']}")
    speedup = out["uncached"]["wall_s"] / out["cached"]["wall_s"]
    p99_ratio = out["uncached"]["p99_ms"] / out["cached"]["p99_ms"]
    out["speedup"] = speedup
    out["p99_speedup"] = p99_ratio
    print(f"  speedup: {speedup:.1f}x wall, {p99_ratio:.1f}x p99")

    # ---- gates
    assert out["cached"]["cache_hits"] > 0
    assert out["cached"]["p99_ms"] < out["uncached"]["p99_ms"], \
        "cached p99 not under the no-cache baseline"
    assert speedup >= (1.5 if smoke else 2.0), f"speedup {speedup:.2f}x"
    return out


# -------------------------------------------------------- single-flight
def bench_single_flight(*, smoke: bool) -> dict:
    store, vol, _ = build_world(cache_bytes=CACHE_BYTES,
                                scan_bw=SCAN_BW, n_hot=65_536,
                                n_cold=131_072)
    scan = vol.scan("hot").filter("run", "<", 8000).project("e_pt")
    solo = ScanSession(vol)
    before = store.fabric.snapshot()
    ref, _ = solo.execute(scan)
    solo_ops = store.fabric.ops - before["ops"]

    n_clients = 8 if smoke else 32
    session = ScanSession(vol, window_s=0.05)
    results: list = [None] * n_clients
    bar = threading.Barrier(n_clients)

    def client(i: int) -> None:
        bar.wait()
        results[i], _ = session.execute(scan)

    before = store.fabric.snapshot()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    group_ops = store.fabric.ops - before["ops"]

    # ---- gates: one OSD round trip for the whole group, bit-identical
    assert session.stats["executed"] == 1, session.stats
    assert session.stats["deduped"] == n_clients - 1, session.stats
    assert group_ops == solo_ops, (group_ops, solo_ops)
    for r in results:
        assert results_equal(r, ref)
    print(f"single-flight: {n_clients} identical concurrent scans -> "
          f"{group_ops} fabric ops (solo scan costs {solo_ops}); "
          f"dedup {session.stats['deduped']}, all bit-identical")
    return {"n_clients": n_clients, "solo_ops": solo_ops,
            "group_ops": group_ops, "session": dict(session.stats)}


# ------------------------------------------------------- write coherence
def bench_write_coherence(*, smoke: bool) -> dict:
    """A version-bumping writer alternates a single-object dataset
    between two known tables while scanners hammer it through the
    cache: every observed result must be EXACTLY one of the two
    versions — a stale cache entry or a blob/xattr tear would show up
    as a mixed or third result."""
    store = make_store(2, replicas=2, cache_bytes=16 << 20,
                       scan_bw=400e6)
    vol = GlobalVOL(store)
    n = 4096
    ds = LogicalDataset("wc", (Column("v", "float64"),), n, n)
    omap = vol.create(ds, PartitionPolicy(  # one unit -> one object
        target_object_bytes=4 << 20, max_object_bytes=16 << 20))
    a = {"v": np.arange(n, dtype=np.float64)}
    b = {"v": np.arange(n, dtype=np.float64) * -3.0 + 7.0}
    vol.write(omap, a)
    allowed = (a["v"], b["v"])

    run_s = 0.6 if smoke else 2.5
    stop = threading.Event()
    writes = [0]
    wrong: list = []
    scans = [0]

    def writer() -> None:
        k = 0
        while not stop.is_set():
            vol.write(omap, b if k % 2 == 0 else a)
            writes[0] += 1
            k += 1

    def scanner() -> None:
        while not stop.is_set():
            r, _ = vol.scan("wc").project("v").execute()
            scans[0] += 1
            if not (np.array_equal(r["v"], allowed[0])
                    or np.array_equal(r["v"], allowed[1])):
                wrong.append(r["v"])
                return

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=scanner) for _ in range(3)]
    for th in threads:
        th.start()
    time.sleep(run_s)
    stop.set()
    for th in threads:
        th.join()

    assert not wrong, "stale/mixed bytes served across a version bump"
    assert writes[0] > 2 and scans[0] > 2
    print(f"write coherence: {scans[0]} scans raced {writes[0]} "
          f"version-bumping writes, 0 stale results "
          f"(cache hits {store.fabric.cache_hits}, "
          f"misses {store.fabric.cache_misses})")
    return {"writes": writes[0], "scans": scans[0], "wrong_results": 0,
            "cache_hits": store.fabric.cache_hits,
            "cache_misses": store.fabric.cache_misses}


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    report = {
        "shape": {"smoke": smoke, "scan_bw": SCAN_BW,
                  "cache_bytes": CACHE_BYTES},
        "hot_scan": bench_hot_scans(smoke=smoke),
        "single_flight": bench_single_flight(smoke=smoke),
        "write_coherence": bench_write_coherence(smoke=smoke),
    }
    if smoke:
        print("serve_plane --smoke: gates hold (hits > 0, p99 under "
              "no-cache baseline, single-flight collapse to one round "
              "trip, bit-exact results incl. under a concurrent "
              "version-bumping writer)")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        print(f"BENCH_serve -> {OUT_PATH}")
    print("claims: hot-data serving cost scales with data, not with "
          "clients (OSD result caches + single-flight) -> OK")


if __name__ == "__main__":
    main()
