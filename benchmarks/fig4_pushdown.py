"""Paper Fig. 3/4: SkyhookDM query offload — pushdown vs client-side.

Executes the same filter+project/aggregate workloads through (a) the
driver/worker pushdown path (sub-queries run inside OSDs, only results
move) and (b) the client-side baseline (full objects move, client
computes).  Reports bytes over the client<->storage fabric, storage-local
bytes scanned, wall time, and the selectivity gain — the paper's claimed
benefit is the O(data) -> O(result) traffic reduction.
"""

from __future__ import annotations

import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.skyhook import Query, SkyhookDriver
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

N_ROWS = 400_000


def build_world():
    ds = LogicalDataset(
        "events",
        (Column("e_pt", "float32"), Column("e_eta", "float32"),
         Column("run", "int32"), Column("hits", "int32")),
        N_ROWS, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=1 << 20,
                                          max_object_bytes=8 << 20))
    rng = np.random.default_rng(1)
    vol.write(omap, {
        "e_pt": rng.gamma(2.0, 20.0, N_ROWS).astype(np.float32),
        "e_eta": rng.normal(0, 2, N_ROWS).astype(np.float32),
        "run": rng.integers(0, 100, N_ROWS).astype(np.int32),
        "hits": rng.poisson(12, N_ROWS).astype(np.int32),
    })
    return store, vol, omap


QUERIES = [
    ("selective_agg", Query("events", filter=("run", "==", 7),
                            aggregate=("mean", "e_pt"))),
    ("broad_agg", Query("events", filter=("e_pt", ">", 10.0),
                        aggregate=("sum", "hits"))),
    ("count_star", Query("events", aggregate=("count", "e_pt"))),
    ("median_approx", Query("events", aggregate=("median", "e_pt"),
                            allow_approx=True)),
    ("project_filter", Query("events", filter=("run", "<", 3),
                             projection=("e_pt", "run"))),
]


def main() -> None:
    store, vol, omap = build_world()
    drv = SkyhookDriver(vol, n_workers=4)
    print("fig4_pushdown (400k rows, 8 OSDs, rep=2)")
    print(f"{'query':<16}{'path':<8}{'wall_ms':>9}{'client_MB':>11}"
          f"{'scan_MB':>9}{'gain':>8}")
    for name, q in QUERIES:
        r1, s1 = drv.execute(q)
        r2, s2 = drv.execute_client_side(q)
        if isinstance(r1, float) and name != "median_approx":
            assert abs(r1 - r2) < 1e-6 * max(abs(r2), 1), (name, r1, r2)
        for path, s in (("push", s1), ("client", s2)):
            print(f"{name:<16}{path:<8}{s.wall_s * 1e3:>9.1f}"
                  f"{s.client_rx_bytes / 2**20:>11.3f}"
                  f"{s.storage_local_bytes / 2**20:>9.1f}"
                  f"{s.selectivity_gain:>8.1f}")
        assert s1.client_rx_bytes <= s2.client_rx_bytes, name
    print("claim: pushdown client-bytes <= client-side for every query "
          "-> OK")


if __name__ == "__main__":
    main()
