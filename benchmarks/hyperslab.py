"""N-d hyperslab selection pushdown (ROADMAP item 3).

The paper's mapping covers tables; scientific datasets are chunked
N-d arrays (HDF5 dataspaces).  This benchmark drives the new array
plane — ``Dataspace`` -> chunk-grouped objects, the OSD-resolved
``hyperslab_slice`` objclass op, per-chunk zone-map pruning, N-d
client assembly — and measures what storage-side selection buys over
the fetch-everything baseline:

  * bytes on the wire (``client_rx``) for contiguous-slab / strided /
    pencil selections vs reading the whole array, at identical results
  * OSD-side chunk pruning: a predicate drops whole chunks before any
    cell is touched (``chunks_pruned`` > 0) with ZERO client zone-map
    requests (``xattr_ops`` == 0)
  * per-OSD response framing: one framed result per contacted OSD
    (``rx_frames`` <= K), never per object
  * late binding: a compiled plan stays bit-exact after the array is
    re-packed into different objects under it

Writes ``BENCH_hyperslab.json`` at the repo root.  ``--smoke`` (or
``BENCH_SMOKE=1``) runs a smaller array and asserts the same gates —
cheap enough for per-PR CI:

  * every selection bit-exact vs numpy on the in-memory array
  * strided and pencil selections move STRICTLY fewer bytes than the
    whole-array baseline
  * predicate sweep: chunks_pruned > 0 and xattr_ops == 0
  * rx_frames per read <= contacted OSDs
  * the pre-repartition compiled plan still bit-exact afterwards
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import sys
import time

import numpy as np

from repro.core import expr as ex
from repro.core.logical import Dataspace, Hyperslab
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_hyperslab.json"

N_OSDS = 4


# --------------------------------------------------------------- world
def build_world(*, smoke: bool):
    """One chunked 3-d float array with a localized hot region (so a
    threshold predicate has whole cold chunks to prune)."""
    shape = (48, 48, 32) if smoke else (96, 96, 64)
    chunk = (12, 12, 8) if smoke else (16, 16, 16)
    rng = np.random.default_rng(23)
    arr = rng.uniform(0.0, 1.0, size=shape)
    hot = tuple(slice(0, max(1, s // 4)) for s in shape)
    arr[hot] += 100.0  # hot corner: most chunks provably < threshold
    space = Dataspace(name="cube", shape=shape, dtype="float64",
                      chunk=chunk)
    store = make_store(N_OSDS, replicas=2, cache_bytes=4 << 20)
    vol = GlobalVOL(store)
    amap = vol.create_array(
        space, PartitionPolicy(target_object_bytes=256 << 10))
    vol.write_array(amap, arr)
    return store, vol, amap, arr


def digest(a: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(a).tobytes()).hexdigest()


def measured_read(store, vol, amap, key, *, where=None, fill=0.0):
    store.fabric.reset()
    t0 = time.perf_counter()
    got = vol.read_array(amap, key, where=where, fill=fill)
    wall = time.perf_counter() - t0
    f = store.fabric
    return got, {
        "wall_s": wall,
        "client_rx": f.client_rx,
        "rx_frames": f.rx_frames,
        "fabric_ops": f.ops,
        "xattr_ops": f.xattr_ops,
        "chunks_pruned": f.chunks_pruned,
        "cells": int(got.size),
        "digest": digest(got),
    }


# --------------------------------------------------------------- sweeps
def bench_selections(store, vol, amap, arr) -> dict:
    """Selection-shape sweep: identical results, fewer wire bytes."""
    sx, sy, sz = arr.shape
    cases = {
        "baseline_full": np.s_[:, :, :],
        "contiguous_slab": np.s_[sx // 4: 3 * sx // 4,
                                 sy // 4: 3 * sy // 4, :],
        "strided": np.s_[::4, ::4, ::2],
        "pencil": np.s_[:, sy // 2, sz // 2],
    }
    out = {}
    for label, key in cases.items():
        got, stats = measured_read(store, vol, amap, key)
        ref = arr[key]
        assert np.array_equal(got, ref), f"{label}: result diverges"
        assert stats["rx_frames"] <= N_OSDS, \
            f"{label}: per-object framing leaked ({stats['rx_frames']})"
        stats["selectivity"] = ref.size / arr.size
        out[label] = stats
        print(f"  {label:16s} cells={ref.size:>7d} "
              f"rx={stats['client_rx']:>9d}B "
              f"frames={stats['rx_frames']} wall={stats['wall_s']:.4f}s")
    base = out["baseline_full"]["client_rx"]
    for label in ("strided", "pencil"):
        assert out[label]["client_rx"] < base, \
            f"{label} moved no fewer bytes than the full read"
    out["baseline_full"]["rx_over_selected"] = 1.0
    return out


def bench_predicate_pruning(store, vol, amap, arr) -> dict:
    """Threshold predicate: cold chunks are dropped ON the OSDs from
    their per-chunk zone maps — the client fetches no metadata at all
    and pays wire bytes only for surviving chunks."""
    pred = ex.Cmp("data", ">", 50.0)
    got, stats = measured_read(store, vol, amap, np.s_[:, :, :],
                               where=pred, fill=0.0)
    mask = arr > 50.0
    assert np.array_equal(got[mask], arr[mask]), "hot cells diverge"
    assert ((got == arr) | (got == 0.0)).all(), \
        "a cell is neither its true value nor the fill"
    assert stats["chunks_pruned"] > 0, "no chunks pruned OSD-side"
    assert stats["xattr_ops"] == 0, "client fetched zone maps"
    full_rx = measured_read(store, vol, amap, np.s_[:, :, :])[1][
        "client_rx"]
    assert stats["client_rx"] < full_rx, \
        "pruned scan moved no fewer bytes than the full read"
    sp = amap.space
    stats["n_chunks"] = sp.n_chunks
    stats["pruned_fraction"] = stats["chunks_pruned"] / sp.n_chunks
    stats["rx_vs_full"] = stats["client_rx"] / full_rx
    print(f"  predicate: {stats['chunks_pruned']}/{sp.n_chunks} chunks "
          f"pruned OSD-side, xattr_ops=0, "
          f"rx={stats['rx_vs_full']:.2f}x full")
    return stats


def bench_repartition(store, vol, amap, arr) -> dict:
    """Late binding: a plan compiled against the ORIGINAL packing keeps
    returning bit-exact cells after the chunks move between objects
    (OSDs resolve against their own ``chunks`` xattrs; the version
    bump triggers a recompile on the next execute)."""
    key = np.s_[3::5, 1::7, ::3]
    hs = Hyperslab.from_key(arr.shape, key)
    plan = vol.engine.compile_hyperslab(amap, hs)
    ref = arr[key]
    out1, _ = vol.engine.execute(plan, omap=amap)
    assert np.array_equal(out1, ref)
    t0 = time.perf_counter()
    amap2 = vol.repartition_array(
        amap, PartitionPolicy(
            target_object_bytes=3 * amap.space.chunk_nbytes))
    repack_s = time.perf_counter() - t0
    store.fabric.reset()
    out2, _ = vol.engine.execute(plan)  # stale plan, no map hint
    assert np.array_equal(out2, ref), \
        "stale compiled plan diverged after re-partition"
    print(f"  repartition: {amap.n_objects} -> {amap2.n_objects} "
          f"objects, stale plan still bit-exact")
    return {
        "objects_before": amap.n_objects,
        "objects_after": amap2.n_objects,
        "repack_s": repack_s,
        "stale_plan_bit_exact": True,
        "digest": digest(out2),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    store, vol, amap, arr = build_world(smoke=smoke)
    print(f"hyperslab pushdown: shape={arr.shape} "
          f"chunk={amap.space.chunk} objects={amap.n_objects} "
          f"chunks={amap.space.n_chunks}")
    report = {
        "shape": {"smoke": smoke, "array": list(arr.shape),
                  "chunk": list(amap.space.chunk),
                  "n_objects": amap.n_objects, "n_osds": N_OSDS},
        "selections": bench_selections(store, vol, amap, arr),
        "predicate_pruning": bench_predicate_pruning(store, vol, amap,
                                                     arr),
        "repartition": bench_repartition(store, vol, amap, arr),
    }
    if smoke:
        print("hyperslab --smoke: gates hold (bit-exact vs numpy, "
              "strided/pencil move strictly fewer bytes than the full "
              "read, chunks pruned OSD-side with zero client zone-map "
              "requests, frames <= OSDs, compiled plan survives "
              "re-partition)")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        print(f"BENCH_hyperslab -> {OUT_PATH}")
    print("claims: N-d selections run storage-side — wire bytes track "
          "the selection, not the array -> OK")


if __name__ == "__main__":
    main()
