"""Emit EXPERIMENTS.md markdown tables from the dry-run records."""
import json, pathlib, sys

DIR = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"

def rows(filt):
    out = []
    for p in sorted(DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if filt(r):
            out.append(r)
    return out

def baseline_table():
    print("| arch.shape | mesh | strat | comp_s | mem_s | coll_s | dominant | HBM GiB | useful | frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows(lambda r: r.get("variant") == "baseline"):
        key = f"{r['arch']}.{r['shape']}"
        mesh = "1-pod" if r["mesh"] == "pod16x16" else "2-pod"
        if r.get("skipped"):
            print(f"| {key} | {mesh} | — | — | — | — | SKIP (full attention) | — | — | — |")
            continue
        rl = r["roofline"]
        print(f"| {key} | {mesh} | {r.get('strategy','?')} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
              f"{rl['collective_s']:.3f} | {rl['dominant']} | "
              f"{r['memory']['peak_hbm_bytes']/2**30:.1f} | {r['useful_flops_ratio']:.2f} | {rl['roofline_fraction']:.2%} |")

def variant_table():
    print("| cell | variant | strat | comp_s | mem_s | coll_s | bound_s | HBM GiB | frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows(lambda r: r.get("variant") != "baseline" or True):
        if r.get("skipped") or not r.get("ok"):
            continue
        key = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        if key not in VARIANT_CELLS:
            continue
        rl = r["roofline"]
        print(f"| {key} | {r['variant']} | {r.get('strategy','?')} | {rl['compute_s']:.2f} | {rl['memory_s']:.2f} | "
              f"{rl['collective_s']:.2f} | {rl['step_s_bound']:.2f} | "
              f"{r['memory']['peak_hbm_bytes']/2**30:.1f} | {rl['roofline_fraction']:.2%} |")

VARIANT_CELLS = {
    "deepseek_67b.train_4k.pod16x16",
    "deepseek_67b.train_4k.pod2x16x16",
    "starcoder2_7b.prefill_32k.pod16x16",
    "yi_9b.train_4k.pod16x16",
    "rwkv6_3b.train_4k.pod2x16x16",
}

if __name__ == "__main__":
    if sys.argv[1:] == ["variants"]:
        variant_table()
    else:
        baseline_table()
