"""Paper §3.1 / §5(1): object-size tradeoff sweep.

'The challenge is to find a size that both aligns with workload access
patterns and strikes a good balance between parallel access and load
balancing (smaller is better), and independent access and metadata
overhead (larger is better).'

For one dataset and one scan workload we sweep the target object size
and report: object count (metadata overhead), per-OSD load imbalance
(max/mean bytes), wall time of a parallel full-scan aggregate, and wall
time of a small random row-range read (independent access).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import objclass as oc
from repro.core.logical import Column, LogicalDataset, RowRange
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

N_ROWS = 200_000


def main() -> None:
    rng = np.random.default_rng(0)
    table = {"x": rng.normal(size=N_ROWS),
             "g": rng.integers(0, 64, N_ROWS).astype(np.int32)}
    print(f"objsize_sweep ({N_ROWS} rows x 12 B, 8 OSDs)")
    print(f"{'target':>9}{'objects':>9}{'imbalance':>11}{'scan_ms':>9}"
          f"{'point_ms':>10}")
    for target_kb in (16, 64, 256, 1024, 4096):
        ds = LogicalDataset(
            "sweep", (Column("x", "float64"), Column("g", "int32")),
            N_ROWS, 512)
        store = make_store(8, replicas=2)
        vol = GlobalVOL(store)
        omap = vol.create(ds, PartitionPolicy(
            target_object_bytes=target_kb << 10,
            max_object_bytes=max(target_kb << 12, 4 << 20)))
        vol.write(omap, table)

        sizes = [v for v in store.stats()["osd_bytes"].values() if v]
        imbalance = max(sizes) / (sum(sizes) / len(sizes))

        t0 = time.perf_counter()
        res, _ = vol.query(omap, [oc.op("agg", col="x", fn="sum")])
        scan_ms = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        for _ in range(20):
            r0 = int(rng.integers(0, N_ROWS - 64))
            vol.read(omap, RowRange(r0, r0 + 64), columns=["x"])
        point_ms = (time.perf_counter() - t0) * 1e3 / 20

        print(f"{target_kb:>7}KB{omap.n_objects:>9}{imbalance:>11.2f}"
              f"{scan_ms:>9.1f}{point_ms:>10.2f}")
    print("tradeoff: small objects -> balance/parallelism; large objects "
          "-> fewer metadata entries, cheaper point reads")


if __name__ == "__main__":
    main()
