"""Paper Table 1: dataset creation — native vs forwarding plugin x N OSDs.

The paper writes a 3 GB HDF5 dataset:
  native (no plugin), 1 node ........ 26.28 s
  forwarding plugin, 1 node ......... 61.12 s   (2.33x native)
  forwarding plugin, 2 nodes ........ 36.07 s   (1.37x)
  forwarding plugin, 3 nodes ........ 29.34 s   (1.12x)
  => >= 3 nodes of parallelism offset the plugin overhead.

We reproduce the *shape* of that result at 1/16 scale (192 MB) with the
store's transport model (client NIC 100 MB/s shared across writers;
60 MB/s disk per OSD — the paper's gigabit-era testbed paired gigabit
ethernet with HDDs slower than the wire, which is exactly what makes
per-node scaling observable): the native path serializes once to a
local disk; the forwarding path pays the client hop + replication, and
N parallel OSDs amortize the disk time while the shared NIC sets the
floor.  The claim validated is the ratio structure (fwd_1 > native;
fwd_N decreasing toward the NIC floor), not absolute seconds.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL, LocalVOL

TOTAL_BYTES = 192 << 20
PAPER = {"native_1": 26.28, "fwd_1": 61.12, "fwd_2": 36.07,
         "fwd_3": 29.34}


def build_world(n_osds: int):
    n_rows = TOTAL_BYTES // 1024
    ds = LogicalDataset(
        "t1", (Column("payload", "uint8", (1024,)),), n_rows, 2048)
    store = make_store(max(n_osds, 1), replicas=min(2, n_osds), n_pgs=64,
                       client_bw=100 << 20, disk_bw=60 << 20)
    # forwarding path pays the plugin work; keep bitpack off so both
    # paths serialize the same bytes (paper writes raw HDF5 either way)
    vol = GlobalVOL(store, local=LocalVOL(bitpack_ints=False))
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 20,
                                          max_object_bytes=32 << 20))
    rng = np.random.default_rng(0)
    table = {"payload": rng.integers(0, 255, (n_rows, 1024),
                                     dtype=np.uint8)}
    return store, vol, omap, table


def run() -> dict:
    rows = {}
    # native: one writer, no partitioning/replication — single blob write
    store, vol, omap, table = build_world(1)
    t0 = time.perf_counter()
    vol.write(omap, table, forwarding=False)
    rows["native_1"] = time.perf_counter() - t0

    for n in (1, 2, 3, 4):
        store, vol, omap, table = build_world(n)
        t0 = time.perf_counter()
        vol.write(omap, table, workers=n)
        rows[f"fwd_{n}"] = time.perf_counter() - t0
    return rows


def main() -> None:
    rows = run()
    native = rows["native_1"]
    print("table1_forwarding (192MB scale; paper ratios at 3GB)")
    print(f"{'config':<10}{'time_s':>9}{'vs_native':>11}{'paper':>8}")
    for k, t in rows.items():
        paper = PAPER.get(k)
        pr = f"{paper / PAPER['native_1']:.2f}x" if paper else "-"
        print(f"{k:<10}{t:>9.2f}{t / native:>10.2f}x{pr:>8}")
    # the paper's qualitative claims:
    assert rows["fwd_1"] > rows["native_1"], "plugin must cost overhead"
    assert rows["fwd_2"] < rows["fwd_1"] and rows["fwd_3"] < rows["fwd_2"], \
        "parallel writers must amortize the overhead"
    print("claims: fwd_1 > native; fwd_N monotonically amortizes -> OK")


if __name__ == "__main__":
    main()
