"""Paper Table 1: dataset creation — native vs forwarding plugin x N OSDs,
plus the streaming-pipelined ingest/scan sections.

The paper writes a 3 GB HDF5 dataset:
  native (no plugin), 1 node ........ 26.28 s
  forwarding plugin, 1 node ......... 61.12 s   (2.33x native)
  forwarding plugin, 2 nodes ........ 36.07 s   (1.37x)
  forwarding plugin, 3 nodes ........ 29.34 s   (1.12x)
  => >= 3 nodes of parallelism offset the plugin overhead.

We reproduce the *shape* of that result at 1/16 scale (192 MB) with the
store's transport model (client NIC 100 MB/s shared across writers;
60 MB/s disk per OSD — the paper's gigabit-era testbed paired gigabit
ethernet with HDDs slower than the wire, which is exactly what makes
per-node scaling observable): the native path serializes once to a
local disk; the forwarding path pays the client hop + replication, and
N parallel OSDs amortize the disk time while the shared NIC sets the
floor.  The claim validated is the ratio structure (fwd_1 > native;
fwd_N decreasing toward the NIC floor), not absolute seconds.

``streaming`` section — the windowed-ingest claim at the same 192 MB
scale, with an LM-corpus-shaped payload (int32 token ids, planar
bitpack17 at rest) and the simulated NIC *calibrated to this
machine's measured encode rate* so encode time ~= stream time on any
host (the regime where overlap matters; also what keeps the CI gate
from flapping on runner CPU speed): ``vol.write``'s windowed mode
overlaps encode with the NIC stream (one long-lived put request per
primary OSD), which must beat the buffered
encode-everything-then-stream path by >= 1.3x (STREAM_GATE) with
identical fabric ops and bit-identical stored bytes.  ``scan`` section — the read-side twin: per-OSD result frames
decode as they land, so time-to-first-frame << total scan wall.

Emits ``BENCH_table1.json`` at the repo root (like
``BENCH_pushdown.json``).  ``--smoke`` / ``BENCH_SMOKE=1`` runs only
the streaming + scan sections and their gates — cheap enough for the
per-PR ``bench-smoke`` CI job.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import sys
import time

import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL, LocalVOL

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_table1.json"
TOTAL_BYTES = 192 << 20
PAPER = {"native_1": 26.28, "fwd_1": 61.12, "fwd_2": 36.07,
         "fwd_3": 29.34}
# windowed ingest must beat buffered by this factor at table1 scale
STREAM_GATE = 1.3


def build_world(n_osds: int):
    n_rows = TOTAL_BYTES // 1024
    ds = LogicalDataset(
        "t1", (Column("payload", "uint8", (1024,)),), n_rows, 2048)
    store = make_store(max(n_osds, 1), replicas=min(2, n_osds), n_pgs=64,
                       client_bw=100 << 20, disk_bw=60 << 20)
    # forwarding path pays the plugin work; keep bitpack off so both
    # paths serialize the same bytes (paper writes raw HDF5 either way)
    vol = GlobalVOL(store, local=LocalVOL(bitpack_ints=False))
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 20,
                                          max_object_bytes=32 << 20))
    rng = np.random.default_rng(0)
    table = {"payload": rng.integers(0, 255, (n_rows, 1024),
                                     dtype=np.uint8)}
    return store, vol, omap, table


def run() -> dict:
    rows = {}
    # native: one writer, no partitioning/replication — single blob write
    store, vol, omap, table = build_world(1)
    t0 = time.perf_counter()
    vol.write(omap, table, forwarding=False)
    rows["native_1"] = time.perf_counter() - t0

    for n in (1, 2, 3, 4):
        store, vol, omap, table = build_world(n)
        t0 = time.perf_counter()
        vol.write(omap, table, workers=n)
        rows[f"fwd_{n}"] = time.perf_counter() - t0
    return rows


# ------------------------------------------------------------ streaming
def _calibrated_bw(table: dict, sample_rows: int = 8192) -> float:
    """Simulated NIC bandwidth (bytes/s of WIRE payload) chosen so the
    table's encoded bytes take about as long to stream as this
    machine's encoder takes to produce them — the balanced regime where
    windowed overlap matters most.  Calibrating the (simulated anyway)
    transport to the host's real encode rate keeps the regime — and the
    >= STREAM_GATE wall-clock gate — a property of the CODE, not of how
    fast the CI runner's CPU happens to run numpy."""
    local = LocalVOL()
    sample = {k: np.asarray(v)[:sample_rows] for k, v in table.items()}
    local.encode(sample)  # warm
    t0 = time.perf_counter()
    wire = len(local.encode(sample))
    dt = time.perf_counter() - t0
    return wire / dt  # bytes of encoded output per second of encode


def build_stream_world(n_osds: int = 4):
    """The streaming section's world: token payload (int32, 17-bit
    values -> planar bitpack17 at rest), so the per-object encode is
    real work, with the simulated NIC calibrated to match its rate
    (``_calibrated_bw``) — the regime the windowed overlap targets."""
    n_rows = TOTAL_BYTES // 1024  # 1 KB/row of raw int32 tokens
    ds = LogicalDataset(
        "t1s", (Column("tokens", "int32", (256,)),), n_rows, 2048)
    rng = np.random.default_rng(0)
    table = {"tokens": rng.integers(0, 1 << 17, (n_rows, 256),
                                    dtype=np.int32)}
    bw = _calibrated_bw(table)
    # disks at the NIC rate: each OSD writes ~(wire/K) primary and as
    # much again as a replica, so disk time per OSD stays under half
    # the (serial) NIC wall — never the bottleneck being measured
    store = make_store(n_osds, replicas=2, n_pgs=64,
                       client_bw=bw, disk_bw=bw)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 20,
                                          max_object_bytes=32 << 20))
    return store, vol, omap, table


def _stored_digest(store, names) -> dict[str, str]:
    out = {}
    for n in names:
        for osd_id in store.cluster.locate(n):
            out[f"{osd_id}/{n}"] = hashlib.sha256(
                store.osds[osd_id].data[n]).hexdigest()
    return out


def bench_streaming(n_osds: int = 4) -> tuple:
    """Windowed vs buffered ingest of the SAME table into identically
    laid-out stores: the stream must win >= STREAM_GATE wall-clock with
    the same O(K) request count and bit-identical stored bytes.
    Returns ``(report_dict, streamed_store, vol, omap)`` — the streamed
    world is reused by ``bench_scan_stream``."""
    store_b, vol_b, omap, table = build_stream_world(n_osds)
    store_b.fabric.reset()
    t0 = time.perf_counter()
    vol_b.write(omap, table, window_objects=0)  # buffered
    wall_buffered = time.perf_counter() - t0
    buffered = store_b.fabric.snapshot()

    store_s, vol_s, omap_s, _ = build_stream_world(n_osds)
    store_s.fabric.reset()
    t0 = time.perf_counter()
    vol_s.write(omap_s, table)  # windowed (default window, io simulated)
    wall_streamed = time.perf_counter() - t0
    streamed = store_s.fabric.snapshot()

    names = omap.object_names()
    primaries = {store_b.cluster.primary(n) for n in names}
    # O(K) unchanged: ONE (streaming) put request per primary OSD
    assert streamed["ops"] == buffered["ops"] == len(primaries), \
        (streamed["ops"], buffered["ops"])
    assert streamed["client_tx"] == buffered["client_tx"]
    assert streamed["replica_bytes"] == buffered["replica_bytes"]
    assert streamed["stream_windows"] > 0 and streamed["overlap_s"] > 0
    # bit-exact stored bytes on every replica
    assert _stored_digest(store_s, names) == _stored_digest(store_b,
                                                            names)
    ratio = wall_buffered / wall_streamed
    assert ratio >= STREAM_GATE, \
        f"streaming ingest only {ratio:.2f}x buffered (< {STREAM_GATE}x)"
    return {
        "total_bytes": TOTAL_BYTES, "n_objects": omap.n_objects,
        "n_osds": n_osds, "wire_bytes": streamed["client_tx"],
        "calibrated_nic_MBps": store_b.client_bw / 2**20,
        "buffered": {"wall_s": wall_buffered,
                     "fabric_ops": buffered["ops"]},
        "streamed": {"wall_s": wall_streamed,
                     "fabric_ops": streamed["ops"],
                     "stream_windows": streamed["stream_windows"],
                     "overlap_s": streamed["overlap_s"]},
        "speedup": ratio,
    }, store_s, vol_s, omap_s


def bench_scan_stream(store, vol, omap) -> dict:
    """The read-side overlap at the same scale: per-OSD frames decode
    as they land, so the first frame reaches the consumer long before
    the full scan wall."""
    from repro.core import objclass as oc
    names = omap.object_names()
    ops = [oc.op("project", cols=["tokens"])]
    store.fabric.reset()
    t0 = time.perf_counter()
    ttfb = None
    n_frames = 0
    for _ in store.exec_concat_iter(names, ops):
        if ttfb is None:
            ttfb = time.perf_counter() - t0
        n_frames += 1
    wall = time.perf_counter() - t0
    snap = store.fabric.snapshot()
    primaries = {store.cluster.primary(n) for n in names}
    assert snap["ops"] == n_frames == len(primaries)  # O(K) frames
    assert snap["stream_windows"] == n_frames
    assert ttfb < wall  # frames really stream, not gather-then-return
    return {"wall_s": wall, "time_to_first_frame_s": ttfb,
            "rx_frames": n_frames, "fabric_ops": snap["ops"],
            "client_rx_bytes": snap["client_rx"]}


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    streaming, store_s, vol_s, omap_s = bench_streaming()
    scan = bench_scan_stream(store_s, vol_s, omap_s)
    report: dict = {"streaming": streaming, "scan": scan}

    s, b = streaming["streamed"], streaming["buffered"]
    print(f"streaming ingest (192MB, {streaming['n_osds']} OSDs, "
          f"{streaming['n_objects']} objects): "
          f"{s['wall_s']:.2f}s windowed vs {b['wall_s']:.2f}s buffered "
          f"(x{streaming['speedup']:.2f}, gate >= {STREAM_GATE}x), "
          f"{s['stream_windows']} windows, "
          f"{s['overlap_s']:.2f}s encode hidden, "
          f"ops {s['fabric_ops']} == {b['fabric_ops']} (O(K)), "
          f"stored bytes bit-exact")
    print(f"streaming scan: first frame at "
          f"{scan['time_to_first_frame_s'] * 1e3:.0f}ms of "
          f"{scan['wall_s'] * 1e3:.0f}ms total, "
          f"{scan['rx_frames']} frames (= K primaries)")
    if smoke:
        print("table1_forwarding --smoke: streaming gates hold")
        return

    rows = run()
    report["table1"] = {"paper": PAPER, "measured": rows}
    native = rows["native_1"]
    print("table1_forwarding (192MB scale; paper ratios at 3GB)")
    print(f"{'config':<10}{'time_s':>9}{'vs_native':>11}{'paper':>8}")
    for k, t in rows.items():
        paper = PAPER.get(k)
        pr = f"{paper / PAPER['native_1']:.2f}x" if paper else "-"
        print(f"{k:<10}{t:>9.2f}{t / native:>10.2f}x{pr:>8}")
    # the paper's qualitative claims:
    assert rows["fwd_1"] > rows["native_1"], "plugin must cost overhead"
    assert rows["fwd_2"] < rows["fwd_1"] and rows["fwd_3"] < rows["fwd_2"], \
        "parallel writers must amortize the overhead"
    print("claims: fwd_1 > native; fwd_N monotonically amortizes -> OK")
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"BENCH_table1 -> {OUT_PATH}")


if __name__ == "__main__":
    main()
