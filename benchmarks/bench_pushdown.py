"""Perf trajectory export: writes ``BENCH_pushdown.json`` at the repo
root so later PRs have hard numbers to compare against.

Four sections:

  queries  — filter→agg (and friends) through the batched pushdown
             plane vs the client-side gather baseline: fabric ops
             (round trips), client_rx bytes, request overhead bytes and
             wall seconds per path.  The headline claims: a scan over N
             objects on K OSDs costs <= K ops batched (seed paid >= N),
             and a decomposable aggregate returns <= K partials
             (client_rx O(K), per-OSD server-side combine).
  prune_pushdown — the composable-scan plane: a pushed-down-prune
             aggregate query issues ZERO client zone-map requests
             (predicates ride inside the batched objclass request and
             each OSD prunes against its own current xattrs), and a
             table-out filter→project scan returns exactly K framed
             responses (per-OSD server-side table concat).
  predicate_algebra — the expression-tree pushdown plane: an OR-group /
             IN-list scan with pushed-down pruning issues ZERO client
             zone-map requests and O(K) framed responses, returns rows
             bit-identical to the client-filtered baseline, and an
             Or-of-disjoint-ranges predicate prunes objects (identically
             under both strategies) that no flat conjunction could.
  ingest   — the symmetric write-plane claim: writing N objects over K
             OSDs through ``put_batch`` costs exactly one put request
             per primary OSD (the seed paid N), plus the batched
             zone-map warm (<= K xattr requests for a fresh client on
             the ``prune="client"`` strategy).
  codec    — vectorized planar-bitpack encode/decode vs the historical
             per-bit-loop reference (bit-exact, same layout): MB/s and
             speedup on the ingest/scan hot path.

Every reported ``wall_s`` is a median-of-5 (``_median_wall``), not a
single shot, so the committed snapshot's numbers don't flap on
container timing jitter.

Regression gate: when a committed ``BENCH_pushdown.json`` exists, the
new ops / client_rx numbers must be no worse before the file is
rewritten (and prune_pushdown's zone-map count must stay 0 / frames
must stay O(K)).  ``--smoke`` (or ``BENCH_SMOKE=1``) runs small shapes
and asserts only the O(K) invariants — cheap enough for per-PR CI.
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import time

import numpy as np

from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.skyhook import Query, SkyhookDriver
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_pushdown.json"
N_ROWS = 200_000
# small enough for per-PR CI, big enough that N objects > K OSDs (the
# O(K) claims are vacuous when every object gets its own request)
SMOKE_ROWS = 100_000


def _loop_bitpack_encode(values, bits):
    """Historical per-bit-loop encoder, kept here as the codec baseline."""
    v = np.ascontiguousarray(values, dtype=np.uint32).ravel()
    n = v.size
    n_groups = -(-n // 32) if n else 0
    padded = np.zeros((n_groups * 32,), np.uint32)
    padded[:n] = v
    g = padded.reshape(n_groups, 32)
    lane = np.arange(32, dtype=np.uint32)
    out = np.zeros((n_groups, bits), np.uint32)
    for k in range(bits):
        out[:, k] = (((g >> np.uint32(k)) & np.uint32(1)) << lane).sum(
            axis=1, dtype=np.uint32)
    return out


def _loop_bitpack_decode(words, bits, n):
    w = np.ascontiguousarray(words, dtype=np.uint32).reshape(-1, bits)
    lane = np.arange(32, dtype=np.uint32)
    vals = np.zeros((w.shape[0], 32), np.uint32)
    for k in range(bits):
        vals |= (((w[:, k:k + 1] >> lane) & np.uint32(1))
                 << np.uint32(k)).astype(np.uint32)
    return vals.ravel()[:n]


def _best_of(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _median_wall(fn, repeat=5):
    """Median-of-N wall seconds — what every section reports instead of
    a single-shot ``wall_s``, so the committed snapshot's numbers stop
    flapping on container timing jitter."""
    walls = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return sorted(walls)[repeat // 2]


def bench_codec(n=1_000_000, bits=17) -> dict:
    rng = np.random.default_rng(0)
    v = rng.integers(0, 1 << bits, n).astype(np.uint32)
    words = fmt.bitpack_encode(v, bits)
    assert np.array_equal(words, _loop_bitpack_encode(v, bits))
    assert np.array_equal(fmt.bitpack_decode(words, bits, n), v)
    enc_vec = _best_of(lambda: fmt.bitpack_encode(v, bits))
    enc_loop = _best_of(lambda: _loop_bitpack_encode(v, bits))
    dec_vec = _best_of(lambda: fmt.bitpack_decode(words, bits, n))
    dec_loop = _best_of(lambda: _loop_bitpack_decode(words, bits, n))
    mb = v.nbytes / 2**20
    return {
        "n_values": n, "bits": bits,
        "encode_vec_s": enc_vec, "encode_loop_s": enc_loop,
        "decode_vec_s": dec_vec, "decode_loop_s": dec_loop,
        "encode_speedup": enc_loop / enc_vec,
        "decode_speedup": dec_loop / dec_vec,
        "encode_vec_MBps": mb / enc_vec, "decode_vec_MBps": mb / dec_vec,
    }


def bench_queries(n_rows: int = N_ROWS) -> dict:
    ds = LogicalDataset(
        "events",
        (Column("e_pt", "float32"), Column("run", "int32"),
         Column("hits", "int32")),
        n_rows, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10,
                                          max_object_bytes=1 << 20))
    rng = np.random.default_rng(1)
    vol.write(omap, {
        "e_pt": rng.gamma(2.0, 20.0, n_rows).astype(np.float32),
        "run": rng.integers(0, 100, n_rows).astype(np.int32),
        "hits": rng.poisson(12, n_rows).astype(np.int32),
    })
    drv = SkyhookDriver(vol, n_workers=4)
    queries = [
        ("filter_agg", Query("events", filter=("run", "<", 50),
                             aggregate=("mean", "e_pt"))),
        ("selective_agg", Query("events", filter=("run", "==", 7),
                                aggregate=("sum", "hits"))),
        ("count_star", Query("events", aggregate=("count", "e_pt"))),
    ]
    out: dict = {"n_rows": n_rows, "n_objects": omap.n_objects,
                 "n_osds": len(store.cluster.up_osds), "queries": {}}
    for name, q in queries:
        drv.execute(q)  # warm the zone-map cache + pools
        walls1: list[float] = []
        walls2: list[float] = []
        r1 = r2 = s1 = s2 = None
        for _ in range(5):  # median-of-5: container clocks are noisy
            r1, s1 = drv.execute(q)
            walls1.append(s1.wall_s)
            r2, s2 = drv.execute_client_side(q)
            walls2.append(s2.wall_s)
        assert abs(r1 - r2) < 1e-6 * max(abs(r2), 1.0), (name, r1, r2)
        out["queries"][name] = {
            "pushdown": {"fabric_ops": s1.fabric_ops,
                         "client_rx_bytes": s1.client_rx_bytes,
                         "wall_s": sorted(walls1)[2]},
            "client_side": {"fabric_ops": s2.fabric_ops,
                            "client_rx_bytes": s2.client_rx_bytes,
                            "wall_s": sorted(walls2)[2]},
            "ops_reduction": s2.fabric_ops / max(s1.fabric_ops, 1),
            "bytes_reduction":
                s2.client_rx_bytes / max(s1.client_rx_bytes, 1),
        }
        assert s1.fabric_ops <= out["n_osds"], (name, s1.fabric_ops)
        # decomposable aggregates: one partial per OSD, client_rx O(K)
        assert s1.client_rx_bytes <= out["n_osds"] * 64, \
            (name, s1.client_rx_bytes)
    return out


def bench_prune_pushdown(n_rows: int = N_ROWS) -> dict:
    """The composable-scan claims: OSD-side pruning needs zero client
    zone-map traffic, and table-out scans are K-framed."""
    ds = LogicalDataset(
        "pp_events",
        (Column("e_pt", "float32"), Column("run", "int32")),
        n_rows, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10,
                                          max_object_bytes=1 << 20))
    rng = np.random.default_rng(3)
    vol.write(omap, {
        "e_pt": rng.gamma(2.0, 20.0, n_rows).astype(np.float32),
        "run": rng.integers(0, 100, n_rows).astype(np.int32),
    })
    n_osds = len(store.cluster.up_osds)
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert omap.n_objects > n_osds  # N > K or the O(K) claim is vacuous

    # pushed-down prune aggregate: ZERO zone-map requests, even for a
    # completely cold client (predicates prune ON the OSDs)
    fresh = GlobalVOL(store)
    agg = fresh.scan(omap).filter("run", "<", 50).agg("mean", "e_pt")
    agg_stats: dict = {}

    def run_agg():
        store.fabric.reset()
        _, stats = agg.execute(omap)
        agg_stats.update(stats)
        assert store.fabric.xattr_ops == 0, store.fabric.xattr_ops

    agg_wall = _median_wall(run_agg)
    agg_zm_reqs = store.fabric.xattr_ops  # measured, gated below AND in CI
    assert agg_stats["prune"] == "pushdown"

    # a fully-pruning predicate: every object skipped OSD-side, still
    # zero metadata traffic (vs the client strategy's K-request warm)
    store.fabric.reset()
    res, prune_stats = (fresh.scan(omap).filter("run", ">", 1000)
                        .agg("count", "run").execute(omap))
    all_zm_reqs = store.fabric.xattr_ops
    assert res == 0.0
    assert all_zm_reqs == 0
    assert prune_stats["objects_pruned"] == omap.n_objects

    # table-out filter→project: exactly K framed responses (per-OSD
    # server-side concat), not one frame per object
    tab = fresh.scan(omap).filter("run", "<", 50).project("e_pt")
    tab_stats = {}

    def run_tab():
        store.fabric.reset()
        _, stats = tab.execute(omap)
        tab_stats.update(stats)

    tab_wall = _median_wall(run_tab)
    assert tab_stats["rx_frames"] == len(primaries) <= n_osds, \
        tab_stats["rx_frames"]
    assert tab_stats["ops"] == len(primaries)

    return {
        "n_rows": n_rows, "n_objects": omap.n_objects, "n_osds": n_osds,
        "agg_pushdown_prune": {
            "zone_map_requests": agg_zm_reqs,
            "fabric_ops": agg_stats["ops"],
            "client_rx_bytes": agg_stats["client_rx"],
            "wall_s": agg_wall},
        "all_pruned": {
            "zone_map_requests": all_zm_reqs,
            "objects_pruned": prune_stats["objects_pruned"]},
        "table_out": {
            "rx_frames": tab_stats["rx_frames"],
            "fabric_ops": tab_stats["ops"],
            "client_rx_bytes": tab_stats["client_rx"],
            "result_rows": tab_stats["result_rows"],
            "wall_s": tab_wall},
    }


def bench_predicate_algebra(n_rows: int = N_ROWS) -> dict:
    """The expression-tree pushdown claims: rich predicates (OR / IN)
    keep the O(K) request/metadata invariants and bit-exact results,
    and interval pruning over the tree skips objects a flat
    conjunction never could."""
    ds = LogicalDataset(
        "pa_events",
        (Column("e_pt", "float32"), Column("run", "int32")),
        n_rows, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10,
                                          max_object_bytes=1 << 20))
    rng = np.random.default_rng(7)
    # run is SORTED so every object's zone map is a tight interval —
    # what makes Or-of-disjoint-ranges pruning observable
    run = (np.arange(n_rows) * 100 // n_rows).astype(np.int32)
    table = {"e_pt": rng.gamma(2.0, 20.0, n_rows).astype(np.float32),
             "run": run}
    vol.write(omap, table)
    n_osds = len(store.cluster.up_osds)
    primaries = {store.cluster.primary(e.name) for e in omap}
    drv = SkyhookDriver(vol, n_workers=4)

    # OR-group aggregate: pushdown vs the client-filter baseline
    or_scan = (vol.scan("pa_events").or_(("run", "<", 10),
                                         ("run", ">", 90))
               .agg("sum", "e_pt"))
    or_stats: dict = {}

    def run_or():
        store.fabric.reset()
        r, stats = or_scan.execute(omap)
        or_stats.update(stats, result=r)
        assert store.fabric.xattr_ops == 0, store.fabric.xattr_ops

    or_wall = _median_wall(run_or)
    or_zm_reqs = store.fabric.xattr_ops  # measured (gated in snapshot)
    base_walls: list[float] = []
    r_base = None
    for _ in range(5):
        r_base, s_base = drv.execute_client_side(
            drv.scan("pa_events").or_(("run", "<", 10), ("run", ">", 90))
            .agg("sum", "e_pt"))
        base_walls.append(s_base.wall_s)
    mask = (run < 10) | (run > 90)
    expect = float(table["e_pt"][mask].astype(np.float64).sum())
    assert abs(or_stats["result"] - r_base) < 1e-6 * max(abs(expect), 1.0)
    assert or_stats["ops"] <= n_osds
    # the Or prunes every middle object — identically on both planes
    _, s_cli = or_scan.prune("client").execute(omap)
    assert s_cli["objects_pruned"] == or_stats["objects_pruned"] > 0

    # IN-list table-out scan: exactly K framed responses
    in_scan = (vol.scan("pa_events").isin("run", [3, 5, 7])
               .project("e_pt"))
    in_stats: dict = {}

    def run_in():
        store.fabric.reset()
        _, stats = in_scan.execute(omap)
        in_stats.update(stats)
        assert store.fabric.xattr_ops == 0

    in_wall = _median_wall(run_in)
    in_zm_reqs = store.fabric.xattr_ops  # measured (gated in snapshot)
    assert in_stats["rx_frames"] <= len(primaries) <= n_osds

    return {
        "n_rows": n_rows, "n_objects": omap.n_objects, "n_osds": n_osds,
        "or_agg": {
            "zone_map_requests": or_zm_reqs,
            "fabric_ops": or_stats["ops"],
            "objects_pruned": or_stats["objects_pruned"],
            "client_rx_bytes": or_stats["client_rx"],
            "wall_s": or_wall,
            "client_filter_wall_s": sorted(base_walls)[2]},
        "in_table_out": {
            "zone_map_requests": in_zm_reqs,
            "rx_frames": in_stats["rx_frames"],
            "fabric_ops": in_stats["ops"],
            "result_rows": in_stats["result_rows"],
            "wall_s": in_wall},
    }


def bench_ingest(n_rows: int = N_ROWS) -> dict:
    """The symmetric write plane: N objects over K OSDs in K put
    requests (``put_batch``) vs the seed's one put per object, plus the
    batched zone-map warm for a fresh client."""
    ds = LogicalDataset(
        "ingest",
        (Column("e_pt", "float32"), Column("run", "int32")),
        n_rows, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10,
                                          max_object_bytes=1 << 20))
    rng = np.random.default_rng(2)
    table = {"e_pt": rng.gamma(2.0, 20.0, n_rows).astype(np.float32),
             "run": rng.integers(0, 100, n_rows).astype(np.int32)}
    n_osds = len(store.cluster.up_osds)
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert omap.n_objects > n_osds  # N > K or the O(K) claim is vacuous

    nb: dict = {}

    def run_write():
        store.fabric.reset()
        nb["bytes"] = vol.write(omap, table)

    wall_batched = _median_wall(run_write)
    nbytes = nb["bytes"]
    batched = store.fabric.snapshot()
    # THE invariant: one put request per primary OSD, <= K
    assert batched["ops"] == len(primaries) <= n_osds, batched["ops"]

    # seed baseline: one put per object (same blobs AND xattrs, read back
    # off the OSDs — a bare re-put would clobber the stored zone maps and
    # leave the warm section below measuring degenerate metadata)
    names = omap.object_names()
    prim = [store.osds[store.cluster.primary(n)] for n in names]
    blobs = [o.data[n] for o, n in zip(prim, names)]
    xats = [dict(o.xattrs[n]) for o, n in zip(prim, names)]

    def run_per_obj():
        store.fabric.reset()
        for n, b, x in zip(names, blobs, xats):
            store.put(n, b, x)

    wall_per_obj = _median_wall(run_per_obj)
    per_obj = store.fabric.snapshot()
    assert per_obj["ops"] == omap.n_objects

    # fresh client warms its zone-map cache in <= K metadata requests
    fresh = GlobalVOL(store)
    store.fabric.reset()
    fresh.plan(omap, [oc.op("filter", col="run", cmp="<", value=50)])
    warm_ops = store.fabric.xattr_ops
    assert warm_ops <= n_osds, warm_ops

    return {
        "n_rows": n_rows, "n_objects": omap.n_objects, "n_osds": n_osds,
        "bytes_written": nbytes,
        "batched": {"fabric_ops": batched["ops"],
                    "overhead_bytes": batched["overhead_bytes"],
                    "wall_s": wall_batched},
        "per_object": {"fabric_ops": per_obj["ops"],
                       "overhead_bytes": per_obj["overhead_bytes"],
                       "wall_s": wall_per_obj},
        "ops_reduction": per_obj["ops"] / max(batched["ops"], 1),
        "zone_map_warm_xattr_ops": warm_ops,
    }


def check_against_snapshot(report: dict, committed: dict) -> list[str]:
    """Regression gate: ops / client_rx must be no worse than the
    committed ``BENCH_pushdown.json`` (wall seconds are machine noise
    and are not gated)."""
    problems: list[str] = []
    old_q = committed.get("queries", {}).get("queries", {})
    for name, row in report["queries"]["queries"].items():
        old = old_q.get(name)
        if not old:
            continue
        for key in ("fabric_ops", "client_rx_bytes"):
            new_v = row["pushdown"][key]
            old_v = old["pushdown"][key]
            if new_v > old_v:
                problems.append(
                    f"queries.{name}.pushdown.{key}: {new_v} > {old_v}")
    old_ing = committed.get("ingest")
    if old_ing:
        new_ops = report["ingest"]["batched"]["fabric_ops"]
        if new_ops > old_ing["batched"]["fabric_ops"]:
            problems.append(
                f"ingest.batched.fabric_ops: {new_ops} > "
                f"{old_ing['batched']['fabric_ops']}")
    old_pp = committed.get("prune_pushdown")
    if old_pp:
        pp = report["prune_pushdown"]
        if pp["agg_pushdown_prune"]["zone_map_requests"] > 0:
            problems.append("prune_pushdown.agg zone_map_requests > 0")
        if pp["table_out"]["rx_frames"] > old_pp["table_out"]["rx_frames"]:
            problems.append(
                f"prune_pushdown.table_out.rx_frames: "
                f"{pp['table_out']['rx_frames']} > "
                f"{old_pp['table_out']['rx_frames']}")
    old_pa = committed.get("predicate_algebra")
    if old_pa:
        pa = report["predicate_algebra"]
        for sec in ("or_agg", "in_table_out"):
            if pa[sec]["zone_map_requests"] > 0:
                problems.append(
                    f"predicate_algebra.{sec} zone_map_requests > 0")
            if pa[sec]["fabric_ops"] > old_pa[sec]["fabric_ops"]:
                problems.append(
                    f"predicate_algebra.{sec}.fabric_ops: "
                    f"{pa[sec]['fabric_ops']} > "
                    f"{old_pa[sec]['fabric_ops']}")
        if pa["in_table_out"]["rx_frames"] > \
                old_pa["in_table_out"]["rx_frames"]:
            problems.append(
                f"predicate_algebra.in_table_out.rx_frames: "
                f"{pa['in_table_out']['rx_frames']} > "
                f"{old_pa['in_table_out']['rx_frames']}")
    return problems


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    n_rows = SMOKE_ROWS if smoke else N_ROWS
    codec_n = 100_000 if smoke else 1_000_000
    report = {"queries": bench_queries(n_rows),
              "prune_pushdown": bench_prune_pushdown(n_rows),
              "predicate_algebra": bench_predicate_algebra(n_rows),
              "ingest": bench_ingest(n_rows),
              "codec": bench_codec(codec_n)}
    if smoke:
        print("bench_pushdown --smoke: O(K) invariants hold "
              f"(scan ops <= K, pushed-down prune zone-map reqs == 0, "
              f"table-out rx frames == K, OR/IN expression scans keep "
              f"zone-map reqs == 0 + O(K) frames + Or-prune parity, "
              f"ingest ops == primaries <= K, "
              f"warm xattr ops <= K) at {n_rows} rows")
    else:
        if OUT_PATH.exists():
            committed = json.loads(OUT_PATH.read_text())
            problems = check_against_snapshot(report, committed)
            assert not problems, "regression vs committed snapshot: " \
                + "; ".join(problems)
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        print(f"BENCH_pushdown -> {OUT_PATH}")
    q = report["queries"]
    print(f"  {q['n_objects']} objects on {q['n_osds']} OSDs")
    for name, row in q["queries"].items():
        print(f"  {name:<14} ops {row['pushdown']['fabric_ops']:>3} vs "
              f"{row['client_side']['fabric_ops']:>3}  "
              f"bytes x{row['bytes_reduction']:<8.1f} "
              f"wall {row['pushdown']['wall_s'] * 1e3:.1f}ms vs "
              f"{row['client_side']['wall_s'] * 1e3:.1f}ms")
    pp = report["prune_pushdown"]
    print(f"  prune_pushdown zone-map reqs 0 (agg, OSD-side prune), "
          f"table-out frames {pp['table_out']['rx_frames']} "
          f"(= K primaries) for {pp['n_objects']} objects")
    pa = report["predicate_algebra"]
    print(f"  predicate_algebra OR-agg pruned "
          f"{pa['or_agg']['objects_pruned']}/{pa['n_objects']} objects "
          f"OSD-side (0 zone-map reqs, both strategies agree), "
          f"wall {pa['or_agg']['wall_s'] * 1e3:.1f}ms vs "
          f"{pa['or_agg']['client_filter_wall_s'] * 1e3:.1f}ms "
          f"client-filter; IN table-out "
          f"{pa['in_table_out']['rx_frames']} frames")
    ing = report["ingest"]
    print(f"  ingest         ops {ing['batched']['fabric_ops']:>3} vs "
          f"{ing['per_object']['fabric_ops']:>3} "
          f"(x{ing['ops_reduction']:.1f} fewer requests), "
          f"zone-map warm {ing['zone_map_warm_xattr_ops']} xattr ops")
    c = report["codec"]
    print(f"  codec bitpack{c['bits']}: encode x{c['encode_speedup']:.1f} "
          f"({c['encode_vec_MBps']:.0f} MB/s), "
          f"decode x{c['decode_speedup']:.1f} "
          f"({c['decode_vec_MBps']:.0f} MB/s) vs per-bit loop")


if __name__ == "__main__":
    main()
