"""Perf trajectory export: writes ``BENCH_pushdown.json`` at the repo
root so later PRs have hard numbers to compare against.

Two sections:

  queries  — filter→agg (and friends) through the batched pushdown
             plane vs the client-side gather baseline: fabric ops
             (round trips), client_rx bytes, request overhead bytes and
             wall seconds per path.  The headline claim: a scan over N
             objects on K OSDs costs <= K ops batched (seed paid >= N).
  codec    — vectorized planar-bitpack encode/decode vs the historical
             per-bit-loop reference (bit-exact, same layout): MB/s and
             speedup on the ingest/scan hot path.
"""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import format as fmt
from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.skyhook import Query, SkyhookDriver
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_pushdown.json"
N_ROWS = 200_000


def _loop_bitpack_encode(values, bits):
    """Historical per-bit-loop encoder, kept here as the codec baseline."""
    v = np.ascontiguousarray(values, dtype=np.uint32).ravel()
    n = v.size
    n_groups = -(-n // 32) if n else 0
    padded = np.zeros((n_groups * 32,), np.uint32)
    padded[:n] = v
    g = padded.reshape(n_groups, 32)
    lane = np.arange(32, dtype=np.uint32)
    out = np.zeros((n_groups, bits), np.uint32)
    for k in range(bits):
        out[:, k] = (((g >> np.uint32(k)) & np.uint32(1)) << lane).sum(
            axis=1, dtype=np.uint32)
    return out


def _loop_bitpack_decode(words, bits, n):
    w = np.ascontiguousarray(words, dtype=np.uint32).reshape(-1, bits)
    lane = np.arange(32, dtype=np.uint32)
    vals = np.zeros((w.shape[0], 32), np.uint32)
    for k in range(bits):
        vals |= (((w[:, k:k + 1] >> lane) & np.uint32(1))
                 << np.uint32(k)).astype(np.uint32)
    return vals.ravel()[:n]


def _best_of(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_codec(n=1_000_000, bits=17) -> dict:
    rng = np.random.default_rng(0)
    v = rng.integers(0, 1 << bits, n).astype(np.uint32)
    words = fmt.bitpack_encode(v, bits)
    assert np.array_equal(words, _loop_bitpack_encode(v, bits))
    assert np.array_equal(fmt.bitpack_decode(words, bits, n), v)
    enc_vec = _best_of(lambda: fmt.bitpack_encode(v, bits))
    enc_loop = _best_of(lambda: _loop_bitpack_encode(v, bits))
    dec_vec = _best_of(lambda: fmt.bitpack_decode(words, bits, n))
    dec_loop = _best_of(lambda: _loop_bitpack_decode(words, bits, n))
    mb = v.nbytes / 2**20
    return {
        "n_values": n, "bits": bits,
        "encode_vec_s": enc_vec, "encode_loop_s": enc_loop,
        "decode_vec_s": dec_vec, "decode_loop_s": dec_loop,
        "encode_speedup": enc_loop / enc_vec,
        "decode_speedup": dec_loop / dec_vec,
        "encode_vec_MBps": mb / enc_vec, "decode_vec_MBps": mb / dec_vec,
    }


def bench_queries() -> dict:
    ds = LogicalDataset(
        "events",
        (Column("e_pt", "float32"), Column("run", "int32"),
         Column("hits", "int32")),
        N_ROWS, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=64 << 10,
                                          max_object_bytes=1 << 20))
    rng = np.random.default_rng(1)
    vol.write(omap, {
        "e_pt": rng.gamma(2.0, 20.0, N_ROWS).astype(np.float32),
        "run": rng.integers(0, 100, N_ROWS).astype(np.int32),
        "hits": rng.poisson(12, N_ROWS).astype(np.int32),
    })
    drv = SkyhookDriver(vol, n_workers=4)
    queries = [
        ("filter_agg", Query("events", filter=("run", "<", 50),
                             aggregate=("mean", "e_pt"))),
        ("selective_agg", Query("events", filter=("run", "==", 7),
                                aggregate=("sum", "hits"))),
        ("count_star", Query("events", aggregate=("count", "e_pt"))),
    ]
    out: dict = {"n_rows": N_ROWS, "n_objects": omap.n_objects,
                 "n_osds": len(store.cluster.up_osds), "queries": {}}
    for name, q in queries:
        drv.execute(q)  # warm the zone-map cache + pools
        r1 = r2 = None
        s1 = s2 = None
        for _ in range(3):  # best-of-3: container wall clocks are noisy
            r1, t1 = drv.execute(q)
            r2, t2 = drv.execute_client_side(q)
            if s1 is None or t1.wall_s < s1.wall_s:
                s1 = t1
            if s2 is None or t2.wall_s < s2.wall_s:
                s2 = t2
        assert abs(r1 - r2) < 1e-6 * max(abs(r2), 1.0), (name, r1, r2)
        out["queries"][name] = {
            "pushdown": {"fabric_ops": s1.fabric_ops,
                         "client_rx_bytes": s1.client_rx_bytes,
                         "wall_s": s1.wall_s},
            "client_side": {"fabric_ops": s2.fabric_ops,
                            "client_rx_bytes": s2.client_rx_bytes,
                            "wall_s": s2.wall_s},
            "ops_reduction": s2.fabric_ops / max(s1.fabric_ops, 1),
            "bytes_reduction":
                s2.client_rx_bytes / max(s1.client_rx_bytes, 1),
        }
        assert s1.fabric_ops <= out["n_osds"], (name, s1.fabric_ops)
    return out


def main() -> None:
    report = {"queries": bench_queries(), "codec": bench_codec()}
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    q = report["queries"]
    print(f"BENCH_pushdown -> {OUT_PATH}")
    print(f"  {q['n_objects']} objects on {q['n_osds']} OSDs")
    for name, row in q["queries"].items():
        print(f"  {name:<14} ops {row['pushdown']['fabric_ops']:>3} vs "
              f"{row['client_side']['fabric_ops']:>3}  "
              f"bytes x{row['bytes_reduction']:<8.1f} "
              f"wall {row['pushdown']['wall_s'] * 1e3:.1f}ms vs "
              f"{row['client_side']['wall_s'] * 1e3:.1f}ms")
    c = report["codec"]
    print(f"  codec bitpack{c['bits']}: encode x{c['encode_speedup']:.1f} "
          f"({c['encode_vec_MBps']:.0f} MB/s), "
          f"decode x{c['decode_speedup']:.1f} "
          f"({c['decode_vec_MBps']:.0f} MB/s) vs per-bit loop")


if __name__ == "__main__":
    main()
