"""Paper §3.2: composability of access operations.

Quantifies the three cases on the same dataset + predicate:
  decomposable      — agg runs per-object, partials combine (pushdown)
  holistic exact    — median gathers its projected input column
  holistic approx   — median rewritten to a decomposable quantile
                      sketch ('de-composable approximations that deliver
                      acceptable results')

Reports client bytes, wall time, and the approximation error.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import objclass as oc
from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL

N_ROWS = 300_000


def main() -> None:
    ds = LogicalDataset("comp", (Column("x", "float64"),), N_ROWS, 4096)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=1 << 20,
                                          max_object_bytes=8 << 20))
    rng = np.random.default_rng(2)
    x = rng.lognormal(0.0, 1.0, N_ROWS)
    vol.write(omap, {"x": x})
    truth = float(np.median(x))

    cases = []
    t0 = time.perf_counter()
    mean, st = vol.query(omap, [oc.op("agg", col="x", fn="mean")])
    cases.append(("mean (decomposable)", time.perf_counter() - t0,
                  st["client_rx"], abs(mean - x.mean())))
    t0 = time.perf_counter()
    med, st = vol.query(omap, [oc.op("median", col="x")])
    cases.append(("median exact (holistic)", time.perf_counter() - t0,
                  st["client_rx"], abs(med - truth)))
    t0 = time.perf_counter()
    meda, st = vol.query(omap, [oc.op("median", col="x")],
                         allow_approx=True)
    cases.append(("median approx (sketch)", time.perf_counter() - t0,
                  st["client_rx"], abs(meda - truth)))

    print(f"composability ({N_ROWS} rows)")
    print(f"{'case':<26}{'wall_ms':>9}{'client_KB':>11}{'abs_err':>10}")
    for name, dt, rx, err in cases:
        print(f"{name:<26}{dt * 1e3:>9.1f}{rx / 1024:>11.1f}{err:>10.5f}")
    assert cases[2][2] < cases[1][2] / 10, "sketch must move fewer bytes"
    assert cases[2][3] < 0.05, "sketch error must stay acceptable"
    print("claim: approximate rewrite trades bounded error for O(result) "
          "traffic -> OK")


if __name__ == "__main__":
    main()
