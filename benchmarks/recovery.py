"""Failure management (paper §1: 'fully leveraging the existing load
balancing, elasticity, and failure management of distributed storage').

Measures: re-replication traffic and time after an OSD loss; elastic
scale-out movement fraction vs the HRW minimal-movement bound; and
training-checkpoint restore under failures.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy
from repro.core.store import make_store
from repro.core.vol import GlobalVOL
from repro.distributed import elastic


def main() -> None:
    ds = LogicalDataset("r", (Column("x", "uint8", (1024,)),),
                        64_000, 2048)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=2 << 20,
                                          max_object_bytes=16 << 20))
    rng = np.random.default_rng(0)
    vol.write(omap, {"x": rng.integers(0, 255, (64_000, 1024),
                                       dtype=np.uint8)})
    total = sum(store.stats()["osd_bytes"].values())

    print("recovery (64MB dataset, 8 OSDs, rep=2)")
    victim = store.cluster.osds[0]
    before = store.fabric.recovery_bytes
    t0 = time.perf_counter()
    store.fail_osd(victim)
    rec = store.recover()
    dt = time.perf_counter() - t0
    moved = store.fabric.recovery_bytes - before
    print(f"osd loss: re-replicated {moved / 2**20:.1f} MB "
          f"({moved / total * 100:.1f}% of stored) in {dt * 1e3:.0f} ms; "
          f"lost={rec['objects_lost']}")
    assert rec["objects_lost"] == 0

    before = store.fabric.recovery_bytes
    out = elastic.apply_storage_resize(store, add=("osd.new",))
    frac = out["plan"]["movement_fraction"]
    moved = store.fabric.recovery_bytes - before
    print(f"scale-out +1 OSD: movement_fraction={frac:.3f} "
          f"(ideal ~{1 / 8:.3f}), traffic {moved / 2**20:.1f} MB")
    assert frac < 0.40
    print("claims: zero loss under rep-1 failures; near-minimal movement "
          "on resize -> OK")


if __name__ == "__main__":
    main()
