"""Failure management (paper §1: 'fully leveraging the existing load
balancing, elasticity, and failure management of distributed storage').

Measures: re-replication traffic and time after an OSD loss; elastic
scale-out movement fraction vs the HRW minimal-movement bound; and the
self-healing plane — scrub throughput against stamped digests, heal
under live scans (foreground latency bound), and 100% detection of an
injected fault campaign (bit rot + torn write + slow OSD + transient
failures) with zero wrong bytes returned to clients.

Writes ``BENCH_recovery.json`` at the repo root.  ``--smoke`` (or
``BENCH_SMOKE=1``) runs a smaller shape and asserts only the
correctness gates — cheap enough for per-PR CI:

  * injected fault campaign: every live scan bit-exact (wrong_bytes=0)
  * scrub detects 100% of injected corruptions and heals them through
    the replication chain; a second scrub finds nothing
  * digest-verified recover: zero loss under rep-1 failures
  * foreground scans keep answering while scrub/heal runs
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import threading
import time

import numpy as np

from repro.core.faults import FaultInjector
from repro.core.logical import Column, LogicalDataset, RowRange
from repro.core.partition import PartitionPolicy
from repro.core.store import RetryPolicy, make_store
from repro.core.vol import GlobalVOL
from repro.core import objclass as oc
from repro.distributed import elastic

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_recovery.json"


def bench_osd_loss() -> dict:
    ds = LogicalDataset("r", (Column("x", "uint8", (1024,)),),
                        64_000, 2048)
    store = make_store(8, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=2 << 20,
                                          max_object_bytes=16 << 20))
    rng = np.random.default_rng(0)
    vol.write(omap, {"x": rng.integers(0, 255, (64_000, 1024),
                                       dtype=np.uint8)})
    total = sum(store.stats()["osd_bytes"].values())

    print("recovery (64MB dataset, 8 OSDs, rep=2)")
    victim = store.cluster.osds[0]
    before = store.fabric.recovery_bytes
    t0 = time.perf_counter()
    store.fail_osd(victim)
    rec = store.recover()  # digest-verified: raises DataLossError on loss
    dt = time.perf_counter() - t0
    moved = store.fabric.recovery_bytes - before
    print(f"osd loss: re-replicated {moved / 2**20:.1f} MB "
          f"({moved / total * 100:.1f}% of stored) in {dt * 1e3:.0f} ms; "
          f"lost={rec['objects_lost']}")
    assert rec["objects_lost"] == 0

    before = store.fabric.recovery_bytes
    out = elastic.apply_storage_resize(store, add=("osd.new",))
    frac = out["plan"]["movement_fraction"]
    emoved = store.fabric.recovery_bytes - before
    print(f"scale-out +1 OSD: movement_fraction={frac:.3f} "
          f"(ideal ~{1 / 8:.3f}), traffic {emoved / 2**20:.1f} MB")
    assert frac < 0.40
    return {"rereplicated_bytes": moved, "recover_wall_s": dt,
            "objects_lost": rec["objects_lost"],
            "scaleout_movement_fraction": frac,
            "scaleout_traffic_bytes": emoved}


def bench_selfheal(n_rows: int) -> dict:
    """The fault campaign the acceptance criteria gate: bit flips on
    random replicas + one torn write + one slow OSD + transient
    failures, under a live scan workload."""
    rng = np.random.default_rng(7)
    ds = LogicalDataset(
        "sh", (Column("x", "float64"), Column("y", "int32")), n_rows, 256)
    store = make_store(8, replicas=3,
                       retry=RetryPolicy(attempts=4, base_s=1e-4))
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=24 << 10,
                                          max_object_bytes=4 << 20))
    table = {"x": rng.normal(size=n_rows),
             "y": rng.integers(0, 1000, n_rows).astype(np.int32)}
    vol.write(omap, table)
    names = omap.object_names()

    def scan_once() -> int:
        """One round of the live workload; returns wrong bytes found."""
        wrong = 0
        r, _ = vol.query(omap, [oc.op("agg", col="y", fn="count")])
        wrong += r != float(n_rows)
        s, _ = vol.query(omap, [
            oc.op("filter", col="y", cmp="<", value=500),
            oc.op("agg", col="x", fn="sum")])
        expect = table["x"][table["y"] < 500].sum()
        wrong += abs(s - expect) > 1e-9 * max(1.0, abs(expect))
        lo = int(rng.integers(0, n_rows - 1000))
        out = vol.read(omap, RowRange(lo, lo + 1000))
        wrong += int((out["y"] != table["y"][lo:lo + 1000]).sum())
        wrong += int((out["x"] != table["x"][lo:lo + 1000]).sum())
        return int(wrong)

    t0 = time.perf_counter()
    scan_once()
    baseline_scan_s = time.perf_counter() - t0

    # ---- inject the campaign
    fi = FaultInjector(store)
    flip_victims = rng.choice(len(names), size=4, replace=False)
    for i in flip_victims:
        acting = store.cluster.locate(names[i])
        fi.flip_bits(names[i],
                     osd_id=acting[int(rng.integers(len(acting)))],
                     n_bits=int(rng.integers(1, 8)))
    torn = names[int(rng.choice(
        [i for i in range(len(names)) if i not in flip_victims]))]
    fi.tear_write(torn)
    fi.slow(store.cluster.up_osds[0], 5e-4)
    for osd_id in store.cluster.up_osds[1:3]:
        fi.transient_failures(osd_id, 3)

    t0 = time.perf_counter()
    wrong_bytes = scan_once()  # live scans under the campaign
    faulted_scan_s = time.perf_counter() - t0
    retries = store.fabric.retries

    # ---- scrub + heal while foreground scans keep running
    fi.clear()  # latency/transient knobs off; the damage stays
    fg_lat: list[float] = []
    stop = threading.Event()

    def foreground():
        while not stop.is_set():
            t = time.perf_counter()
            wrong = scan_once()
            fg_lat.append(time.perf_counter() - t)
            assert wrong == 0, "wrong bytes during heal"

    fg = threading.Thread(target=foreground)
    fg.start()
    t0 = time.perf_counter()
    scrub_stats = store.scrub()
    scrub_wall_s = time.perf_counter() - t0
    stop.set()
    fg.join()

    detected = store.fabric.corruptions_detected
    injected = fi.corruptions_injected
    second = store.scrub()
    scrub_mb_s = (store.fabric.scrub_bytes / 2**20) / max(scrub_wall_s,
                                                          1e-9)
    fg_worst = max(fg_lat) if fg_lat else faulted_scan_s

    # ---- the gates (asserted in smoke AND full runs)
    assert wrong_bytes == 0, f"{wrong_bytes} wrong bytes under faults"
    assert detected == injected, (detected, injected)
    assert scrub_stats["lost"] == (), scrub_stats["lost"]
    assert second["corrupt_copies"] == 0 and second["healed_copies"] == 0
    assert store.fabric.heals >= scrub_stats["healed_copies"] >= 1
    # heal never starves the foreground: scans keep completing (bit-
    # exact, asserted above) and the worst foreground latency stays
    # within a generous bound of the unfaulted baseline (wall clock is
    # machine-noisy; the bound is a wedge detector, not a perf claim)
    lat_bound_s = max(50 * baseline_scan_s, 1.0)
    assert fg_worst < lat_bound_s, (fg_worst, lat_bound_s)

    print(f"self-heal ({n_rows} rows, {len(names)} objects, rep=3): "
          f"campaign={injected} corruptions + torn + slow + transients")
    print(f"  live scans under faults: wrong_bytes=0, "
          f"retries={retries}, "
          f"latency x{faulted_scan_s / max(baseline_scan_s, 1e-9):.2f}")
    print(f"  scrub: {scrub_mb_s:.0f} MB/s verify, detected "
          f"{detected}/{injected}, healed "
          f"{scrub_stats['healed_copies']} copies through the chain; "
          f"second scrub clean")
    print(f"  foreground under heal: worst {fg_worst * 1e3:.0f} ms "
          f"(bound {lat_bound_s * 1e3:.0f} ms), "
          f"{len(fg_lat)} rounds completed")
    return {
        "n_rows": n_rows, "n_objects": len(names),
        "corruptions_injected": injected,
        "corruptions_detected": detected,
        "wrong_bytes": wrong_bytes,
        "retries": retries,
        "healed_copies": scrub_stats["healed_copies"],
        "scrub_bytes": store.fabric.scrub_bytes,
        "scrub_mb_per_s": scrub_mb_s,
        "second_scrub_corrupt": second["corrupt_copies"],
        "baseline_scan_s": baseline_scan_s,
        "faulted_scan_s": faulted_scan_s,
        "fg_worst_latency_s": fg_worst,
        "fg_latency_bound_s": lat_bound_s,
        "fg_rounds_under_heal": len(fg_lat),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    report = {"osd_loss": bench_osd_loss(),
              "selfheal": bench_selfheal(20_000 if smoke else 100_000)}
    if smoke:
        print("recovery --smoke: gates hold (zero loss under rep-1 "
              "failure, near-minimal resize movement, zero wrong bytes "
              "under the fault campaign, 100% corruption detection, "
              "idempotent scrub, live scans under heal)")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        print(f"BENCH_recovery -> {OUT_PATH}")
    print("claims: zero loss under rep-1 failures; near-minimal movement "
          "on resize; self-healing under gray failures -> OK")


if __name__ == "__main__":
    main()
