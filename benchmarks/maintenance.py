"""Online maintenance plane (paper §1: storage-side 'load balancing,
elasticity, and failure management' that access libraries inherit
instead of reimplementing).

Runs ALL FOUR maintenance daemons — continuous scrub walker, small-
object compactor, live rebalancer, versioned GC — concurrently with a
foreground serve workload while the harness injects a fault campaign,
appends a tiny-object stream, and swaps an OSD.  Measures foreground
p50/p99 against a quiet baseline and the maintenance plane's own
throughput (scrub MB/s, compaction ratio, rebalance traffic, GC
reclaim).

Writes ``BENCH_maintenance.json`` at the repo root.  ``--smoke`` (or
``BENCH_SMOKE=1``) runs a smaller shape and asserts only the gates —
cheap enough for per-PR CI:

  * every foreground scan bit-exact while all four daemons run
  * foreground p99 under maintenance within a bounded factor of the
    quiet baseline (wedge detector, not a perf claim)
  * compaction folds the tiny-append stream >= 4x by object count
  * the walker detects 100% of the injected campaign
  * after the campaign drains, an on-demand ``scrub()`` finds nothing
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import threading
import time

import numpy as np

from repro.core.faults import FaultInjector
from repro.core.logical import Column, LogicalDataset, RowRange
from repro.core.maintenance import MaintenancePlane
from repro.core.partition import PartitionPolicy
from repro.core.store import RetryPolicy, make_store
from repro.core.vol import GlobalVOL
from repro.core import objclass as oc

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_maintenance.json"


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _wait(cond, timeout_s: float, what: str) -> None:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if cond():
            return
        time.sleep(0.01)
    raise AssertionError(f"maintenance bench: timed out on {what}")


def bench_maintenance(hot_rows: int, tiny_rows: int) -> dict:
    rng = np.random.default_rng(11)
    store = make_store(6, replicas=3,
                       retry=RetryPolicy(attempts=4, base_s=1e-4,
                                         jitter="decorrelated", seed=0))
    vol = GlobalVOL(store)

    # dataset A — the hot serve set (right-sized objects, scanned
    # continuously by the foreground workload)
    ds_hot = LogicalDataset(
        "hot", (Column("x", "float64"), Column("y", "int32")),
        hot_rows, 256)
    omap_hot = vol.create(ds_hot, PartitionPolicy(
        target_object_bytes=24 << 10, max_object_bytes=4 << 20))
    hot = {"x": rng.normal(size=hot_rows),
           "y": rng.integers(0, 1000, hot_rows).astype(np.int32)}
    vol.write(omap_hot, hot)

    # dataset B — the tiny-append stream (one object per appended
    # unit: the ckpt/kvcache shape compaction exists for)
    unit = 32
    ds_ck = LogicalDataset("ck", (Column("v", "float64"),),
                           tiny_rows, unit)
    omap_ck = vol.create(ds_ck, PartitionPolicy(
        target_object_bytes=unit * 8, max_object_bytes=1 << 20))
    ck = {"v": rng.normal(size=tiny_rows)}
    vol.write(omap_ck, ck)

    n_tiny_before = vol.open("ck").n_objects
    want_x_sum = float(hot["x"].sum())

    def scan_once() -> tuple[float, int]:
        """One foreground round against the HOT set; returns (latency,
        wrong-results count)."""
        wrong = 0
        t0 = time.perf_counter()
        s, _ = vol.query(omap_hot, [oc.op("agg", col="x", fn="sum")])
        wrong += abs(s - want_x_sum) > 1e-9 * max(1.0, abs(want_x_sum))
        lo = int(rng.integers(0, hot_rows - 1000))
        out = vol.read(omap_hot, RowRange(lo, lo + 1000))
        wrong += int((out["x"] != hot["x"][lo:lo + 1000]).sum())
        wrong += int((out["y"] != hot["y"][lo:lo + 1000]).sum())
        return time.perf_counter() - t0, int(wrong)

    # ---- quiet baseline: foreground latencies with no maintenance
    quiet_lat: list[float] = []
    for _ in range(30):
        dt, wrong = scan_once()
        assert wrong == 0
        quiet_lat.append(dt)
    p99_quiet = _pct(quiet_lat, 99)

    # ---- start the plane: all four daemons, short retention, GC
    # confirmed up front so the whole lifecycle runs inside the bench
    plane = MaintenancePlane(
        store,
        compact_policy=PartitionPolicy(target_object_bytes=48 << 10,
                                       max_object_bytes=1 << 20),
        compact_datasets=["ck"],  # the hot set is already right-sized
        gc_retention_s=0.2, gc_confirmed=True,
        batch_objects=16, interval_s=0.0005)
    plane.start()

    maint_lat: list[float] = []
    wrong_total = 0
    stop = threading.Event()

    def foreground():
        nonlocal wrong_total
        while not stop.is_set():
            dt, wrong = scan_once()
            maint_lat.append(dt)
            wrong_total += wrong

    fg = threading.Thread(target=foreground)
    fg.start()
    t_start = time.perf_counter()

    # ---- live events, in order:
    # (1) one OSD swap — the REBALANCER (not on-demand recover()) must
    #     re-home and re-replicate in digest-verified steps
    victim = store.cluster.up_osds[0]
    store.fail_osd(victim)
    store.add_osds(["osd.swap0"])

    # (2) wait for compaction of the tiny-append stream to settle
    _wait(lambda: plane.compact_runs > 0, 30, "first compaction run")
    prev = -1
    while plane.compact_runs != prev:
        prev = plane.compact_runs
        time.sleep(0.3)
    n_tiny_after = vol.open("ck").n_objects

    # (3) fault campaign against the compacted stream's LIVE objects —
    #     the foreground never scans them, so the WALKER is the sole
    #     detector and detected == injected is a strict equality
    fi = FaultInjector(store)
    placed = fi.campaign(vol.open("ck").object_names(),
                         flips=3, torn=1, seed=5)
    assert placed, "campaign placed nothing"
    _wait(lambda: store.fabric.corruptions_detected
          == fi.corruptions_injected, 60, "walker detection")

    # (4) drain: GC reclaims the compacted-away members, the
    #     rebalancer finishes re-homing after the swap
    _wait(lambda: store.fabric.gc_objects > 0, 60, "gc reclaim")
    _wait(lambda: plane.rebalance_rounds >= plane.topology_changes + 1,
          60, "rebalance rounds after swap")
    maint_wall_s = time.perf_counter() - t_start
    stop.set()
    fg.join()
    plane.pause()
    time.sleep(0.05)  # let in-flight steps park

    p99_maint = _pct(maint_lat, 99)
    p50_quiet, p50_maint = _pct(quiet_lat, 50), _pct(maint_lat, 50)
    detected = store.fabric.corruptions_detected
    injected = fi.corruptions_injected
    ratio = n_tiny_before / max(1, n_tiny_after)

    # post-campaign verify pass: the plane left nothing behind
    final = store.scrub()
    plane.stop()

    # ---- the gates (asserted in smoke AND full runs)
    assert wrong_total == 0, f"{wrong_total} wrong results under maint"
    assert len(maint_lat) >= 10, "foreground starved under maintenance"
    lat_bound_s = max(50 * p99_quiet, 1.0)
    assert p99_maint < lat_bound_s, (p99_maint, lat_bound_s)
    assert ratio >= 4.0, (n_tiny_before, n_tiny_after)
    assert detected == injected, (detected, injected)
    assert final["corrupt_copies"] == 0, final
    assert final["lost"] == (), final["lost"]
    # post-compaction reads of the stream stay bit-exact end to end
    out = vol.read(vol.open("ck"), RowRange(0, tiny_rows))
    assert np.array_equal(out["v"], ck["v"])

    scrub_mb_s = (store.fabric.scrub_bytes / 2**20) / max(maint_wall_s,
                                                          1e-9)
    print(f"maintenance plane ({hot_rows} hot rows, {n_tiny_before} "
          f"tiny objects, 6 OSDs rep=3, all four daemons + OSD swap)")
    print(f"  foreground: {len(maint_lat)} rounds bit-exact; "
          f"p50 {p50_quiet * 1e3:.1f} -> {p50_maint * 1e3:.1f} ms, "
          f"p99 {p99_quiet * 1e3:.1f} -> {p99_maint * 1e3:.1f} ms "
          f"(bound {lat_bound_s * 1e3:.0f} ms)")
    print(f"  compactor: {n_tiny_before} -> {n_tiny_after} objects "
          f"({ratio:.1f}x, gate >=4x), "
          f"{store.fabric.compaction_bytes / 2**20:.2f} MB moved")
    print(f"  walker: detected {detected}/{injected} injected, "
          f"scrubbed {store.fabric.scrub_bytes / 2**20:.1f} MB "
          f"(~{scrub_mb_s:.0f} MB/s); final scrub clean")
    print(f"  rebalancer: {store.fabric.rebalance_bytes / 2**20:.2f} MB "
          f"re-homed after swap; GC reclaimed "
          f"{store.fabric.gc_objects} objects "
          f"({store.fabric.gc_bytes / 2**20:.2f} MB)")
    return {
        "hot_rows": hot_rows, "tiny_rows": tiny_rows,
        "tiny_objects_before": n_tiny_before,
        "tiny_objects_after": n_tiny_after,
        "compaction_ratio": ratio,
        "compaction_bytes": store.fabric.compaction_bytes,
        "p50_quiet_s": p50_quiet, "p99_quiet_s": p99_quiet,
        "p50_maint_s": p50_maint, "p99_maint_s": p99_maint,
        "p99_bound_s": lat_bound_s,
        "fg_rounds_under_maint": len(maint_lat),
        "wrong_results": wrong_total,
        "corruptions_injected": injected,
        "corruptions_detected": detected,
        "scrub_bytes": store.fabric.scrub_bytes,
        "rebalance_bytes": store.fabric.rebalance_bytes,
        "gc_objects": store.fabric.gc_objects,
        "gc_bytes": store.fabric.gc_bytes,
        "final_scrub_corrupt": final["corrupt_copies"],
        "maint_wall_s": maint_wall_s,
        "plane": plane.stats(),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv or os.environ.get("BENCH_SMOKE") == "1"
    report = {"maintenance": bench_maintenance(
        20_000 if smoke else 100_000,
        4_096 if smoke else 16_384)}
    if smoke:
        print("maintenance --smoke: gates hold (bit-exact foreground "
              "under all four daemons, bounded p99, >=4x compaction, "
              "100% walker detection, clean final scrub)")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        print(f"BENCH_maintenance -> {OUT_PATH}")
    print("claims: the serve plane keeps answering bit-exactly while "
          "the store scrubs, compacts, rebalances, and collects "
          "itself -> OK")


if __name__ == "__main__":
    main()
