"""Gradient compression, elastic resize, pushdown_jax data plane."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import make_store
from repro.core.pushdown_jax import (
    packed_shape, pushdown_filter_aggregate, unpack_bitpacked)
from repro.distributed import elastic
from repro.distributed.compression import (
    compress_residual, dequantize_int8, init_error_state, quantize_int8)


# ---------------------------------------------------------------- int8
@given(st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_quantize_error_bounded(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 3.0
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_recovers_mean_gradient():
    """With a CONSTANT gradient, EF-compressed updates converge so the
    time-average of decoded gradients -> the true gradient."""
    g = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 0.1
    err = jnp.zeros_like(g)
    decoded_sum = jnp.zeros_like(g)
    steps = 200
    for _ in range(steps):
        q, s, err = compress_residual(g, err)
        decoded_sum = decoded_sum + dequantize_int8(q, s)
    avg = decoded_sum / steps
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g),
                               atol=5e-4)


def test_init_error_state_shapes():
    params = {"a": jnp.zeros((3, 4), jnp.bfloat16), "b": jnp.ones((2,))}
    err = init_error_state(params)
    assert err["a"].shape == (3, 4) and err["a"].dtype == jnp.float32


# ---------------------------------------------------------------- elastic
@given(st.integers(4, 20))
@settings(max_examples=10, deadline=None)
def test_storage_resize_plan_minimal(n):
    from repro.core.placement import ClusterMap
    cm = ClusterMap(tuple(f"o{i}" for i in range(n)), n_pgs=64,
                    replicas=2)
    new, plan = elastic.plan_storage_resize(cm, add=("newbie",))
    assert plan.movement_fraction <= 3.0 / (n + 1)
    assert plan.epoch == cm.epoch + 1


def test_apply_storage_resize_end_to_end():
    store = make_store(4, replicas=2)
    for i in range(50):
        store.put(f"obj.{i}", bytes([i]) * 100)
    out = elastic.apply_storage_resize(store, add=("osd.new.0",))
    assert out["objects_lost"] == 0
    for i in range(50):
        assert store.get(f"obj.{i}") == bytes([i]) * 100
    # new OSD actually holds data (took over some PGs)
    assert store.osds["osd.new.0"].nbytes() > 0


def test_replan_loader_coverage():
    out = elastic.replan_loader(10_000, 256, old_dp=16, new_dp=32)
    assert out["coverage_preserved"]
    assert out["new_local_batch"] == 8


# ---------------------------------------------------------------- device
def test_unpack_bitpacked_matches_host():
    from repro.core.format import bitpack_encode
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 1 << 11, 4096).astype(np.uint32)
    words = bitpack_encode(vals, 11)
    assert words.shape == packed_shape(4096, 11)
    out = unpack_bitpacked(jnp.asarray(words), 11)
    np.testing.assert_array_equal(np.asarray(out), vals.astype(np.int32))


def test_pushdown_filter_aggregate_no_mesh():
    rng = np.random.default_rng(4)
    v = rng.normal(size=1000).astype(np.float32)
    f = rng.integers(0, 10, 1000).astype(np.float32)
    res = pushdown_filter_aggregate(jnp.asarray(v), jnp.asarray(f),
                                    "<", 5.0)
    mask = f < 5
    np.testing.assert_allclose(float(res["sum"]), v[mask].sum(),
                               rtol=1e-5)
    assert float(res["count"]) == mask.sum()
