"""CRUSH/HRW placement properties (paper §1: 'fully leveraging the
existing load balancing, elasticity, and failure management')."""

import collections

from _hyp import given, settings, st

from repro.core.placement import ClusterMap, movement_fraction, pg_delta

osd_names = st.lists(st.integers(0, 999), min_size=3, max_size=24,
                     unique=True).map(
    lambda xs: tuple(f"osd.{i}" for i in xs))


@given(osd_names, st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_acting_set_deterministic_and_distinct(osds, replicas):
    cm = ClusterMap(osds, n_pgs=32, replicas=replicas)
    for pg in range(cm.n_pgs):
        a = cm.acting_set(pg)
        assert a == cm.acting_set(pg)          # deterministic
        assert len(a) == min(replicas, len(osds))
        assert len(set(a)) == len(a)           # distinct OSDs


@given(osd_names)
@settings(max_examples=25, deadline=None)
def test_failure_moves_only_affected_pgs(osds):
    cm = ClusterMap(osds, n_pgs=64, replicas=2)
    victim = osds[0]
    cm2 = cm.mark_down(victim)
    for pg, (old, new) in pg_delta(cm, cm2).items():
        assert victim in old                  # only its PGs moved
    for pg in range(cm.n_pgs):
        assert victim not in cm2.acting_set(pg)


@given(osd_names)
@settings(max_examples=25, deadline=None)
def test_add_osd_minimal_movement(osds):
    cm = ClusterMap(osds, n_pgs=64, replicas=2)
    cm2 = cm.add_osds(["osd.newcomer"])
    # every remapped PG must now include the newcomer (nothing else
    # reshuffles under rendezvous hashing)
    for pg, (old, new) in pg_delta(cm, cm2).items():
        assert "osd.newcomer" in new
    # movement bounded ~ by the newcomer's capacity share (slack 3x)
    frac = movement_fraction(cm, cm2)
    assert frac <= 3.0 / (len(osds) + 1)


def test_load_balance_roughly_uniform():
    cm = ClusterMap(tuple(f"osd.{i}" for i in range(10)), n_pgs=1024,
                    replicas=3)
    load = collections.Counter()
    for pg in range(cm.n_pgs):
        for o in cm.acting_set(pg):
            load[o] += 1
    mean = sum(load.values()) / len(load)
    for o, n in load.items():
        assert 0.6 * mean < n < 1.4 * mean, (o, n, mean)


def test_weights_bias_placement():
    osds = tuple(f"osd.{i}" for i in range(8))
    cm = ClusterMap(osds, n_pgs=2048, replicas=1,
                    weights={"osd.0": 4.0})
    load = collections.Counter(cm.acting_set(pg)[0]
                               for pg in range(cm.n_pgs))
    others = [load[o] for o in osds[1:]]
    assert load["osd.0"] > 2 * max(others)


def test_epoch_bumps():
    cm = ClusterMap(("a", "b", "c"))
    assert cm.mark_down("a").epoch == 1
    assert cm.mark_down("a").mark_up("a").epoch == 2
    assert cm.reweight("b", 2.0).epoch == 1
