"""Partition planner properties (paper §3.1: object sizing tradeoff)."""

from _hyp import given, settings, st

from repro.core.logical import Column, LogicalDataset, RowRange
from repro.core.partition import ObjectMap, PartitionPolicy, plan_partition


def dataset(n_rows, unit_rows, row_bytes=16):
    return LogicalDataset(
        "d", (Column("x", "uint8", (row_bytes,)),), n_rows, unit_rows)


@given(st.integers(1, 5000), st.integers(1, 300),
       st.integers(4, 64), st.integers(2, 8))
@settings(max_examples=50, deadline=None)
def test_partition_covers_exactly(n_rows, unit_rows, target_kb, max_mult):
    ds = dataset(n_rows, unit_rows)
    pol = PartitionPolicy(target_object_bytes=target_kb * 64,
                          max_object_bytes=target_kb * 64 * max_mult)
    omap = plan_partition(ds, pol)
    # exact, gapless, ordered coverage (validated by ObjectMap too)
    prev = 0
    for e in omap:
        assert e.row_start == prev and len(e) > 0
        prev = e.row_stop
    assert prev == n_rows
    # object size cap holds whenever a unit fits the cap
    max_rows = max(1, pol.max_object_bytes // ds.row_nbytes)
    if unit_rows <= max_rows:
        for e in omap:
            assert len(e) * ds.row_nbytes <= pol.max_object_bytes \
                or len(e) <= unit_rows


@given(st.integers(1, 5000), st.integers(1, 300))
@settings(max_examples=50, deadline=None)
def test_lookup_matches_bruteforce(n_rows, unit_rows):
    ds = dataset(n_rows, unit_rows)
    omap = plan_partition(ds, PartitionPolicy(
        target_object_bytes=1024, max_object_bytes=8192))
    import numpy as np
    rng = np.random.default_rng(n_rows)
    for _ in range(5):
        a = int(rng.integers(0, n_rows))
        b = int(rng.integers(a + 1, n_rows + 1))
        got = omap.lookup(RowRange(a, b))
        rows = []
        for e, local in got:
            rows.extend(range(e.row_start + local.start,
                              e.row_start + local.stop))
        assert rows == list(range(a, b))


def test_big_unit_is_split():
    ds = dataset(100, 100)  # one 1600-byte unit
    omap = plan_partition(ds, PartitionPolicy(target_object_bytes=256,
                                              max_object_bytes=256))
    assert omap.n_objects >= 100 * 16 // 256
    for e in omap:
        assert len(e) * ds.row_nbytes <= 256


def test_objmap_serialization_roundtrip():
    ds = dataset(1000, 10)
    omap = plan_partition(ds, PartitionPolicy(target_object_bytes=512,
                                              max_object_bytes=4096))
    again = ObjectMap.from_bytes(omap.to_bytes())
    assert again.n_objects == omap.n_objects
    assert again.dataset.n_rows == 1000
    assert [e.name for e in again] == [e.name for e in omap]


def test_colocate_quantum_respected():
    ds = dataset(256, 1)
    pol = PartitionPolicy(target_object_bytes=16 * 64,
                          max_object_bytes=16 * 256, colocate_rows=32)
    omap = plan_partition(ds, pol)
    for e in omap:
        # no extent straddles a 32-row boundary unless it starts on one
        if e.row_start % 32:
            assert (e.row_stop - 1) // 32 == e.row_start // 32
