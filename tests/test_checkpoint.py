"""Checkpoint-as-objects: roundtrip, atomicity, failure tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import make_store


def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 32)),
                   "b": jnp.zeros((32,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((64, 32)), "step": jnp.asarray(7)},
    }


def test_roundtrip():
    store = make_store(4, replicas=2)
    state = tiny_state()
    ckpt.save(store, state, 100)
    like = jax.tree.map(np.asarray, state)
    restored, manifest = ckpt.restore(store, like)
    assert manifest["step"] == 100
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), b)
        assert np.asarray(a).dtype == b.dtype


def test_latest_step_and_tags():
    store = make_store(3, replicas=2)
    ckpt.save(store, tiny_state(), 10)
    ckpt.save(store, tiny_state(), 30)
    ckpt.save(store, tiny_state(), 20, tag="eval")
    assert ckpt.latest_step(store) == 30
    assert ckpt.latest_step(store, tag="eval") == 20


def test_manifest_last_atomicity():
    """Objects without a manifest are invisible to restore."""
    store = make_store(3, replicas=2)
    state = tiny_state()
    ckpt.save(store, state, 10)
    # simulate a crash mid-save of step 20: leaves written, no manifest
    leaves = ckpt._flatten(state)
    for i, (key, arr) in enumerate(sorted(leaves.items())):
        store.put(f"ckpt/train/step-20/leaf-{i:05d}/obj.000000",
                  arr.tobytes())
    assert ckpt.latest_step(store) == 10  # 20 is not committed


def test_restore_survives_osd_failure():
    store = make_store(5, replicas=3)
    state = tiny_state()
    ckpt.save(store, state, 5)
    store.fail_osd(store.cluster.osds[0])
    store.fail_osd(store.cluster.osds[1])
    like = jax.tree.map(np.asarray, state)
    restored, _ = ckpt.restore(store, like)
    assert np.array_equal(np.asarray(state["params"]["w"]),
                          restored["params"]["w"])


def test_manager_retention_and_async():
    store = make_store(3, replicas=2)
    mgr = ckpt.CheckpointManager(store, every_steps=1, keep=2)
    state = tiny_state()
    for step in (1, 2, 3, 4):
        assert mgr.maybe_save(state, step)
    mgr.wait()
    mgr._retire()
    manifests = [n for n in store.list_objects("ckpt/")
                 if n.endswith(".manifest")]
    steps = sorted(int(m.split("step-")[1].split("/")[0])
                   for m in manifests)
    assert steps == [3, 4]


def test_shape_mismatch_rejected():
    store = make_store(3, replicas=2)
    ckpt.save(store, {"w": jnp.zeros((4, 4))}, 1)
    with pytest.raises(ValueError):
        ckpt.restore(store, {"w": np.zeros((2, 2), np.float32)})
