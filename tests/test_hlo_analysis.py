"""HLO analyzer cross-checks (run in a subprocess so the 8-device
XLA_FLAGS never leak into other tests' single-device world)."""

import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = lambda *s: NamedSharding(mesh, P(*s))

    # 1. while-free: flops/bytes must match XLA's own cost analysis
    def f(w1, w2, x):
        h = jnp.tanh(x @ w1)
        return (h @ w2).sum()
    comp = jax.jit(f, in_shardings=(sh(None, "model"), sh("model", None),
                                    sh("data", None))).lower(
        jax.ShapeDtypeStruct((512, 1024), jnp.float32),
        jax.ShapeDtypeStruct((1024, 512), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32)).compile()
    got = analyze(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns a 1-elem list of dicts
        ca = ca[0]
    assert abs(got["flops"] / ca["flops"] - 1) < 0.05, (got["flops"],
                                                        ca["flops"])
    assert abs(got["bytes"] / ca["bytes accessed"] - 1) < 0.2
    assert got["bytes_fused"] <= got["bytes"]
    assert got["collective"]["all-reduce"] > 0

    # 2. scan: flops must scale with trip count (XLA's count does not)
    L = 12
    def g(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, None, length=L)
        return h.sum()
    comp2 = jax.jit(g, in_shardings=(sh(None, "model"),
                                     sh("data", None))).lower(
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32)).compile()
    got2 = analyze(comp2.as_text())
    expect = 2 * 128 * 128 * 512 * L
    assert abs(got2["flops"] / expect - 1) < 0.05, (got2["flops"], expect)
    print("HLO_ANALYSIS_OK")
""")


@pytest.mark.slow
def test_analyzer_matches_xla_costs():
    out = subprocess.run([sys.executable, "-c", PROG],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"},
                         cwd="/root/repo", timeout=600)
    assert "HLO_ANALYSIS_OK" in out.stdout, out.stdout + out.stderr
