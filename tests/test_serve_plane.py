"""Hot-data serve plane: OSD result caches (coherence, LRU bound,
meters), ScanSession single-flight/coalescing, the modeled service
queue, and adaptive put_batch windows.
"""

import threading

import numpy as np
import pytest

from repro.core import (Column, FaultInjector, GlobalVOL, LogicalDataset,
                        PartitionPolicy, ScanSession, make_store)
from repro.core import objclass as oc
from repro.core.cache import _MISS, ResultCache
from repro.core.store import (ADAPTIVE_WINDOW_CAP, ADAPTIVE_WINDOW_FLOOR,
                              DEFAULT_WINDOW_BYTES)


def make_world(n=4000, n_osds=4, replicas=2, seed=0, obj_kb=8, **store_kw):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas, **store_kw)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=obj_kb << 10,
                                          max_object_bytes=obj_kb << 13))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table)
    return store, vol, omap, table


# ------------------------------------------------------------ LRU unit
def test_result_cache_lru_byte_bound_and_name_index():
    c = ResultCache(100)
    assert c.put(("a", 1, "p"), "v1", 60) == (0, 60)
    assert c.put(("b", 1, "p"), "v2", 30) == (0, 30)
    assert c.get(("a", 1, "p")) == "v1"  # refresh a -> MRU
    evicted, nb = c.put(("c", 1, "p"), "v3", 40)  # evicts LRU = b
    assert (evicted, nb) == (1, 40)
    assert c.get(("b", 1, "p")) is _MISS
    assert c.get(("a", 1, "p")) == "v1"
    assert c.resident_bytes <= 100
    # over-capacity value refused, cache NOT flushed for it
    assert c.put(("d", 1, "p"), "huge", 101) == (0, 0)
    assert len(c) == 2
    # name index: invalidate drops every entry for that object
    c.put(("a", 2, "q"), "v4", 10)  # evicts c (LRU after a's refresh)
    assert c.get(("c", 1, "p")) is _MISS
    assert c.entries_for("a") == 2
    assert c.invalidate("a") == 2
    assert c.entries_for("a") == 0 and c.get(("a", 1, "p")) is _MISS
    assert len(c) == 0 and c.resident_bytes == 0


def test_result_cache_capacity_zero_disables():
    c = ResultCache(0)
    assert c.put(("a", 1, "p"), "v", 8) == (0, 0)
    assert c.get(("a", 1, "p")) is _MISS and len(c) == 0


# ----------------------------------------------------- serve-side cache
def test_repeat_scan_hits_cache_and_skips_decode_bytes():
    store, vol, omap, table = make_world(cache_bytes=8 << 20)
    scan = vol.scan("t").filter("y", "<", 500).project("x")
    cold, _ = scan.execute()
    assert store.fabric.cache_misses > 0 and store.fabric.cache_hits == 0
    scanned_cold = store.fabric.local_bytes
    warm, _ = scan.execute()
    assert np.array_equal(warm["x"], cold["x"])
    assert store.fabric.cache_hits > 0
    # hits skip the decode entirely: no new OSD-local bytes scanned
    assert store.fabric.local_bytes == scanned_cold
    assert store.fabric.cache_bytes > 0  # admitted payload was metered


def test_cache_disabled_store_serves_identically_with_zero_counters():
    plain = make_world(cache_bytes=0)
    cached = make_world(cache_bytes=8 << 20)
    for _ in range(2):  # repeat: second round hits on the cached store
        for (store, vol, omap, table) in (plain, cached):
            out, _ = vol.scan("t").filter("y", ">=", 100).project(
                "x", "y").execute()
            keep = table["y"] >= 100
            assert np.array_equal(out["x"], table["x"][keep])
            assert np.array_equal(out["y"], table["y"][keep])
    assert plain[0].fabric.cache_hits == 0
    assert plain[0].fabric.cache_misses == 0
    assert plain[0].fabric.cache_bytes == 0
    assert cached[0].fabric.cache_hits > 0


def test_aggregate_and_concat_modes_cache_too():
    store, vol, omap, table = make_world(cache_bytes=8 << 20)
    for _ in range(2):
        r, _ = vol.query(omap, [oc.op("agg", col="x", fn="sum")])
        assert r == pytest.approx(table["x"].sum(), rel=1e-12)
    assert store.fabric.cache_hits > 0
    hits = store.fabric.cache_hits
    for _ in range(2):
        out, _ = vol.scan("t").project("y").execute()
        assert np.array_equal(out["y"], table["y"])
    assert store.fabric.cache_hits > hits


# ------------------------------------------------------------ coherence
def test_version_bump_never_serves_stale_bytes():
    store, vol, omap, table = make_world(cache_bytes=8 << 20)
    scan = vol.scan("t").project("x")
    first, _ = scan.execute()
    assert np.array_equal(first["x"], table["x"])
    # rewrite the dataset in place: every object's version bumps and the
    # write path drops its cache entries eagerly
    table2 = {"x": table["x"] * -2.0 + 1.0, "y": table["y"]}
    vol.write(omap, table2)
    second, _ = scan.execute()
    assert np.array_equal(second["x"], table2["x"])  # zero stale bytes
    third, _ = scan.execute()  # and the NEW version is cached + correct
    assert np.array_equal(third["x"], table2["x"])
    assert store.fabric.cache_hits > 0


def test_scrub_quarantine_invalidates_cached_entries():
    store, vol, omap, table = make_world(cache_bytes=8 << 20)
    vol.scan("t").project("x").execute()  # populate primary caches
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    hit = fi.flip_bits(name, osd_id=store.cluster.locate(name)[0],
                       n_bits=3)
    assert store.osds[hit].cache.entries_for(name) > 0  # stale entry...
    store.scrub()
    # ...dropped with the quarantined copy: the cache never outlives
    # the digest-verified blob its entries were derived from
    assert name in store.osds[hit].quarantine
    assert store.osds[hit].cache.entries_for(name) == 0
    out, _ = vol.scan("t").project("x").execute()
    assert np.array_equal(out["x"], table["x"])


def test_maintenance_rewrites_never_serve_stale_entries():
    """Every maintenance-plane rewrite path — compaction's merged
    object + map rewrite, rebalance's stray drop, GC's delete — must
    eagerly retire cached results (positive AND negative entries)
    rather than wait for version-key misses to age them out."""
    from repro.core import MaintenancePlane
    from repro.core.partition import objmap_key
    store, vol, omap, table = make_world(obj_kb=2, cache_bytes=8 << 20)
    scan = vol.scan("t").project("x")
    first, _ = scan.execute()  # populate caches over the SMALL objects
    assert np.array_equal(first["x"], table["x"])
    assert store.fabric.cache_misses > 0
    plane = MaintenancePlane(
        store, compact_policy=PartitionPolicy(
            target_object_bytes=64 << 10, max_object_bytes=1 << 20),
        gc_retention_s=0.0, gc_confirmed=True)
    # compaction: merged objects + a rewritten .objmap land while the
    # old entries are cached — the scan must re-resolve, bit-exactly
    while plane.compact_step() is not None:
        pass
    assert plane.compact_runs > 0
    mk = objmap_key("t")
    for osd_id in store.cluster.locate(mk):  # map rewrite invalidated
        assert store.osds[osd_id].cache.entries_for(mk) == 0
    out, _ = scan.execute()
    assert np.array_equal(out["x"], table["x"])
    # rebalance after churn: dropped strays take their entries along
    store.add_osds(["osd.s0", "osd.s1"])
    while plane.rebalance_step()["objects"]:
        pass
    for name in vol.open("t").object_names():
        for osd_id in store.cluster.up_osds:
            if osd_id not in store.cluster.locate(name):
                assert store.osds[osd_id].cache.entries_for(name) == 0
    out, _ = scan.execute()
    assert np.array_equal(out["x"], table["x"])
    # GC: collected members leave no cache residue anywhere
    dead = list(plane._dead)
    assert dead
    plane.gc_step()
    for name in dead:
        assert not store.exists(name)
        for osd_id in store.cluster.up_osds:
            assert store.osds[osd_id].cache.entries_for(name) == 0
    out, _ = scan.execute()  # and the serve plane still answers warm
    assert np.array_equal(out["x"], table["x"])
    assert store.fabric.cache_hits > 0
    plane.stop()


def test_lru_byte_bound_holds_under_churn():
    cap = 64 << 10  # far smaller than the dataset's decoded footprint
    store, vol, omap, table = make_world(n=20_000, cache_bytes=cap)
    for lo in range(0, 18_000, 1500):
        vol.scan("t").filter("y", ">=", 0).rows(lo, lo + 2000).project(
            "x", "y").execute()
    assert store.fabric.cache_evictions > 0
    for o, resident in store.stats()["cache_resident_bytes"].items():
        assert resident <= cap, (o, resident)


# ------------------------------------------------------- service queue
def test_modeled_service_queue_meters_wait_under_contention():
    store, vol, omap, table = make_world(cache_bytes=0, scan_bw=50e6)
    scan = vol.scan("t").project("x")
    bar = threading.Barrier(2)

    def client():
        bar.wait()
        out, _ = scan.execute()
        assert np.array_equal(out["x"], table["x"])

    threads = [threading.Thread(target=client) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.fabric.queue_wait_s > 0  # second scan queued behind
    # a cache hit skips the service queue: warm repeats add no wait
    cached = make_world(cache_bytes=8 << 20, scan_bw=50e6)
    cached[1].scan("t").project("x").execute()
    waited = cached[0].fabric.queue_wait_s
    cached[1].scan("t").project("x").execute()
    assert cached[0].fabric.cache_hits > 0
    assert cached[0].fabric.queue_wait_s == waited


# ------------------------------------------------------- single-flight
def test_single_flight_fans_one_execution_out_bit_identically():
    store, vol, omap, table = make_world(cache_bytes=0)
    session = ScanSession(vol, window_s=0.05)
    n = 6
    results = [None] * n
    bar = threading.Barrier(n)

    def client(i):
        bar.wait()
        results[i], _ = session.execute(
            vol.scan("t").filter("y", "<", 700).project("x"))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert session.stats["executed"] == 1
    assert session.stats["deduped"] == n - 1
    expect = table["x"][table["y"] < 700]
    for r in results:
        assert np.array_equal(r["x"], expect)
        # fan-out is by reference: every waiter sees the SAME array
        assert r["x"] is results[0]["x"]


def test_column_coalescing_widens_one_flight_and_slices_back():
    store, vol, omap, table = make_world(cache_bytes=0)
    session = ScanSession(vol, window_s=0.05)
    cols = ("x", "y", "x", "y")
    results = [None] * len(cols)
    bar = threading.Barrier(len(cols))

    def client(i):
        bar.wait()
        results[i], _ = session.execute(
            vol.scan("t").filter("y", ">=", 250).project(cols[i]))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(cols))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert session.stats["executed"] == 1
    assert session.stats["coalesced"] >= 1
    keep = table["y"] >= 250
    for i, c in enumerate(cols):
        assert set(results[i]) == {c}  # exactly the requested columns
        assert np.array_equal(results[i][c], table[c][keep])


def test_session_sequential_scans_do_not_dedup():
    store, vol, omap, table = make_world(cache_bytes=0)
    session = ScanSession(vol)  # no admission window
    for _ in range(3):
        out, _ = session.execute(vol.scan("t").project("y"))
        assert np.array_equal(out["y"], table["y"])
    assert session.stats == {"admitted": 3, "executed": 3, "deduped": 0,
                             "coalesced": 0, "solo": 0}


def test_session_error_fans_out_to_every_waiter():
    store, vol, omap, table = make_world(cache_bytes=0)
    session = ScanSession(vol, window_s=0.05)
    n = 4
    errs = [None] * n
    bar = threading.Barrier(n)

    def client(i):
        bar.wait()
        try:
            session.execute(vol.scan("t").filter("y", "<", 1).project(
                "nope"))
        except Exception as e:  # noqa: BLE001 — capturing for assert
            errs[i] = e

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(e is not None for e in errs)
    assert session.stats["executed"] == 1  # one failure, fanned out
    # the session recovered: the flight was torn down, new scans lead
    out, _ = session.execute(vol.scan("t").project("x"))
    assert np.array_equal(out["x"], table["x"])


# -------------------------------------------------- adaptive put windows
def test_adaptive_windows_bit_exact_and_bounded():
    rng = np.random.default_rng(3)
    # > DEFAULT_WINDOW_BYTES of encoded rows so at least one ingest
    # window fills and triggers a retarget
    n = 1_200_000
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 8192)
    store = make_store(4, replicas=2, client_bw=200e6)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=256 << 10,
                                          max_object_bytes=4 << 20))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table, window_bytes="adaptive")
    traj = store.last_adaptive_windows
    assert traj, "adaptive streaming recorded no retargeted windows"
    assert all(ADAPTIVE_WINDOW_FLOOR <= w <= ADAPTIVE_WINDOW_CAP
               for w in traj)
    out, _ = vol.scan("t").project("x", "y").execute()
    assert np.array_equal(out["x"], table["x"])
    assert np.array_equal(out["y"], table["y"])


def test_adaptive_falls_back_to_static_without_client_bw():
    store, vol, omap, table = make_world(n=8000)  # client_bw unset
    table2 = {"x": table["x"] + 1.0, "y": table["y"]}
    vol.write(omap, table2, window_bytes="adaptive")
    assert store.last_adaptive_windows == ()  # static 8 MB fallback
    assert DEFAULT_WINDOW_BYTES == 8 << 20
    out, _ = vol.scan("t").project("x").execute()
    assert np.array_equal(out["x"], table2["x"])
