"""Self-healing storage plane: digests, scrub/heal, fault injection,
and the deadline/backoff request layer (paper §2 "failure management").
"""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import ckpt
from repro.core import (Column, DataLossError, FaultInjector, GlobalVOL,
                        LogicalDataset, PartitionPolicy, RetryPolicy,
                        RowRange, make_store)
from repro.core import objclass as oc
from repro.core.format import content_digest
from repro.core.store import PartialWriteError


def make_world(n=4000, n_osds=6, replicas=3, seed=0, obj_kb=8, **store_kw):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas, **store_kw)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=obj_kb << 10,
                                          max_object_bytes=obj_kb << 13))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table)
    return store, vol, omap, table


def _copies_all_verify(store, name):
    for osd_id in store.cluster.locate(name):
        osd = store.osds[osd_id]
        assert name in osd.data, (name, osd_id)
        x = osd.xattrs.get(name) or {}
        assert "digest" in x, (name, osd_id)
        assert content_digest(osd.data[name]) == int(x["digest"])


# ------------------------------------------------------- digest substrate
def test_digest_stamped_on_every_write_path_and_hop():
    store, vol, omap, table = make_world()
    # vol.write rode put_batch; every replica of every object (each
    # chain hop forwards blob + xattr together) carries a digest
    for name in omap.object_names():
        _copies_all_verify(store, name)
    # the per-object put path stamps too
    store.put("solo", b"some bytes")
    _copies_all_verify(store, "solo")
    # and the windowed streaming path
    names = [f"w/{i}" for i in range(6)]
    blobs = [bytes([i]) * 2048 for i in range(6)]
    store.put_batch(names, ((b, None) for b in blobs), window_bytes=4096)
    for name in names:
        _copies_all_verify(store, name)


def test_corrupt_primary_read_fails_over_and_is_counted():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    fi.flip_bits(name, osd_id=store.cluster.locate(name)[0], n_bits=5)
    out = vol.read(omap, RowRange(0, 1000))
    assert np.allclose(out["x"], table["x"][:1000])  # zero wrong bytes
    assert store.fabric.corruptions_detected == 1
    # the bad copy is quarantined on its OSD, out of service
    prim = store.cluster.locate(name)[0]
    assert name in store.osds[prim].quarantine
    assert name not in store.osds[prim].data


def test_all_replicas_corrupt_is_loud_data_loss():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    for osd_id in list(store.cluster.locate(name)):
        fi.flip_bits(name, osd_id=osd_id)
    with pytest.raises(DataLossError) as ei:
        store.get(name)
    assert name in ei.value.objects  # the error NAMES the objects


def test_scans_bit_exact_under_replica_corruption():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    # corrupt the PRIMARY copy of several objects: both batched planes
    # (combine for aggregates, concat for table-out) must fail the
    # items over and return bit-exact results
    for name in omap.object_names()[::2]:
        fi.flip_bits(name, osd_id=store.cluster.locate(name)[0])
    r, stats = vol.query(omap, [oc.op("agg", col="y", fn="count")])
    assert r == float(len(table["y"]))
    out = vol.read(omap, RowRange(0, len(table["y"])))
    assert np.array_equal(out["y"], table["y"])
    assert np.allclose(out["x"], table["x"])
    assert store.fabric.corruptions_detected >= len(fi.injected)


# ------------------------------------------------------- retry layer
def test_transient_faults_are_retried_with_backoff():
    store, vol, omap, table = make_world(
        retry=RetryPolicy(attempts=4, base_s=0.0))
    fi = FaultInjector(store)
    victim = store.cluster.primary(omap.object_names()[0])
    fi.transient_failures(victim, 2)  # fail twice, then serve
    r, _ = vol.query(omap, [oc.op("agg", col="y", fn="count")])
    assert r == float(len(table["y"]))
    assert store.fabric.retries >= 2


def test_exhausted_retry_budget_fails_over_to_replica():
    # attempts=1 => no retry at all: the transient is terminal for that
    # replica and the read falls down the acting set
    store, vol, omap, table = make_world(retry=RetryPolicy(attempts=1))
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    fi.transient_failures(store.cluster.locate(name)[0], 50)
    assert store.get(name) is not None
    assert store.fabric.retries == 0


def test_deadline_bounds_retrying():
    p = RetryPolicy(attempts=10, base_s=0.05, cap_s=0.05, deadline_s=0.01)
    # next backoff would cross the deadline immediately
    import time
    assert p.give_up(0, time.perf_counter())
    assert not RetryPolicy(attempts=2).give_up(0, time.perf_counter())
    assert RetryPolicy(attempts=2).give_up(1, time.perf_counter())


def test_slow_osd_degrades_but_stays_correct():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    fi.slow(store.cluster.primary(omap.object_names()[0]), 0.002)
    r, _ = vol.query(omap, [oc.op("agg", col="x", fn="sum")])
    assert r == pytest.approx(table["x"].sum(), rel=1e-12)


# ------------------------------------------------------- scrub / heal
def test_scrub_detects_quarantines_and_heals_bit_rot():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    hit = fi.flip_bits(name, n_bits=3)  # maybe a non-primary replica:
    # no read would ever notice — only scrub finds it proactively
    stats = store.scrub()
    assert stats["corrupt_copies"] == 1
    assert stats["healed_copies"] >= 1
    assert store.fabric.corruptions_detected == 1
    assert store.fabric.heals >= 1
    assert store.fabric.scrub_bytes > 0
    assert name in store.osds[hit].quarantine
    _copies_all_verify(store, name)  # healed through the chain path


def test_torn_write_detected_and_healed():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[1]
    hit = fi.tear_write(name)  # blob landed, xattr (digest) missing
    stats = store.scrub()
    assert stats["corrupt_copies"] == 1
    assert name in store.osds[hit].quarantine
    _copies_all_verify(store, name)
    again = store.scrub()
    assert again["corrupt_copies"] == 0 and again["healed_copies"] == 0


def test_scrub_without_heal_only_quarantines():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    hit = fi.flip_bits(name)
    stats = store.scrub(heal=False)
    assert stats["corrupt_copies"] == 1 and stats["healed_copies"] == 0
    assert name not in store.osds[hit].data
    healed = store.scrub()  # now heal
    assert healed["healed_copies"] >= 1
    _copies_all_verify(store, name)


def test_legacy_undigested_objects_are_reported_not_touched():
    store = make_store(4, replicas=2)
    # a pre-digest write: straight to the OSDs, no digest xattr
    for osd_id in store.cluster.locate("old"):
        store.osds[osd_id].put("old", b"legacy bytes", {"version": 1})
    stats = store.scrub()
    assert "old" in stats["undigested"]
    assert stats["corrupt_copies"] == 0
    assert store.get("old") == b"legacy bytes"  # still served


@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 2)),
                min_size=1, max_size=10, unique=True))
@settings(max_examples=15, deadline=None)
def test_scrub_is_idempotent_under_random_corruption(pattern):
    """Property: whatever (object, replica) set gets corrupted, one
    healing scrub restores every survivor and a second scrub finds
    NOTHING (no corrupt copies, no heals) — scrub converges."""
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    names = omap.object_names()
    for obj_i, rep_i in pattern:
        name = names[obj_i % len(names)]
        acting = store.cluster.locate(name)
        fi.flip_bits(name, osd_id=acting[rep_i % len(acting)])
    first = store.scrub()
    assert first["corrupt_copies"] == len({
        (o % len(names), r) for o, r in pattern})
    second = store.scrub()
    assert second["corrupt_copies"] == 0
    assert second["healed_copies"] == 0
    for name in names:
        _copies_all_verify(store, name)


# ------------------------------------------------------- verified recover
def test_recover_never_propagates_a_corrupt_replica():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    acting = store.cluster.locate(name)
    # corrupt the primary copy, then lose a different replica: recover
    # must source from the surviving VERIFIED copy, never the corrupt one
    fi.flip_bits(name, osd_id=acting[0])
    store.fail_osd(acting[1])
    store.recover()
    for osd_id in store.cluster.locate(name):
        osd = store.osds[osd_id]
        assert content_digest(osd.data[name]) == \
            int(osd.xattrs[name]["digest"])
    out = vol.read(omap, RowRange(0, 500))
    assert np.allclose(out["x"], table["x"][:500])


def test_recover_raises_dataloss_with_names_and_opt_out():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    for osd_id in list(store.cluster.locate(name)):
        fi.flip_bits(name, osd_id=osd_id)  # every replica rotten
    with pytest.raises(DataLossError) as ei:
        store.recover()
    assert name in ei.value.objects
    rec = store.recover(allow_loss=True)
    assert rec["objects_lost"] == 1 and name in rec["lost"]


def test_recover_expected_detects_fully_vanished_objects():
    store = make_store(4, replicas=2)
    store.put("a", b"aaaa")
    # "b" never existed on ANY osd — invisible to list_objects, but the
    # caller's inventory (e.g. an ObjectMap) knows it should
    with pytest.raises(DataLossError) as ei:
        store.recover(expected=["a", "b"])
    assert ei.value.objects == ("b",)


# ------------------------------------------------------- fault campaign
def test_randomized_fault_campaign_live_scans_stay_bit_exact():
    """The acceptance scenario: bit-flips + transient failures + one
    slow OSD + one torn write, all at once.  Live scans return
    bit-exact results (zero wrong bytes), scrub detects 100% of the
    injected corruptions and heals them through the chain path, and a
    second scrub is clean."""
    store, vol, omap, table = make_world(
        n=6000, retry=RetryPolicy(attempts=4, base_s=0.0))
    fi = FaultInjector(store)
    rng = np.random.default_rng(42)
    names = omap.object_names()
    victims = rng.choice(len(names), size=3, replace=False)
    for i in victims:  # bit rot on random replicas of random objects
        acting = store.cluster.locate(names[i])
        fi.flip_bits(names[i],
                     osd_id=acting[int(rng.integers(len(acting)))],
                     n_bits=int(rng.integers(1, 8)))
    torn = names[int(rng.choice(
        [i for i in range(len(names)) if i not in victims]))]
    fi.tear_write(torn)  # one torn write
    slow_osd = store.cluster.up_osds[0]
    fi.slow(slow_osd, 0.001)  # one slow OSD
    for osd_id in store.cluster.up_osds[1:3]:
        fi.transient_failures(osd_id, 2)  # gray failures

    # live scans under the campaign: aggregates, filtered scans, reads
    r, _ = vol.query(omap, [oc.op("agg", col="y", fn="count")])
    assert r == float(len(table["y"]))
    s, _ = (vol.scan("t").filter("y", "<", 500).agg("sum", "x")
            .execute(omap))
    assert s == pytest.approx(table["x"][table["y"] < 500].sum(),
                              rel=1e-12)
    out = vol.read(omap, RowRange(100, 4100))
    assert np.array_equal(out["y"], table["y"][100:4100])
    assert np.allclose(out["x"], table["x"][100:4100])

    fi.clear()  # scrub runs in a quiet window
    detected_before = store.fabric.corruptions_detected
    stats = store.scrub()
    # 100% detection: every injected corruption was found (reads may
    # have caught some first; the counter is cumulative either way)
    assert store.fabric.corruptions_detected == fi.corruptions_injected
    assert stats["lost"] == ()
    second = store.scrub()
    assert second["corrupt_copies"] == 0 and second["healed_copies"] == 0
    for name in names:
        _copies_all_verify(store, name)
    # and the cluster serves bit-exact afterwards, faults healed
    out = vol.read(omap, RowRange(0, len(table["y"])))
    assert np.allclose(out["x"], table["x"])


# ------------------------------------------------------- ckpt reconcile
def test_partial_save_reconciles_to_bit_exact_checkpoint():
    """``save`` killed mid-stream: the PartialWriteError's persisted
    listing is sufficient to delete-and-retry to a bit-exact
    checkpoint, and the torn save is invisible to restore."""
    store = make_store(4, replicas=2)
    state = {"w": np.arange(9000, dtype=np.float64),
             "b": np.linspace(-1, 1, 5000, dtype=np.float32)}
    policy = PartitionPolicy(target_object_bytes=8 << 10,
                             max_object_bytes=64 << 10)

    real_put_batch = store.put_batch

    def killed_put_batch(names, blobs, xattrs=None, **kw):
        it = iter(blobs)  # producer dies after half the sub-writes
        return real_put_batch(
            names, (b for _, b in zip(range(len(names) // 2), it)),
            xattrs, **kw)

    store.put_batch = killed_put_batch
    with pytest.raises(PartialWriteError) as ei:
        ckpt.save(store, state, 1, policy=policy, window_bytes=16 << 10)
    store.put_batch = real_put_batch

    assert ei.value.persisted  # it tells us exactly what landed
    assert ckpt.latest_step(store) is None  # torn save is invisible
    deleted = ckpt.reconcile_partial_save(store, ei.value)
    assert sorted(deleted) == sorted(n for n, _ in ei.value.persisted)
    assert not any(n.startswith("ckpt/") for n in store.list_objects())

    ckpt.save(store, state, 1, policy=policy, window_bytes=16 << 10)
    like = {"w": np.empty_like(state["w"]), "b": np.empty_like(state["b"])}
    restored, manifest = ckpt.restore(store, like)
    assert np.array_equal(restored["w"], state["w"])
    assert np.array_equal(restored["b"], state["b"])
    assert manifest["step"] == 1


# ------------------------------------------------- row-slice refresh
def test_row_sliced_plan_refreshes_names_after_repartition():
    """ROADMAP standing item: an object whose extent GREW into a row
    range after a re-partition is contacted at execute time — the plan
    stamps the ObjectMap version and re-derives its targets when the
    map moved."""
    n = 4000
    rng = np.random.default_rng(3)
    ds = LogicalDataset("rr", (Column("v", "float64"),), n, 64)
    store = make_store(5, replicas=2)
    vol = GlobalVOL(store)
    fine = vol.create(ds, PartitionPolicy(target_object_bytes=4 << 10,
                                          max_object_bytes=4 << 13))
    table = {"v": rng.normal(size=n)}
    vol.write(fine, table)
    assert fine.n_objects > 2

    s = vol.scan("rr").rows(500, 1500).agg("count", "v")
    plan = s.explain(fine)
    assert plan.omap_version == fine.version
    assert len(plan.names) < fine.n_objects  # targeted subset

    # re-partition coarse: obj.000000's extent GROWS to cover the whole
    # range; the fine map's extra objects vanish
    coarse = vol.create(ds, PartitionPolicy(
        target_object_bytes=(n * 8) << 1, max_object_bytes=(n * 8) << 2))
    assert coarse.version > fine.version
    vol.write(coarse, table)
    for name in set(fine.object_names()) - set(coarse.object_names()):
        store.delete(name)  # a real re-partition retires stale objects

    # the OLD compiled plan, executed standalone (no caller-held map):
    # one version probe notices the move and re-derives the targets
    r, stats = vol.engine.execute(plan)
    assert r == 1000.0
    # a fresh hint that matches the current map skips the probe
    plan2 = s.explain(coarse)
    store.fabric.reset()
    r2, _ = vol.engine.execute(plan2, omap=coarse)
    assert r2 == 1000.0
    assert store.fabric.xattr_ops == 0
