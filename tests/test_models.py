"""Per-arch smoke tests + prefill/decode consistency.

Every assigned architecture instantiates its reduced same-family config,
runs one forward/train step on CPU, and asserts output shapes + finite
values.  The decode-equivalence test asserts that prefill(S) followed by
one decode step produces the same logits as prefill(S+1) — the KV-cache/
recurrence correctness invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.archs import build_model
from repro.models.inputs import make_batch
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step


def seq_for(cfg):
    if cfg.ssm is not None:
        return 2 * min(cfg.ssm.chunk, 64)
    return 64


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, seq_for(cfg))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v2_lite_16b",
                                  "rwkv6_3b", "zamba2_2p7b"])
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, remat="full")
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, OptConfig(warmup_steps=2)))
    batch = make_batch(cfg, 2, seq_for(cfg))
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])  # same batch: must drop
    assert int(m2["step"]) == 2


@pytest.mark.parametrize("arch", ["yi_9b", "starcoder2_7b", "granite_20b",
                                  "deepseek_v2_lite_16b", "musicgen_large",
                                  "rwkv6_3b", "zamba2_2p7b"])
def test_prefill_decode_equals_full(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.frontend == "audio_stub":
        pytest.skip("audio stub decodes over token ids, prefill over "
                    "embeds — no shared path to compare")
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(1))
    S = seq_for(cfg)
    batch = make_batch(cfg, 2, S)
    toks = batch["tokens"]
    n_dec = S - S // 2  # prefill half, decode the rest token by token

    logits_full, _ = jax.jit(model.prefill)(
        params, {"tokens": toks})
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": toks[:, :S // 2]})
    pad = {}
    for key in ("k", "v", "ckv", "krope"):
        if key in cache:
            widths = [(0, 0)] * cache[key].ndim
            widths[2] = (0, n_dec)
            pad[key] = jnp.pad(cache[key], widths)
    cache = dict(cache, **pad)
    decode = jax.jit(model.decode_step)
    for t in range(S // 2, S):
        logits, cache = decode(params, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_param_count_formula_close_to_actual():
    for arch in ("yi_9b", "deepseek_v2_lite_16b", "rwkv6_3b"):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.25, \
            (arch, actual, predicted)


def test_moe_routing_load_and_gates():
    cfg = get_config("deepseek_v2_lite_16b", smoke=True)
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 64)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert float(metrics["aux_loss"]) > 0.0  # balance loss is live


def test_int8_kv_decode_close_to_full_precision():
    """kvint8 serving variant: logits stay within ~2% after a run of
    decode steps (per-token/head symmetric quantization)."""
    import repro.models.transformer as T

    cfg = get_config("yi_9b", smoke=True)
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(1))
    toks = make_batch(cfg, 2, 64)["tokens"]

    def run(quant: bool):
        T.KV_CACHE_QUANT = quant
        try:
            m = build_model(cfg, remat="none")
            logits, cache = jax.jit(m.prefill)(params,
                                               {"tokens": toks[:, :32]})
            pad = {}
            for key, v in cache.items():
                if key == "pos":
                    continue
                widths = [(0, 0)] * v.ndim
                widths[2] = (0, 16)
                pad[key] = jnp.pad(v, widths)
            cache = dict(cache, **pad)
            dec = jax.jit(m.decode_step)
            for t in range(32, 44):
                logits, cache = dec(params, toks[:, t:t + 1], cache)
            return np.asarray(logits)
        finally:
            T.KV_CACHE_QUANT = False

    ref = run(False)
    q8 = run(True)
    rel = np.abs(q8 - ref).max() / max(np.abs(ref).max(), 1e-6)
    assert rel < 0.05, rel
