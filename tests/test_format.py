"""Block format / codec properties."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import format as fmt
from repro.core import objclass as oc


@given(st.integers(1, 24), st.integers(0, 2000))
@settings(max_examples=30, deadline=None)
def test_bitpack_roundtrip(bits, n):
    rng = np.random.default_rng(bits * 1000 + n)
    v = rng.integers(0, 1 << bits, n).astype(np.uint32)
    words = fmt.bitpack_encode(v, bits)
    assert words.shape == (-(-n // 32) if n else 0, bits)
    out = fmt.bitpack_decode(words, bits, n)
    assert np.array_equal(out, v)


def test_bitpack_rejects_overflow():
    with pytest.raises(ValueError):
        fmt.bitpack_encode(np.array([8], np.uint32), 3)


@given(st.sampled_from(["none", "zlib", "bitpack12"]),
       st.integers(1, 300))
@settings(max_examples=20, deadline=None)
def test_block_roundtrip_col(codec, n):
    rng = np.random.default_rng(n)
    table = {
        "a": rng.integers(0, 4000, n).astype(np.int32),
        "b": rng.normal(size=(n, 3)).astype(np.float32),
    }
    codecs = {"a": codec} if codec != "none" else {}
    blob = fmt.encode_block(table, codecs=codecs)
    out = fmt.decode_block(blob)
    assert np.array_equal(out["a"], table["a"])
    assert np.allclose(out["b"], table["b"])


def test_block_projection_reads_subset():
    table = {"x": np.arange(10, dtype=np.int64),
             "y": np.ones((10, 2), np.float32)}
    blob = fmt.encode_block(table)
    out = fmt.decode_block(blob, columns=["y"])
    assert set(out) == {"y"}
    with pytest.raises(KeyError):
        fmt.decode_block(blob, columns=["nope"])


def test_layout_transform_roundtrip():
    rng = np.random.default_rng(0)
    table = {"x": rng.integers(0, 100, 50).astype(np.int32),
             "y": rng.normal(size=50)}
    col = fmt.encode_block(table, layout="col")
    row = fmt.transform_layout(col, "row")
    assert fmt.block_header(row)["layout"] == "row"
    back = fmt.transform_layout(row, "col")
    out = fmt.decode_block(back)
    assert np.array_equal(out["x"], table["x"])
    assert np.allclose(out["y"], table["y"])


def test_zone_map_in_header():
    blob = fmt.encode_block({"v": np.array([3.0, -1.0, 7.0])})
    zm = fmt.block_header(blob)["zone_map"]
    assert zm["v"] == [-1.0, 7.0]


def test_select_packed_zero_decode_equals_decoded_select():
    rng = np.random.default_rng(1)
    S, n = 64, 20
    toks = rng.integers(0, 5000, (n, S)).astype(np.int32)
    blob = fmt.encode_block({"tokens": toks},
                            codecs={"tokens": "bitpack13"})
    res = oc.select_packed(blob, rows=(5, 12), col="tokens")
    assert res["packed"].shape == (7, S // 32, 13)
    dec = fmt.bitpack_decode(res["packed"].reshape(-1, 13), 13,
                             7 * S).reshape(7, S)
    assert np.array_equal(dec.astype(np.int32), toks[5:12])
