"""The verification plane verified: every invariant pass must FIRE on
a seeded violation and stay quiet on a clean module.

The AST passes run over tiny fixture trees written to tmp_path (shaped
like ``src/repro/core/<mod>.py`` so the walker picks them up); the
registry pass runs with injected declaration tables; the lockcheck
harness is driven directly with hand-built lock graphs and finally as
a full ``install()`` over a real store.
"""

import textwrap
import threading

import pytest

from repro.analysis import invariants, lockcheck, registry
from repro.analysis.base import (SuppressionError, apply_suppressions,
                                 load_suppressions)
from repro.analysis.cli import main as analysis_main

# --------------------------------------------------------------------------
# fixture trees
# --------------------------------------------------------------------------


def _tree(tmp_path, source: str, name: str = "storeish.py"):
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True, exist_ok=True)
    (core / name).write_text(textwrap.dedent(source))
    return tmp_path


VIOLATIONS = """\
    import threading
    import time


    class Fabric:
        ops: int = 0
        scrub_bytes: int = 0


    class OSD:
        _GUARDED_BY = {"data": "lock"}

        def __init__(self):
            self.lock = threading.Lock()
            self.data = {}
            self.cache = None

        def read_bad(self, name):
            return self.data[name]          # unguarded read

        def read_good(self, name):
            with self.lock:
                return self.data[name]

        def sleepy(self):
            with self.lock:
                time.sleep(0.1)             # blocking while locked

        def rot(self, name):
            with self.lock:
                self.data[name] = b""       # rewrite, no invalidation


    class ObjectStore:
        def __init__(self):
            self.fabric = Fabric()
            self._pool = None
            self._versions = {}

        def _next_version(self, name):
            v = self._versions.get(name, 0) + 1
            self._versions[name] = v
            return v

        def kickoff(self):
            def worker():
                self.fabric.ops += 1        # submit root hits counter
            self._pool.submit(worker)

        def start_daemon(self):
            threading.Thread(target=self._loop).start()

        def _loop(self):
            self.fabric.ops += 1            # client-owned, off-thread
            self.fabric.scrub_bytes += 1    # daemon-owned: allowed

        def half_write(self, name):
            self._versions[name] = self._next_version(name)
"""

CLEAN = """\
    import threading


    class Fabric:
        ops: int = 0


    class OSD:
        _GUARDED_BY = {"data": "lock"}

        def __init__(self):
            self.lock = threading.Lock()
            self.data = {}
            self.cache = None

        def get(self, name):
            with self.lock:
                return self.data[name]

        def put(self, name, blob, version):
            digest = content_digest(blob)
            with self.lock:
                self.data[name] = blob
            self.cache.invalidate(name)
            return digest


    class ObjectStore:
        def __init__(self):
            self.fabric = Fabric()
            self._versions = {}

        def _next_version(self, name):
            v = self._versions.get(name, 0) + 1
            self._versions[name] = v
            return v

        def put(self, osd, name, blob):
            self.fabric.ops += 1            # caller thread: fine
            return osd.put(name, blob, self._next_version(name))
"""


def _rules(findings, rule):
    return [f for f in findings if f.rule == rule]


# --------------------------------------------------------------------------
# AST passes fire on seeded violations
# --------------------------------------------------------------------------


class TestSeededViolations:
    @pytest.fixture(scope="class")
    def findings(self, tmp_path_factory):
        root = _tree(tmp_path_factory.mktemp("bad"), VIOLATIONS)
        return invariants.analyze(root)

    def test_accounting_submit_root_fires(self, findings):
        hits = _rules(findings, "accounting")
        assert any("kickoff.worker" in f.qualname
                   and "Fabric.ops" in f.message for f in hits)

    def test_accounting_thread_root_fires(self, findings):
        hits = _rules(findings, "accounting")
        assert any(f.qualname == "ObjectStore._loop"
                   and "Fabric.ops" in f.message for f in hits)

    def test_accounting_daemon_counter_exempt(self, findings):
        assert not any("scrub_bytes" in f.message
                       for f in _rules(findings, "accounting"))

    def test_lock_guard_fires(self, findings):
        hits = _rules(findings, "lock-guard")
        assert [f.qualname for f in hits] == ["OSD.read_bad"]

    def test_lock_blocking_fires(self, findings):
        hits = _rules(findings, "lock-blocking")
        assert [f.qualname for f in hits] == ["OSD.sleepy"]
        assert "time.sleep" in hits[0].message

    def test_write_path_d1_fires(self, findings):
        hits = _rules(findings, "write-path")
        assert any(f.qualname == "OSD.rot"
                   and "invalidation" in f.message for f in hits)

    def test_write_path_d2_fires(self, findings):
        hits = _rules(findings, "write-path")
        assert any(f.qualname == "ObjectStore.half_write"
                   and "content_digest" in f.message for f in hits)

    def test_clean_module_is_quiet(self, tmp_path):
        root = _tree(tmp_path, CLEAN)
        assert invariants.analyze(root) == []


# --------------------------------------------------------------------------
# registry pass (injected tables)
# --------------------------------------------------------------------------


class TestRegistryPass:
    def test_missing_rep_params(self):
        hits = registry.check_registry(reps={}, ops=("select",))
        assert any("representative params" in f.message for f in hits)

    def test_undeclared_not_mergeable(self):
        hits = registry.check_registry(
            ops=("median",), not_mergeable=frozenset())
        assert any("KNOWN_NOT_MERGEABLE" in f.message
                   and f.qualname == "op:median" for f in hits)

    def test_stale_not_mergeable_declaration(self):
        hits = registry.check_registry(
            ops=("agg",),
            not_mergeable=frozenset({"agg"}))
        assert any("stale" in f.message and f.qualname == "op:agg"
                   for f in hits)

    def test_undeclared_col_conservative(self):
        hits = registry.check_registry(
            ops=("recompress",), col_conservative=frozenset())
        assert any("KNOWN_COL_CONSERVATIVE" in f.message
                   for f in hits)

    def test_real_registry_is_fully_declared(self):
        assert registry.check_registry() == []


# --------------------------------------------------------------------------
# suppression machinery
# --------------------------------------------------------------------------


class TestSuppressions:
    def test_justification_required(self, tmp_path):
        p = tmp_path / "s.txt"
        p.write_text("lock-guard cache.py:ResultCache._evict_lru\n")
        with pytest.raises(SuppressionError):
            load_suppressions(p)

    def test_match_and_stale(self, tmp_path):
        from repro.analysis.base import Finding
        p = tmp_path / "s.txt"
        p.write_text(
            "lock-guard x.py:A.f -- caller holds the lock\n"
            "accounting y.py:B.g -- never matches\n")
        supps = load_suppressions(p)
        f = Finding("lock-guard", "src/x.py", 3, "A.f", "m")
        active, quiet, unused = apply_suppressions([f], supps)
        assert active == [] and quiet == [f]
        assert [s.key for s in unused] == ["accounting y.py:B.g"]


# --------------------------------------------------------------------------
# dynamic lockcheck harness
# --------------------------------------------------------------------------


class TestLockCheck:
    def test_cycle_detected(self):
        st = lockcheck.LockCheckState()
        a = lockcheck.InstrumentedLock("A", st)
        b = lockcheck.InstrumentedLock("B", st)
        with a:
            with b:
                pass
        with b:
            with a:                 # inverted order: A<->B cycle
                pass
        assert st.cycles() == [["A", "B"]]
        assert not st.report()["ok"]

    def test_same_name_self_edge_is_cycle(self):
        st = lockcheck.LockCheckState()
        a1 = lockcheck.InstrumentedLock("OSD.lock", st)
        a2 = lockcheck.InstrumentedLock("OSD.lock", st)
        with a1:
            with a2:                # two instances of the same lock
                pass
        assert st.cycles() == [["OSD.lock"]]

    def test_consistent_order_is_clean(self):
        st = lockcheck.LockCheckState()
        a = lockcheck.InstrumentedLock("A", st)
        b = lockcheck.InstrumentedLock("B", st)
        for _ in range(3):
            with a:
                with b:
                    pass
        assert st.cycles() == []
        assert st.report()["ok"]

    def test_guarded_mutation_without_lock_flagged(self):
        st = lockcheck.LockCheckState()
        owner = lockcheck.InstrumentedLock("C._lock", st)
        d = lockcheck._wrap_container({}, "C.table", owner, st)
        d["k"] = 1                  # mutation, lock not held
        assert any("C.table" in v for v in st.report()["violations"])

    def test_guarded_mutation_under_lock_clean(self):
        st = lockcheck.LockCheckState()
        owner = lockcheck.InstrumentedLock("C._lock", st)
        d = lockcheck._wrap_container({}, "C.table", owner, st)
        with owner:
            d["k"] = 1
            d.pop("k")
        assert st.report()["violations"] == []
        assert d == {}

    def test_cross_thread_order_edges_merge(self):
        st = lockcheck.LockCheckState()
        a = lockcheck.InstrumentedLock("A", st)
        b = lockcheck.InstrumentedLock("B", st)

        def t1():
            with a:
                with b:
                    pass

        def t2():
            with b:
                with a:
                    pass

        th1 = threading.Thread(target=t1)
        th1.start()
        th1.join()
        th2 = threading.Thread(target=t2)
        th2.start()
        th2.join()
        assert st.cycles() == [["A", "B"]]

    def test_install_over_real_store(self):
        st = lockcheck.install()
        try:
            from repro.core.store import make_store
            store = make_store(3, replicas=2, cache_bytes=1 << 20)
            store.put("obj/0", b"x" * 512)
            assert store.get("obj/0") == b"x" * 512
            store.delete("obj/0")
        finally:
            lockcheck.uninstall(st)
        rep = st.report()
        assert rep["locks_instrumented"] > 0
        assert rep["containers_instrumented"] > 0
        assert rep["acquisitions"] > 0
        assert rep["ok"], rep


# --------------------------------------------------------------------------
# the repo itself is clean (same check CI runs)
# --------------------------------------------------------------------------


def test_repo_baseline_clean(capsys):
    assert analysis_main([]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out
    assert "0 stale" in out
