"""Online maintenance plane: continuous scrub walker, small-object
compaction, live rebalance, versioned GC — plus the satellite pieces
(decorrelated retry jitter, DataLossError copy census, maintenance
rate limiting).
"""

import time

import numpy as np
import pytest

from repro.core import (Column, DataLossError, FaultInjector, GlobalVOL,
                        LogicalDataset, MaintenancePlane, PartitionPolicy,
                        RetryPolicy, RowRange, TokenBucket, make_store)
from repro.core import objclass as oc
from repro.core.format import content_digest
from repro.core.partition import (ObjectMap, PartitionPolicy as PP,
                                  compact_plan, merge_run, objmap_key)


def make_world(n=4096, n_osds=6, replicas=3, seed=0, unit_rows=64,
               obj_kb=8, name="t", **store_kw):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        name, (Column("x", "float64"), Column("y", "int32")), n, unit_rows)
    store = make_store(n_osds, replicas=replicas, **store_kw)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=obj_kb << 10,
                                          max_object_bytes=obj_kb << 13))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table)
    return store, vol, omap, table


def make_tiny_append_world(n=4096, unit_rows=32, n_osds=6, replicas=3,
                           seed=1, name="ck"):
    """The one-blob-per-append pattern: every unit lands as its own tiny
    object (ckpt/kvcache streams), leaving a map full of under-target
    extents — compaction's whole reason to exist."""
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(name, (Column("v", "float64"),), n, unit_rows)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    # target below one unit's bytes => one object per append
    omap = vol.create(ds, PartitionPolicy(
        target_object_bytes=unit_rows * 8, max_object_bytes=1 << 20))
    table = {"v": rng.normal(size=n)}
    vol.write(omap, table)
    return store, vol, omap, table


def _copies_all_verify(store, name):
    for osd_id in store.cluster.locate(name):
        osd = store.osds[osd_id]
        assert name in osd.data, (name, osd_id)
        x = osd.xattrs.get(name) or {}
        assert "digest" in x, (name, osd_id)
        assert content_digest(osd.data[name]) == int(x["digest"])


def _wait_for(cond, timeout_s=10.0, what="condition"):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if cond():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {what}")


# ===================================================== scrub walker
def test_walker_heals_and_is_idempotent_without_ondemand_scrub():
    """The WALKER (not on-demand scrub()) finds, quarantines, and heals
    injected rot; after its rounds a verifying scrub() finds nothing —
    scrub-idempotence holds when the background path does the work."""
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    names = omap.object_names()
    hits = [fi.flip_bits(names[0], n_bits=3), fi.tear_write(names[1])]
    plane = MaintenancePlane(store, batch_objects=4)
    # two full synchronous rounds of the walker
    for _ in range(2):
        plane._scrub_cursor = ""
        while plane.scrub_step()["objects"]:
            pass
    assert plane.scrub_corrupt == 2
    assert plane.scrub_healed >= 2
    assert store.fabric.corruptions_detected == fi.corruptions_injected
    for name, hit in zip(names[:2], hits):
        assert name in store.osds[hit].quarantine
        _copies_all_verify(store, name)
    after = store.scrub()  # on-demand verify pass: nothing left to do
    assert after["corrupt_copies"] == 0 and after["healed_copies"] == 0
    out = vol.read(omap, RowRange(0, len(table["x"])))
    assert np.allclose(out["x"], table["x"])
    plane.stop()


def test_walker_pause_resume_survives_topology_churn():
    """Pause the running walker, churn the topology (fail_osd +
    add_osds + recover), resume — the walker finishes the round against
    the NEW inventory and heals damage injected after the churn."""
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    plane = MaintenancePlane(store, batch_objects=2, interval_s=0.0005)
    plane.start(daemons=("scrub",))
    _wait_for(lambda: plane.scrub_objects > 0, what="walker progress")
    plane.pause()
    busy = plane.scrub_objects
    time.sleep(0.02)
    assert plane.scrub_objects <= busy + plane.batch_objects  # parked
    # topology churn while paused
    victim = store.cluster.up_osds[0]
    store.fail_osd(victim)
    store.add_osds(["osd.new0", "osd.new1"])
    store.recover()
    name = omap.object_names()[2]
    fi.flip_bits(name, n_bits=2)
    paused_progress = plane.scrub_objects
    time.sleep(0.02)
    assert plane.scrub_objects == paused_progress  # still parked
    plane.resume()
    _wait_for(lambda: plane.scrub_rounds >= 2 and plane.scrub_corrupt >= 1,
              what="post-churn walker rounds")
    plane.stop()
    assert store.fabric.corruptions_detected == fi.corruptions_injected
    _copies_all_verify(store, name)
    out = vol.read(omap, RowRange(0, 1000))
    assert np.allclose(out["x"], table["x"][:1000])


def test_walker_rate_limit_bounds_scrub_throughput():
    store, vol, omap, table = make_world(n=2048)
    # inventory is ~tens of KB; a 1 MB/s budget forces measurable sleep
    plane = MaintenancePlane(store, scrub_rate_bytes_s=1e6,
                             batch_objects=64)
    t0 = time.monotonic()
    plane._scrub_cursor = ""
    total = 0
    while True:
        got = plane.scrub_step()
        if not got["objects"]:
            break
        total += got["objects"]
    elapsed = time.monotonic() - t0
    scrubbed = store.fabric.scrub_bytes
    assert total > 0 and scrubbed > 0
    # bucket grants one rate-second of burst, the rest is paid in sleep
    assert elapsed >= (scrubbed - 1e6) / 1e6 - 0.05
    plane.stop()


# ===================================================== compaction
def test_compaction_reduces_object_count_4x_and_stays_bit_exact():
    store, vol, omap, table = make_tiny_append_world()
    n_before = omap.n_objects
    assert n_before >= 64  # genuinely tiny-append shaped
    plane = MaintenancePlane(
        store, compact_policy=PP(target_object_bytes=64 << 10,
                                 max_object_bytes=1 << 20))
    # compile a plan against the OLD map before compacting
    scan = vol.scan("ck").rows(100, 2100).agg("sum", "v")
    old_plan = scan.explain(omap)
    assert old_plan.omap_version == omap.version
    runs = 0
    while plane.compact_step() is not None:
        runs += 1
    assert runs > 0 and plane.compact_runs == runs
    assert store.fabric.compactions == runs
    assert store.fabric.compaction_bytes > 0
    fresh = vol.open("ck")
    assert fresh.n_objects * 4 <= n_before  # >= 4x fewer objects
    assert fresh.version > omap.version     # the map version bumped
    # merged objects verify and carry global row extents + zone maps
    for e in fresh:
        if "/cmp." not in e.name:
            continue
        prim = store.cluster.locate(e.name)[0]
        x = store.osds[prim].xattrs[e.name]
        assert x["rows"] == [e.row_start, e.row_stop]
        assert "zone_map" in x
        _copies_all_verify(store, e.name)
    # the OLD compiled plan re-targets through _refresh, bit-exactly
    want = float(table["v"][100:2100].sum())
    got, _ = vol.engine.execute(old_plan)
    assert got == pytest.approx(want, rel=1e-12)
    # and fresh scans over the compacted map agree
    out = vol.read(fresh, RowRange(0, len(table["v"])))
    assert np.allclose(out["v"], table["v"])
    plane.stop()


def test_compaction_members_survive_until_gc_then_collect():
    """Versioned GC: replaced members stay servable through the
    retention window (in-flight plans may still target them), are NOT
    collected before the operator confirms, and vanish after."""
    store, vol, omap, table = make_tiny_append_world(n=1024)
    plane = MaintenancePlane(
        store, compact_policy=PP(target_object_bytes=32 << 10,
                                 max_object_bytes=1 << 20),
        gc_retention_s=0.05)
    members = []
    while True:
        got = plane.compact_step()
        if got is None:
            break
        members.extend(got["members"])
    assert members
    for m in members:  # retained: old copies still in service
        assert store.exists(m)
    plane.gc_step()  # not confirmed: ages the ledger, deletes nothing
    assert all(store.exists(m) for m in members)
    assert store.fabric.gc_objects == 0
    plane.confirm_gc()
    plane.gc_step()  # confirmed but not yet ripe
    assert all(store.exists(m) for m in members)
    time.sleep(0.06)
    got = plane.gc_step()
    assert got["dead_reclaimed"] == len(members)
    assert not any(store.exists(m) for m in members)
    assert store.fabric.gc_objects == len(members)
    assert store.fabric.gc_bytes > 0
    # post-GC scans over the compacted map are still bit-exact
    out = vol.read(vol.open("ck"), RowRange(0, len(table["v"])))
    assert np.allclose(out["v"], table["v"])
    plane.stop()


def test_compact_plan_and_merge_run_unit():
    ds = LogicalDataset("d", (Column("v", "float64"),), 100, 10)
    ext = [("d/obj.%06d" % i, i * 10, (i + 1) * 10) for i in range(10)]
    omap = ObjectMap(ds, tuple(
        __import__("repro.core.partition", fromlist=["ObjectExtent"])
        .ObjectExtent(n, a, b) for n, a, b in ext))
    pol = PP(target_object_bytes=300, max_object_bytes=500)
    sizes = {e.name: 100 for e in omap.extents}
    sizes.pop("d/obj.000004")          # absent member breaks the run
    sizes["d/obj.000007"] = 900        # oversized member breaks it too
    runs = compact_plan(omap, sizes, pol)
    assert runs == [(0, 3), (5, 7), (8, 10)]  # greedy, stop at target
    merged = merge_run(omap, 0, 3, "d/cmp.1")
    assert merged.n_objects == 8
    assert merged.extents[0].name == "d/cmp.1"
    assert (merged.extents[0].row_start, merged.extents[0].row_stop) \
        == (0, 30)
    with pytest.raises(ValueError):
        merge_run(omap, 3, 4, "d/cmp.2")  # a 1-run is not a merge


# ===================================================== live rebalance
def test_rebalance_moves_objects_to_fresh_placement_verified():
    store, vol, omap, table = make_world(n_osds=4, replicas=2)
    store.add_osds([f"osd.n{i}" for i in range(3)])  # placement shifts
    plane = MaintenancePlane(store, batch_objects=16)
    while plane.rebalance_step()["objects"]:
        pass
    assert store.fabric.rebalance_bytes > 0
    for name in omap.object_names() + [objmap_key("t")]:
        acting = set(store.cluster.locate(name))
        for osd_id in store.cluster.up_osds:
            osd = store.osds[osd_id]
            if osd_id in acting:   # every acting copy present+verified
                assert name in osd.data
                assert content_digest(osd.data[name]) == \
                    int(osd.xattrs[name]["digest"])
            else:                  # every stray dropped
                assert name not in osd.data
    # steady state: peering finds nothing left to move, scrub is clean
    rec = store.recover()
    assert rec["objects_moved"] == 0 and rec["lost"] == ()
    assert store.scrub()["corrupt_copies"] == 0
    out = vol.read(omap, RowRange(0, len(table["x"])))
    assert np.allclose(out["x"], table["x"])
    plane.stop()


def test_rebalance_keeps_old_copy_until_new_copy_lands():
    """Verify-before-drop: while the target OSD refuses the new copy,
    the stray (old-placement) copy is retained — a crashed move never
    reduces the number of good copies."""
    store = make_store(3, replicas=1, retry=RetryPolicy(attempts=2))
    names = [f"mv{i}" for i in range(16)]
    olds = {}
    for n in names:
        store.put(n, b"payload" * 100)
        olds[n] = store.cluster.primary(n)
    store.add_osds(["osd.z0", "osd.z1", "osd.z2"])
    moved_names = [n for n in names
                   if store.cluster.primary(n) != olds[n]]
    assert moved_names  # 16 names, 2x the OSDs: some placement moved
    name, holder = moved_names[0], olds[moved_names[0]]
    target = store.cluster.primary(name)
    fi = FaultInjector(store)
    fi.transient_failures(target, 1000)  # the new home refuses copies
    plane = MaintenancePlane(store)
    moved = store.rebalance_object(name)
    assert moved == 0
    assert name in store.osds[holder].data  # old copy retained
    fi.clear()
    store.rebalance_object(name)
    assert name in store.osds[target].data
    assert name not in store.osds[holder].data  # stray dropped AFTER
    assert store.get(name) == b"payload" * 100
    plane.stop()


# ===================================================== versioned GC
def test_gc_never_collects_sole_quarantined_copy():
    store, vol, omap, table = make_world(n_osds=4, replicas=2)
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    for osd_id in list(store.cluster.locate(name)):
        fi.flip_bits(name, osd_id=osd_id)  # EVERY replica rotten
    store.scrub(heal=False)  # all copies quarantined, none verified
    quarantined = [o for o in store.cluster.up_osds
                   if name in store.osds[o].quarantine]
    assert quarantined
    plane = MaintenancePlane(store, gc_retention_s=0.0,
                             gc_confirmed=True)
    plane.gc_step()  # ages the quarantine ledger
    time.sleep(0.01)
    plane.gc_step()
    # the quarantined copies are the only evidence left: kept
    for o in quarantined:
        assert name in store.osds[o].quarantine
    plane.stop()


def test_gc_purges_quarantined_copy_once_verified_copy_exists():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    hit = fi.flip_bits(name)
    store.scrub()  # quarantines the bad copy AND heals a fresh one
    assert name in store.osds[hit].quarantine
    plane = MaintenancePlane(store, gc_retention_s=0.02,
                             gc_confirmed=True)
    plane.gc_step()  # first sight: starts the retention clock
    assert name in store.osds[hit].quarantine
    time.sleep(0.03)
    got = plane.gc_step()
    assert got["quarantine_purged"] == 1
    assert name not in store.osds[hit].quarantine
    assert store.fabric.gc_bytes > 0
    _copies_all_verify(store, name)  # the live object is untouched
    plane.stop()


# ===================================================== retry jitter
def test_decorrelated_jitter_schedules_bounded_and_distinct():
    p = RetryPolicy(attempts=8, base_s=0.001, cap_s=0.05,
                    jitter="decorrelated", seed=7)
    schedules = [p.schedule(8, salt=s) for s in range(6)]
    for sched in schedules:  # bounded: base <= sleep <= cap, always
        assert all(p.base_s <= s <= p.cap_s for s in sched)
    # non-synchronized: different waiters do NOT share a schedule
    distinct = {tuple(s) for s in schedules}
    assert len(distinct) == len(schedules)
    # reproducible: same (seed, salt) -> same schedule
    assert p.schedule(8, salt=3) == schedules[3]
    # a different seed decorrelates the whole fleet
    q = RetryPolicy(attempts=8, base_s=0.001, cap_s=0.05,
                    jitter="decorrelated", seed=8)
    assert q.schedule(8, salt=0) != schedules[0]


def test_jitter_none_keeps_deterministic_exponential():
    p = RetryPolicy(attempts=5, base_s=0.002, cap_s=0.1)
    assert p.schedule(5) == [p.backoff_s(k) for k in range(5)]
    assert p.schedule(5, salt=9) == p.schedule(5, salt=0)


def test_jittered_policy_still_respects_deadline_and_retries():
    # give_up budgets against the un-jittered curve: deterministic
    p = RetryPolicy(attempts=10, base_s=0.05, cap_s=0.05,
                    deadline_s=0.01, jitter="decorrelated", seed=1)
    assert p.give_up(0, time.perf_counter())
    # and a store under transient faults retries fine with jitter on
    store, vol, omap, table = make_world(
        n=1024, retry=RetryPolicy(attempts=4, base_s=0.0,
                                  jitter="decorrelated", seed=3))
    fi = FaultInjector(store)
    fi.transient_failures(store.cluster.primary(omap.object_names()[0]), 2)
    r, _ = vol.query(omap, [oc.op("agg", col="y", fn="count")])
    assert r == float(len(table["y"]))
    assert store.fabric.retries >= 2


# ===================================================== copy census
def test_dataloss_error_carries_copy_census():
    store, vol, omap, table = make_world(n_osds=4, replicas=2)
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    acting = list(store.cluster.locate(name))
    for osd_id in acting:
        fi.flip_bits(name, osd_id=osd_id)
    with pytest.raises(DataLossError) as ei:
        store.get(name)
    census = ei.value.census
    assert name in census
    c = census[name]
    assert c["verified"] == []  # nothing serveable — that's the loss
    # every copy is accounted somewhere: quarantined by the failed
    # reads, or still divergent in place
    assert set(c["quarantined"]) | set(c["divergent"]) == set(acting)
    assert set(census[name]) == {"verified", "divergent", "bare",
                                 "quarantined"}


def test_recover_census_names_surviving_copy_locations():
    store, vol, omap, table = make_world()
    fi = FaultInjector(store)
    name = omap.object_names()[0]
    for osd_id in list(store.cluster.locate(name)):
        fi.flip_bits(name, osd_id=osd_id)
    with pytest.raises(DataLossError) as ei:
        store.recover()
    c = ei.value.census[name]
    assert c["verified"] == []
    assert len(c["quarantined"]) + len(c["divergent"]) >= 1
    # an unrelated healthy object censuses as fully verified
    other = omap.object_names()[1]
    healthy = store.copy_census([other])[other]
    assert set(healthy["verified"]) == set(store.cluster.locate(other))
    assert healthy["divergent"] == [] and healthy["quarantined"] == []


# ===================================================== rate limiter
def test_token_bucket_meters_and_disables():
    free = TokenBucket(None)
    assert free.consume(10**9) == 0.0
    tb = TokenBucket(1e6)           # 1 MB/s, 1 MB burst
    assert tb.consume(1 << 19) == 0.0   # within the burst: free
    waited = tb.consume(1 << 20)        # now in deficit: must sleep
    assert waited > 0.0


# ===================================================== all four at once
def test_all_four_daemons_against_live_faults_and_churn():
    """The tentpole scenario at test scale: all four daemons run as
    threads while faults land and the topology changes; afterwards the
    cluster is compacted, healed, rebalanced, GC'd — and bit-exact."""
    store, vol, omap, table = make_tiny_append_world(n=2048)
    fi = FaultInjector(store)
    plane = MaintenancePlane(
        store, compact_policy=PP(target_object_bytes=32 << 10,
                                 max_object_bytes=1 << 20),
        gc_retention_s=0.05, gc_confirmed=True,
        batch_objects=16, interval_s=0.0005)
    n_before = omap.n_objects
    plane.start()
    store.add_osds(["osd.x0"])
    _wait_for(lambda: plane.compact_runs > 0, what="compaction")
    prev = -1  # let compaction settle so the campaign hits objects
    while plane.compact_runs != prev:  # that stay in the live map
        prev = plane.compact_runs
        time.sleep(0.2)
    placed = fi.campaign(vol.open("ck").object_names(),
                         flips=3, torn=1, seed=2)
    assert placed
    _wait_for(lambda: store.fabric.corruptions_detected
              == fi.corruptions_injected, what="walker detection")
    _wait_for(lambda: plane.gc_reclaimed > 0, timeout_s=20,
              what="gc reclaim")
    plane.pause()
    time.sleep(0.01)
    fresh = vol.open("ck")
    assert fresh.n_objects * 4 <= n_before
    out = vol.read(fresh, RowRange(0, len(table["v"])))
    assert np.allclose(out["v"], table["v"])
    plane.stop()
    assert store.fabric.corruptions_detected == fi.corruptions_injected
    final = store.scrub()
    assert final["corrupt_copies"] == 0 and final["lost"] == ()
