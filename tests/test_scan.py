"""Composable scan surface: builder→PhysicalPlan→engine, pushed-down
OSD pruning vs client pruning, server-side table concat, and the
unified stats emission.  Example-based on purpose: must run without
hypothesis."""

import numpy as np
import pytest

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        Query, RowRange, Scan, SkyhookDriver, make_store)
from repro.core import expr as ex
from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core import scan as sc


def make_world(n=4000, n_osds=5, replicas=3, seed=0):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 10,
                                          max_object_bytes=8 << 12))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table)
    return store, vol, omap, table


IMPOSSIBLE = [oc.op("filter", col="y", cmp=">", value=2000),
              oc.op("agg", col="x", fn="count")]


# ---------------------------------------------------------------- builder
def test_builder_is_immutable_and_composable():
    store, vol, omap, table = make_world()
    base = vol.scan("t").filter("y", "<", 500)
    a = base.agg("sum", "x")
    b = base.project("x")
    assert base.aggregates == () and base.projection is None  # untouched
    ra, _ = a.execute()
    rb, _ = b.execute()
    mask = table["y"] < 500
    assert ra == pytest.approx(table["x"][mask].sum(), rel=1e-12)
    assert np.array_equal(rb["x"], table["x"][mask])


def test_builder_multi_filter_conjunction():
    store, vol, omap, table = make_world()
    res, stats = (vol.scan("t").filter("y", ">", 100)
                  .filter("y", "<", 300).filter("x", ">", 0.0)
                  .agg("count", "x").execute())
    mask = (table["y"] > 100) & (table["y"] < 300) & (table["x"] > 0)
    assert res == float(mask.sum())
    assert stats["pushdown"] and stats["exec_class"] == sc.EXEC_OSD_COMBINE


def test_builder_multi_aggregate_one_partial_per_osd():
    store, vol, omap, table = make_world()
    s = (vol.scan("t").filter("y", "<", 500)
         .agg("sum", "x").agg("count", "x").agg("min", "x")
         .agg("max", "x").agg("mean", "x"))
    assert s.pipeline()[-1].name == "multi_agg"
    assert oc.pipeline_mergeable(s.pipeline())
    store.fabric.reset()
    res, stats = s.execute()
    sel = table["x"][table["y"] < 500]
    assert res["sum(x)"] == pytest.approx(sel.sum(), rel=1e-12)
    assert res["count(x)"] == float(sel.size)
    assert res["min(x)"] == pytest.approx(sel.min(), rel=1e-12)
    assert res["max(x)"] == pytest.approx(sel.max(), rel=1e-12)
    assert res["mean(x)"] == pytest.approx(sel.mean(), rel=1e-12)
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert stats["rx_frames"] == len(primaries)  # ONE partial per OSD
    assert stats["result_rows"] == 1


def test_builder_rows_range_scan():
    store, vol, omap, table = make_world()
    res, stats = vol.scan("t").rows(123, 456).project("y").execute()
    assert np.array_equal(res["y"], table["y"][123:456])
    assert stats["exec_class"] == sc.EXEC_SERVER_CONCAT


def test_builder_rows_compose_with_tails():
    """A row range composes with every tail class.  The range ships as
    a shared ``row_slice`` op (resolved per object ON the OSD from its
    extent xattr), so a row-ranged aggregate rides the per-OSD combine
    plane — with pushed-down pruning — instead of per-object gathers."""
    store, vol, omap, table = make_world()
    s = vol.scan("t").rows(100, 2500).filter("y", "<", 500).agg("sum", "x")
    plan = s.explain()
    assert plan.exec_cls == sc.EXEC_OSD_COMBINE
    assert plan.prune == "pushdown"
    assert plan.pipelines is None          # ONE shared pipeline
    assert plan.ops[0].name == "row_slice"
    r, stats = s.execute()
    mask = table["y"][100:2500] < 500
    assert r == pytest.approx(table["x"][100:2500][mask].sum(), rel=1e-12)
    assert stats["xattr_ops"] == 0         # no client zone-map traffic
    m, _ = vol.scan("t").rows(0, 1000).median("x").execute()
    assert m == pytest.approx(float(np.median(table["x"][:1000])),
                              abs=1e-12)
    ma, _ = (vol.scan("t").rows(0, 1000).agg("sum", "x")
             .agg("count", "x").execute())
    assert ma["count(x)"] == 1000.0
    assert ma["sum(x)"] == pytest.approx(table["x"][:1000].sum(),
                                         rel=1e-12)
    # the client strategy still restricts itself to the row range: a
    # scan of the first object's rows never plans the rest
    first = omap.extents[0]
    plan = (vol.scan("t").rows(first.row_start, first.row_stop)
            .filter("y", "<", 500).agg("sum", "x").prune("client")
            .explain())
    assert plan.prune == "client"
    assert set(plan.names) | set(plan.pruned) == {first.name}


def test_builder_median_exact_vs_approx():
    store, vol, omap, table = make_world()
    med, st1 = vol.scan("t").median("x").execute()
    assert med == pytest.approx(float(np.median(table["x"])), abs=1e-12)
    assert st1["exec_class"] == sc.EXEC_HOLISTIC_GATHER
    assert st1["pushdown"] is False
    ap, st2 = vol.scan("t").median("x", approx=True).execute()
    assert st2["approx_rewrite"] and st2["pushdown"] is True
    assert st2["exec_class"] == sc.EXEC_OSD_COMBINE
    assert abs(ap - med) < 0.1


def test_builder_validation_errors():
    s = Scan(dataset="t")
    with pytest.raises(ValueError):
        s.filter("y", "~", 1)
    with pytest.raises(ValueError):
        s.agg("stddev", "x")
    with pytest.raises(ValueError):
        s.agg("sum", "x").median("x")
    with pytest.raises(ValueError):
        s.median("x").agg("sum", "x")
    with pytest.raises(ValueError):
        s.prune("osd")
    with pytest.raises(ValueError):
        s.execute()  # unbound


def test_explain_exposes_physical_plan():
    store, vol, omap, table = make_world()
    plan = vol.scan("t").filter("y", "<", 500).agg("sum", "x").explain()
    assert plan.exec_cls == sc.EXEC_OSD_COMBINE
    assert plan.prune == "pushdown"
    assert plan.predicates == ex.Cmp("y", "<", 500)
    assert len(plan.names) == omap.n_objects
    assert {o for o, _ in plan.shards} <= set(store.cluster.up_osds)
    assert sum(len(i) for _, i in plan.shards) == omap.n_objects


# ---------------------------------------------------------- query shim
def test_query_shim_compiles_to_scan():
    q = Query("t", filter=("y", "<", 500), projection=("x",),
              aggregate=("mean", "x"))
    ops = q.pipeline()
    assert [o.name for o in ops] == ["filter", "project", "agg"]
    # N filters: explicit field, or a sequence in the legacy slot —
    # both compile to ONE filter op carrying the conjunction tree
    q2 = Query("t", filters=(("y", ">", 1), ("y", "<", 9)))
    (f2,) = q2.pipeline()
    assert f2.name == "filter"
    assert ex.from_json(f2.params["expr"]) == ex.And(
        (ex.Cmp("y", ">", 1), ex.Cmp("y", "<", 9)))
    q3 = Query("t", filter=(("y", ">", 1), ("y", "<", 9)))
    assert q3.pipeline() == q2.pipeline()
    # N aggregates compile to one mergeable multi_agg tail
    q4 = Query("t", aggregate=(("sum", "x"), ("count", "x")))
    assert q4.pipeline()[-1].name == "multi_agg"


def test_query_shim_multi_filter_end_to_end():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    q = Query("t", filters=(("y", ">", 100), ("y", "<", 300)),
              aggregate=("count", "x"))
    res, stats = drv.execute(q)
    mask = (table["y"] > 100) & (table["y"] < 300)
    assert res == float(mask.sum())
    assert stats.pushdown and stats.result_rows == 1
    # conjunction prunes: a range wholly outside every zone map
    q_imp = Query("t", filters=(("y", ">", 100), ("y", ">", 2000)),
                  aggregate=("count", "x"))
    res, stats = drv.execute(q_imp)
    assert res == 0.0 and stats.objects_pruned == omap.n_objects


# ------------------------------------------------- OSD-side prune plane
def test_pushed_down_prune_issues_zero_zone_map_requests():
    store, vol, omap, table = make_world()
    store.fabric.reset()
    res, stats = vol.query(omap, [
        oc.op("filter", col="y", cmp="<", value=500),
        oc.op("agg", col="x", fn="sum")])
    assert res == pytest.approx(table["x"][table["y"] < 500].sum(),
                                rel=1e-12)
    assert store.fabric.xattr_ops == 0          # NO client zone-map reqs
    assert stats["xattr_ops"] == 0 and stats["prune"] == "pushdown"
    # a FRESH client is just as cold-start free
    fresh = GlobalVOL(store)
    store.fabric.reset()
    fresh.query(omap, IMPOSSIBLE)
    assert store.fabric.xattr_ops == 0


def test_osd_prune_equals_client_prune_sets_and_results():
    """The two strategies share one prune rule: same kept/pruned sets,
    bit-exact results, on identical metadata."""
    store, vol, omap, table = make_world()
    for flt in [("y", ">", 2000),     # prunes everything
                ("y", "<", 5),        # prunes most objects
                ("y", "<", 500),      # prunes nothing
                ("y", "==", 7)]:
        ops = [oc.op("filter", col=flt[0], cmp=flt[1], value=flt[2]),
               oc.op("agg", col="x", fn="sum")]
        r_osd, s_osd = vol.query(omap, ops, prune="pushdown")
        r_cli, s_cli = vol.query(omap, ops, prune="client")
        assert r_osd == r_cli, flt                      # bit-exact
        assert s_osd["objects_pruned"] == s_cli["objects_pruned"], flt
        assert s_osd["objects_touched"] == s_cli["objects_touched"], flt
        # and both match the unpruned ground truth
        r_none, _ = vol.query(omap, ops, prune="none")
        assert r_osd == r_none, flt


def test_osd_prune_table_out_preserves_row_order():
    store, vol, omap, table = make_world()
    ops = [oc.op("filter", col="y", cmp="<", value=30)]
    r_osd, s_osd = vol.query(omap, ops, prune="pushdown")
    mask = table["y"] < 30
    assert np.array_equal(r_osd["y"], table["y"][mask])  # ROW order
    assert np.array_equal(r_osd["x"], table["x"][mask])
    assert s_osd["result_rows"] == int(mask.sum())


def test_cross_client_rewrite_between_plan_and_execute():
    """A client-side prune decides at COMPILE time, so a rewrite landing
    between plan and execute slips through (the inherent TOCTOU).  The
    pushed-down prune decides ON the OSD at EXECUTE time against its
    current xattrs, so the same race cannot produce a stale result."""
    store, vol_a, omap, table = make_world()
    vol_b = GlobalVOL(store)
    n = len(table["y"])

    # compile both plans BEFORE the rewrite
    s_osd = vol_a.scan("t").filter("y", ">", 2000).agg("count", "x")
    s_cli = s_osd.prune("client")
    plan_osd = s_osd.explain(omap)
    plan_cli = s_cli.explain(omap)
    assert plan_osd.pruned == () and plan_osd.predicates  # decide later
    assert len(plan_cli.pruned) == omap.n_objects         # decided NOW

    # client B rewrites at the same epoch: now every row matches
    table2 = dict(table, y=(table["y"] + 5000).astype(np.int32))
    vol_b.write(omap, table2)

    r_osd, st = vol_a.engine.execute(plan_osd)
    assert r_osd == float(n)            # OSD saw the FRESH zone maps
    assert st["objects_pruned"] == 0
    r_cli, _ = vol_a.engine.execute(plan_cli)
    assert r_cli == 0.0                 # the stale window, demonstrated


# ------------------------------------------------- server-side concat
def test_filter_project_scan_returns_exactly_k_frames():
    store, vol, omap, table = make_world()
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert omap.n_objects > len(primaries)  # N > K or the claim is vacuous
    store.fabric.reset()
    res, stats = vol.query(omap, [
        oc.op("filter", col="y", cmp="<", value=500),
        oc.op("project", cols=["x"])])
    assert stats["rx_frames"] == len(primaries)      # EXACTLY K frames
    assert stats["ops"] == len(primaries)
    mask = table["y"] < 500
    assert np.array_equal(res["x"], table["x"][mask])


def test_exec_concat_matches_exec_batch_bit_exact():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    ops = [oc.op("filter", col="y", cmp="<", value=500),
           oc.op("project", cols=["x", "y"])]
    frames, pruned = store.exec_concat(names, ops)
    assert not pruned
    parts = sc._split_frames(len(names), frames)
    blobs = store.exec_batch(names, ops)
    for part, blob in zip(parts, blobs):
        ref = fmt.decode_block(blob)
        assert set(part) == set(ref)
        for k in ref:
            assert np.array_equal(part[k], ref[k])


def test_exec_concat_failover_to_replica_mid_batch():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    ops = [oc.op("project", cols=["y"])]
    expect = np.concatenate(
        [fmt.decode_block(b)["y"] for b in store.exec_batch(names, ops)])
    victim = names[0]
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].data[victim]
    store.fabric.reset()
    frames, _ = store.exec_concat(names, ops)
    primaries = {store.cluster.primary(n) for n in names}
    assert store.fabric.ops == len(primaries) + 1  # + one retry request
    parts = sc._split_frames(len(names), frames)
    got = np.concatenate([p["y"] for p in parts])
    assert np.array_equal(got, expect)


def test_exec_concat_rejects_partial_tails():
    store, vol, omap, table = make_world()
    with pytest.raises(ValueError):
        store.exec_concat(omap.object_names(),
                          [oc.op("agg", col="x", fn="sum")])


def test_read_rides_concat_plane():
    store, vol, omap, table = make_world()
    store.fabric.reset()
    out = vol.read(omap, RowRange(100, 1300), columns=["y"])
    assert np.array_equal(out["y"], table["y"][100:1300])
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert store.fabric.rx_frames <= len(primaries)


# ---------------------------------------------------- unified stats
def test_stats_drift_fixed_between_vol_and_driver():
    """Same scan, same stats: the holistic+approx rewrite used to report
    pushdown=True via vol.query but False via the driver."""
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    _, vs = vol.query(omap, [oc.op("median", col="x")], allow_approx=True)
    _, ds = drv.execute(Query("t", aggregate=("median", "x"),
                              allow_approx=True))
    assert vs["pushdown"] is True and ds.pushdown is True
    assert vs["approx_rewrite"] and ds.exec_class == sc.EXEC_OSD_COMBINE
    _, vs2 = vol.query(omap, [oc.op("median", col="x")])
    _, ds2 = drv.execute(Query("t", aggregate=("median", "x")))
    assert vs2["pushdown"] is False and ds2.pushdown is False


def test_result_rows_never_none_for_completed_queries():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=2)
    _, s = drv.execute(Query("t", aggregate=("sum", "x")))
    assert s.result_rows == 1                     # scalar aggregate
    _, s = drv.execute(Query("t", aggregate=("median", "x")))
    assert s.result_rows == 1                     # holistic scalar
    _, s = drv.execute(Query("t", aggregate=(("sum", "x"),
                                             ("count", "y"))))
    assert s.result_rows == 1                     # one aggregate row
    _, s = drv.execute(Query("t", filter=("y", "<", 50),
                             projection=("x",)))
    assert s.result_rows == int((table["y"] < 50).sum())
    _, s = drv.execute_client_side(Query("t", aggregate=("sum", "x")))
    assert s.result_rows == 1                     # baseline, unified too


def test_driver_and_vol_execute_identical_plans():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    q = Query("t", filter=("y", "<", 300), aggregate=("mean", "x"))
    r1, s1 = drv.execute(q)
    r2, vs = vol.query(omap, q.pipeline())
    assert r1 == pytest.approx(r2, rel=1e-15)
    assert s1.exec_class == vs["exec_class"]
    assert s1.prune == vs["prune"]
    assert s1.fabric_ops == vs["ops"]
    assert s1.rx_frames == vs["rx_frames"]


def test_driver_table_out_preserves_row_order():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    res, _ = drv.execute(Query("t", filter=("y", "<", 50),
                               projection=("x",)))
    assert np.array_equal(res["x"], table["x"][table["y"] < 50])


def test_driver_executes_scans_directly():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=2)
    res, stats = drv.execute(drv.scan("t").filter("y", "<", 500)
                             .agg("count", "x"))
    assert res == float((table["y"] < 500).sum())
    assert stats.exec_class == sc.EXEC_OSD_COMBINE


# ----------------------------------------------------- multi_agg op
def test_multi_agg_column_pruning_and_merge():
    specs = (("sum", "x"), ("count", "y"))
    ops = [oc.op("filter", col="y", cmp="<", value=500),
           oc.op("multi_agg", specs=specs)]
    assert oc.required_columns(ops) == ["x", "y"]
    rng = np.random.default_rng(5)
    tabs = [{"x": rng.normal(size=100),
             "y": rng.integers(0, 1000, 100).astype(np.int32),
             "z": rng.normal(size=100)} for _ in range(3)]
    parts = [oc.get_impl("multi_agg").local(
        oc.get_impl("filter").local(t, col="y", cmp="<", value=500),
        specs=specs) for t in tabs]
    merged = oc.merge_partials([oc.op("multi_agg", specs=specs)], parts)
    direct = oc.combine_partials([oc.op("multi_agg", specs=specs)], parts)
    via_merge = oc.combine_partials(
        [oc.op("multi_agg", specs=specs)], [merged])
    assert direct == pytest.approx(via_merge, rel=1e-12)
    allx = np.concatenate([t["x"][t["y"] < 500] for t in tabs])
    assert direct["sum(x)"] == pytest.approx(allx.sum(), rel=1e-12)
    assert direct["count(y)"] == float(allx.size)
