"""Graceful-degrade shim for hypothesis (see requirements-dev.txt).

When hypothesis is installed this re-exports ``given``, ``settings`` and
``strategies as st`` untouched.  When it is missing, property tests
degrade to per-test skips (via ``pytest.importorskip``) instead of
killing collection of the whole module — the example-based tests in the
same files still run.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:

    class _StubStrategy:
        """Chainable stand-in so module-level strategy definitions like
        ``st.lists(...).map(...)`` still evaluate at import time."""

        def __call__(self, *args, **kwargs):
            return _StubStrategy()

        def __getattr__(self, name):
            return _StubStrategy()

    st = _StubStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # (*args, **kwargs)-free signature so pytest does not try to
            # inject the property arguments as fixtures
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
