"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the assignment: sweep shapes/dtypes and assert_allclose against the
ref.py oracle for each kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.format import bitpack_encode
from repro.kernels import ops, ref


@pytest.mark.parametrize("bits", [1, 5, 8, 13, 16, 17, 20])
@pytest.mark.parametrize("shape", [(1, 128), (4, 512), (2, 1024)])
def test_bitunpack_sweep(bits, shape):
    rng = np.random.default_rng(bits)
    B, S = shape
    toks = rng.integers(0, 1 << bits, (B, S)).astype(np.int32)
    words = bitpack_encode(toks.ravel(), bits).reshape(B, S // 32, bits)
    out = ops.bitunpack_tokens(jnp.asarray(words), bits=bits)
    np.testing.assert_array_equal(np.asarray(out), toks)
    r = ref.bitunpack_ref(jnp.asarray(words.reshape(-1, 4, bits)), bits)
    np.testing.assert_array_equal(np.asarray(r).reshape(B, S), toks)


@pytest.mark.parametrize("cmp", ["<", "<=", ">", ">=", "==", "!="])
@pytest.mark.parametrize("n", [8192, 12345])
def test_filter_agg_sweep(cmp, n):
    rng = np.random.default_rng(hash(cmp) % 1000)
    v = rng.normal(size=n).astype(np.float32)
    f = rng.integers(0, 50, n).astype(np.float32)
    got = ops.filter_aggregate(jnp.asarray(v), jnp.asarray(f), cmp, 25)
    want = ref.filter_agg_ref(jnp.asarray(v), jnp.asarray(f), cmp, 25)
    for k in want:
        np.testing.assert_allclose(float(got[k]), float(want[k]),
                                   rtol=3e-5, atol=1e-3)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("n", [8192, 9000, 40000])
def test_block_agg_sweep(dtype, n):
    rng = np.random.default_rng(n)
    v = (rng.normal(size=n) * 10).astype(dtype)
    m = rng.random(n) < 0.5
    got = ops.masked_aggregate(jnp.asarray(v, jnp.float32),
                               jnp.asarray(m))
    want = ref.block_agg_ref(jnp.asarray(v, jnp.float32), jnp.asarray(m))
    for k in want:
        np.testing.assert_allclose(float(got[k]), float(want[k]),
                                   rtol=3e-5, atol=1e-3)


def test_filter_agg_empty_selection():
    v = jnp.ones((8192,), jnp.float32)
    f = jnp.zeros((8192,), jnp.float32)
    got = ops.filter_aggregate(v, f, ">", 1.0)
    assert float(got["count"]) == 0.0
    assert float(got["sum"]) == 0.0


def test_kernel_matches_host_codec_end_to_end():
    """Object bytes -> select_packed -> device bitunpack == raw tokens."""
    from repro.core import format as fmt
    from repro.core import objclass as oc
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 100_000, (16, 128)).astype(np.int32)
    bits = fmt.bitpack_width(100_000 - 1)
    blob = fmt.encode_block({"tokens": toks},
                            codecs={"tokens": f"bitpack{bits}"})
    res = oc.select_packed(blob, rows=(3, 11), col="tokens")
    out = ops.bitunpack_tokens(jnp.asarray(res["packed"]),
                               bits=int(res["bits"]))
    np.testing.assert_array_equal(np.asarray(out), toks[3:11])
