"""Batched scatter-gather data plane (exec_batch + column pruning +
vectorized bitpack codec).  Example-based on purpose: this module must
run even when hypothesis is unavailable."""

import numpy as np
import pytest

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        Query, RowRange, SkyhookDriver, make_store)
from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.store import PER_REQUEST_OVERHEAD_BYTES


def make_world(n=4000, n_osds=5, replicas=3, seed=0):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32"),
              Column("z", "float32")), n, 64)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 10,
                                          max_object_bytes=8 << 12))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32),
             "z": rng.normal(size=n).astype(np.float32)}
    vol.write(omap, table)
    return store, vol, omap, table


FILTER_AGG = [oc.op("filter", col="y", cmp="<", value=500),
              oc.op("agg", col="x", fn="sum")]


# ------------------------------------------------------------- exec_batch
def test_exec_batch_results_match_per_object_exec():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    batch = store.exec_batch(names, FILTER_AGG)
    single = [store.exec(n, FILTER_AGG) for n in names]
    assert len(batch) == len(single)
    for b, s in zip(batch, single):
        assert set(b) == set(s)
        for k in b:
            assert np.array_equal(b[k], s[k]), k


def test_exec_batch_one_request_per_osd_and_same_bytes():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    primaries = {store.cluster.primary(n) for n in names}

    store.fabric.reset()
    store.exec_batch(names, FILTER_AGG)
    batched = store.fabric.snapshot()

    store.fabric.reset()
    for n in names:
        store.exec(n, FILTER_AGG)
    per_obj = store.fabric.snapshot()

    # ops collapse from N to the number of primaries (<= K OSDs)
    assert per_obj["ops"] == len(names)
    assert batched["ops"] == len(primaries)
    assert batched["ops"] <= len(store.cluster.up_osds)
    assert batched["overhead_bytes"] == \
        batched["ops"] * PER_REQUEST_OVERHEAD_BYTES
    # payload accounting is identical: same results, same scanned bytes
    assert batched["client_rx"] == per_obj["client_rx"]
    assert batched["local_bytes"] == per_obj["local_bytes"]


def test_exec_batch_per_object_pipelines():
    store, vol, omap, table = make_world()
    names = omap.object_names()[:3]
    pipelines = [[oc.op("select", rows=(0, k + 1))] for k in range(3)]
    blobs = store.exec_batch(names, pipelines)
    for k, blob in enumerate(blobs):
        assert fmt.block_header(blob)["n_rows"] == k + 1
    with pytest.raises(ValueError):
        store.exec_batch(names, pipelines[:2])


def test_exec_batch_failover_to_replica_mid_batch():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    expect = store.exec_batch(names, FILTER_AGG)

    # primary silently lost one object: that item must fail over to a
    # replica inside the batch while everything else stays batched
    victim = names[0]
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].data[victim]
    store.fabric.reset()
    got = store.exec_batch(names, FILTER_AGG)
    for g, e in zip(got, expect):
        for k in e:
            assert np.array_equal(g[k], e[k])
    primaries = {store.cluster.primary(n) for n in names}
    assert store.fabric.snapshot()["ops"] == len(primaries) + 1  # + retry


def test_exec_batch_failover_on_osd_failure():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    expect = store.exec_batch(names, FILTER_AGG)
    store.fail_osd(store.cluster.primary(names[0]))
    got = store.exec_batch(names, FILTER_AGG)
    for g, e in zip(got, expect):
        for k in e:
            assert np.array_equal(g[k], e[k])


def test_exec_batch_raises_when_all_replicas_lost():
    store, vol, omap, table = make_world()
    name = omap.object_names()[0]
    for osd in store.osds.values():
        with osd.lock:
            osd.data.pop(name, None)
    with pytest.raises(KeyError):
        store.exec_batch([name], FILTER_AGG)


def test_query_ops_bounded_by_osds_not_objects():
    store, vol, omap, table = make_world()
    assert omap.n_objects > len(store.cluster.up_osds)
    res, stats = vol.query(omap, FILTER_AGG)
    assert stats["ops"] <= len(store.cluster.up_osds)
    assert res == pytest.approx(
        table["x"][table["y"] < 500].sum(), rel=1e-12)


def test_driver_query_ops_bounded_and_correct():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    q = Query("t", filter=("y", "<", 500), aggregate=("mean", "x"))
    r, s = drv.execute(q)
    assert r == pytest.approx(table["x"][table["y"] < 500].mean(),
                              rel=1e-12)
    assert s.fabric_ops <= len(store.cluster.up_osds)


def test_read_through_batch_equals_slice():
    store, vol, omap, table = make_world()
    store.fabric.reset()
    out = vol.read(omap, RowRange(100, 1300), columns=["y", "z"])
    assert np.array_equal(out["y"], table["y"][100:1300])
    assert np.allclose(out["z"], table["z"][100:1300])
    assert store.fabric.ops <= len(store.cluster.up_osds)


# ------------------------------------------------------- zone-map cache
# (the client-side prune plane: pinned to prune="client" — the default
# pushed-down prune needs no client zone-map cache at all)
def test_zone_map_cache_amortizes_xattr_lookups():
    store, vol, omap, table = make_world()
    store.fabric.reset()
    vol.query(omap, FILTER_AGG, prune="client")
    # the writing client cached its own zone maps on write: no lookups
    assert store.fabric.xattr_ops == 0
    # a fresh client warms its whole cache with ONE batched metadata
    # request per OSD (not one lookup per object, let alone per
    # obj x filter even with two filters in the pipeline), then runs warm
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    assert len(primaries) < omap.n_objects  # N > K or the claim is vacuous
    vol2 = GlobalVOL(store)
    store.fabric.reset()
    two_filters = [oc.op("filter", col="y", cmp=">", value=0),
                   oc.op("filter", col="y", cmp="<", value=900),
                   oc.op("agg", col="x", fn="count")]
    vol2.query(omap, two_filters, prune="client")
    assert store.fabric.xattr_ops == len(primaries)
    vol2.query(omap, two_filters, prune="client")
    assert store.fabric.xattr_ops == len(primaries)  # warm: no new ones
    # the pushed-down prune path needs NO zone-map requests at all
    vol3 = GlobalVOL(store)
    store.fabric.reset()
    vol3.query(omap, two_filters)
    assert store.fabric.xattr_ops == 0


def test_zone_map_cache_invalidated_on_epoch_bump():
    store, vol, omap, table = make_world()
    vol.query(omap, FILTER_AGG, prune="client")
    store.fail_osd(store.cluster.up_osds[0])  # epoch bump
    store.recover()
    store.fabric.reset()
    res, stats = vol.query(omap, FILTER_AGG, prune="client")
    assert store.fabric.xattr_ops > 0  # cache was dropped and re-warmed
    assert res == pytest.approx(table["x"][table["y"] < 500].sum(),
                                rel=1e-12)


def test_zone_map_cache_refreshed_by_write():
    store, vol, omap, table = make_world()
    # warm the cache, then rewrite with shifted data: pruning decisions
    # must follow the NEW zone maps, not the cached ones
    impossible = [oc.op("filter", col="y", cmp=">", value=2000),
                  oc.op("agg", col="x", fn="count")]
    assert vol.query(omap, impossible, prune="client")[0] == 0.0
    table2 = dict(table, y=(table["y"] + 5000).astype(np.int32))
    vol.write(omap, table2)
    res, _ = vol.query(omap, impossible, prune="client")
    assert res == float(len(table2["y"]))


# ------------------------------------------------------- column pruning
def test_required_columns_minimal_sets():
    f = oc.op("filter", col="y", cmp="<", value=1)
    assert oc.required_columns([f, oc.op("agg", col="x", fn="sum")]) == \
        ["x", "y"]
    assert oc.required_columns([oc.op("median", col="x")]) == ["x"]
    assert oc.required_columns(
        [f, oc.op("project", cols=["z"])]) == ["y", "z"]
    # table-out tails without projection keep every column
    assert oc.required_columns([f]) is None
    assert oc.required_columns([oc.op("select", rows=(0, 5))]) is None
    assert oc.required_columns([]) is None
    # non-analyzable ops decode everything
    assert oc.required_columns([oc.op("recompress", codecs={})]) is None


def test_pruned_pipeline_equals_full_decode():
    rng = np.random.default_rng(3)
    table = {"a": rng.integers(0, 100, 500).astype(np.int32),
             "b": rng.normal(size=500),
             "c": rng.normal(size=(500, 4)).astype(np.float32)}
    blob = fmt.encode_block(table, codecs={"a": "bitpack7"})
    ops = [oc.op("filter", col="a", cmp=">=", value=50),
           oc.op("agg", col="b", fn="mean")]
    got = oc.run_pipeline(blob, ops)
    full = fmt.decode_block(blob)
    mask = full["a"] >= 50
    assert float(got["sum"]) == pytest.approx(
        full["b"][mask].sum(), rel=1e-15)
    assert float(got["count"]) == float(mask.sum())
    # projection after a filter decodes only the union of their columns
    tab_blob = oc.run_pipeline(blob, [
        oc.op("filter", col="a", cmp=">=", value=50),
        oc.op("project", cols=["b"])])
    out = fmt.decode_block(tab_blob)
    assert set(out) == {"b"}
    assert np.array_equal(out["b"], full["b"][mask])


def test_filter_agg_query_end_to_end_unchanged_by_pruning():
    store, vol, omap, table = make_world()
    for fn in ("sum", "count", "min", "max", "mean"):
        res, _ = vol.query(omap, [
            oc.op("filter", col="y", cmp=">=", value=250),
            oc.op("agg", col="x", fn=fn)])
        sel = table["x"][table["y"] >= 250]
        expect = {"sum": sel.sum(), "count": float(sel.size),
                  "min": sel.min(), "max": sel.max(),
                  "mean": sel.mean()}[fn]
        assert res == pytest.approx(expect, rel=1e-12)


# --------------------------------------------------- vectorized bitpack
def _seed_bitpack_encode(values, bits):
    """The historical per-bit-loop encoder (the bit-exactness oracle)."""
    v = np.ascontiguousarray(values, dtype=np.uint32).ravel()
    n = v.size
    n_groups = -(-n // 32) if n else 0
    padded = np.zeros((n_groups * 32,), np.uint32)
    padded[:n] = v
    g = padded.reshape(n_groups, 32)
    lane = np.arange(32, dtype=np.uint32)
    out = np.zeros((n_groups, bits), np.uint32)
    for k in range(bits):
        out[:, k] = (((g >> np.uint32(k)) & np.uint32(1)) << lane).sum(
            axis=1, dtype=np.uint32)
    return out


@pytest.mark.parametrize("bits", list(range(1, 25)))
def test_bitpack_vectorized_bit_exact_vs_seed(bits):
    rng = np.random.default_rng(bits)
    for n in (0, 1, 31, 32, 33, 100, 1000, 4097):
        v = rng.integers(0, 1 << bits, n).astype(np.uint32)
        words = fmt.bitpack_encode(v, bits)
        assert words.shape == ((-(-n // 32) if n else 0), bits)
        assert np.array_equal(words, _seed_bitpack_encode(v, bits))
        assert np.array_equal(fmt.bitpack_decode(words, bits, n), v)


def test_bitpack_codec_in_block_roundtrip():
    rng = np.random.default_rng(9)
    a = rng.integers(0, 1 << 13, 999).astype(np.int32)
    blob = fmt.encode_block({"a": a}, codecs={"a": "bitpack13"})
    assert np.array_equal(fmt.decode_block(blob)["a"], a)


def test_bit_transpose_does_not_mutate_input():
    # (1, 32) inputs alias their own transpose; the butterfly must work
    # on a private buffer
    v = np.arange(32, dtype=np.uint32)
    before = v.copy()
    fmt.bitpack_encode(v, 6)
    assert np.array_equal(v, before)
    w = fmt.bitpack_encode(v, 6)
    w_before = w.copy()
    fmt.bitpack_decode(w, 6, 32)
    assert np.array_equal(w, w_before)


def test_codec_none_decode_is_zero_copy():
    table = {"x": np.arange(64, dtype=np.int64)}
    blob = fmt.encode_block(table)
    out = fmt.decode_block(blob)
    assert not out["x"].flags.writeable          # aliases the block bytes
    assert not out["x"].flags.owndata
    assert np.array_equal(out["x"], table["x"])


# ------------------------------------------------------------ get_hedged
def test_get_hedged_accounts_transfer_and_uses_shared_pool():
    store, vol, omap, table = make_world(n_osds=4, replicas=2)
    name = omap.object_names()[0]
    primary = store.cluster.primary(name)
    store.osds[primary].latency_s = 0.5
    store.fabric.reset()
    blob = store.get_hedged(name, timeout_s=0.02)
    assert blob == store.osds[store.cluster.locate(name)[1]].get(name)
    snap = store.fabric.snapshot()
    assert snap["client_rx"] == len(blob)     # transfer is accounted now
    assert snap["ops"] == 2                   # hedge + winning replica
    assert snap["overhead_bytes"] == 2 * PER_REQUEST_OVERHEAD_BYTES
    store.osds[primary].latency_s = 0.0


def test_get_hedged_falls_back_past_missing_replica():
    store, vol, omap, table = make_world(n_osds=5, replicas=3)
    name = omap.object_names()[0]
    acting = store.cluster.locate(name)
    # slow primary AND first replica missing the object: the hedge must
    # keep walking the acting set instead of raising
    store.osds[acting[0]].latency_s = 0.5
    with store.osds[acting[1]].lock:
        del store.osds[acting[1]].data[name]
    blob = store.get_hedged(name, timeout_s=0.02)
    assert blob == store.osds[acting[2]].get(name)
    # every replica gone: wait out the slow primary rather than fail
    with store.osds[acting[2]].lock:
        del store.osds[acting[2]].data[name]
    blob2 = store.get_hedged(name, timeout_s=0.02)
    assert blob2 == blob
    store.osds[acting[0]].latency_s = 0.0


def test_data_loader_batches_fetches_per_osd():
    from repro.data.corpus import CorpusSpec, build_corpus
    from repro.data.pipeline import ObjectDataLoader
    store = make_store(6, replicas=2)
    vol = GlobalVOL(store)
    spec = CorpusSpec(n_seqs=256, seq_len=64, vocab_size=5000, seed=1)
    build_corpus(vol, spec, policy=PartitionPolicy(
        target_object_bytes=4 << 10, max_object_bytes=1 << 20))
    loader = ObjectDataLoader(vol, "corpus", global_batch=64, prefetch=0)
    store.fabric.reset()
    batch = loader.make_batch(0)
    assert batch["tokens"].shape == (64, 64)
    # one batched request per OSD, not one per contiguous run
    assert store.fabric.ops <= len(store.cluster.up_osds)
