"""Object store + VOL + objclass behaviour (paper §2 goals 1-3)."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        Query, RowRange, SkyhookDriver, make_store)
from repro.core import objclass as oc
from repro.core.store import ObjectNotFound


def make_world(n=2000, n_osds=6, replicas=3, seed=0, obj_kb=8):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=obj_kb << 10,
                                          max_object_bytes=obj_kb << 12))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    vol.write(omap, table)
    return store, vol, omap, table


# ---------------------------------------------------------------- store
def test_put_get_replication_failover():
    store = make_store(5, replicas=3)
    store.put("obj", b"hello")
    assert store.get("obj") == b"hello"
    # kill primary AND second replica; read must still succeed
    acting = store.cluster.locate("obj")
    store.fail_osd(acting[0])
    store.fail_osd(acting[1])
    assert store.get("obj") == b"hello"
    store.fail_osd(store.cluster.locate("obj")[0])
    with pytest.raises((ObjectNotFound, RuntimeError, KeyError)):
        store.get("obj")


def test_recovery_restores_replication():
    store, vol, omap, table = make_world()
    victim = store.cluster.locate(omap.object_names()[0])[0]
    store.fail_osd(victim)
    rec = store.recover()
    assert rec["objects_lost"] == 0
    # every object now has a full acting set
    for name in omap.object_names():
        for osd_id in store.cluster.locate(name):
            assert name in store.osds[osd_id].data


def test_exec_runs_on_surviving_replica():
    store, vol, omap, table = make_world()
    name = omap.object_names()[0]
    store.fail_osd(store.cluster.locate(name)[0])
    res = store.exec(name, [oc.op("agg", col="y", fn="count")])
    assert res["count"] > 0


# ---------------------------------------------------------------- vol
def test_read_equals_slice():
    store, vol, omap, table = make_world()
    out = vol.read(omap, RowRange(123, 456))
    assert np.allclose(out["x"], table["x"][123:456])
    assert np.array_equal(out["y"], table["y"][123:456])


def test_read_projection_moves_fewer_bytes():
    store, vol, omap, table = make_world()
    store.fabric.reset()
    vol.read(omap, RowRange(0, 1000), columns=["y"])
    rx_proj = store.fabric.client_rx
    store.fabric.reset()
    vol.read(omap, RowRange(0, 1000))
    rx_all = store.fabric.client_rx
    assert rx_proj < rx_all / 2


@given(st.sampled_from(["sum", "count", "min", "max", "mean"]),
       st.sampled_from(["<", "<=", ">", ">=", "==", "!="]),
       st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_pushdown_agg_matches_numpy(fn, cmp, thr):
    store, vol, omap, table = make_world()
    res, stats = vol.query(omap, [
        oc.op("filter", col="y", cmp=cmp, value=thr),
        oc.op("agg", col="x", fn=fn)])
    mask = {"<": np.less, "<=": np.less_equal, ">": np.greater,
            ">=": np.greater_equal, "==": np.equal,
            "!=": np.not_equal}[cmp](table["y"], thr)
    sel = table["x"][mask]
    expect = {"sum": sel.sum() if sel.size else 0.0,
              "count": float(sel.size),
              "min": sel.min() if sel.size else np.inf,
              "max": sel.max() if sel.size else -np.inf,
              "mean": sel.mean() if sel.size else 0.0}[fn]
    assert res == pytest.approx(expect, rel=1e-9, abs=1e-12)
    assert stats["pushdown"]


def test_holistic_median_exact_and_approx():
    # enough rows per object that the fixed sketch cost (bins * 4 B per
    # object) clearly beats the gather — the crossover the paper's §3.2
    # "acceptable approximations" tradeoff is about
    store, vol, omap, table = make_world(n=30_000, obj_kb=64)
    med, st1 = vol.query(omap, [oc.op("median", col="x")])
    assert med == pytest.approx(float(np.median(table["x"])), abs=1e-12)
    approx, st2 = vol.query(omap, [oc.op("median", col="x")],
                            allow_approx=True)
    assert st2["approx_rewrite"]
    assert abs(approx - med) < 0.02
    # the decomposable rewrite moves far fewer bytes than the gather
    assert st2["client_rx"] < st1["client_rx"] / 3


def test_zone_map_pruning_sound_and_effective():
    store, vol, omap, table = make_world()
    # impossible predicate: everything pruned, count = 0
    res, stats = vol.query(omap, [
        oc.op("filter", col="y", cmp=">", value=10_000),
        oc.op("agg", col="x", fn="count")])
    assert res == 0.0 and stats["objects_pruned"] == omap.n_objects
    # sound: pruned plan result == unpruned result for a selective filter
    res2, _ = vol.query(omap, [
        oc.op("filter", col="y", cmp="<", value=5),
        oc.op("agg", col="x", fn="count")])
    assert res2 == float((table["y"] < 5).sum())


def test_pushdown_vs_clientside_same_result_fewer_bytes():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    q = Query("t", filter=("y", "<", 300), aggregate=("mean", "x"))
    r1, s1 = drv.execute(q)
    r2, s2 = drv.execute_client_side(q)
    assert r1 == pytest.approx(r2, rel=1e-12)
    assert s1.client_rx_bytes < s2.client_rx_bytes / 20
    assert s1.pushdown and not s2.pushdown


def test_driver_table_pipeline_roundtrip():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=2)
    res, stats = drv.execute(Query("t", filter=("y", "<", 50),
                                   projection=("x",)))
    expect = table["x"][table["y"] < 50]
    assert sorted(res["x"].tolist()) == sorted(expect.tolist())


def test_local_vol_physical_design_counter():
    store, vol, omap, table = make_world()
    for _ in range(10):
        vol.query(omap, [oc.op("agg", col="x", fn="sum")])
    assert vol.local.preferred_layout() == "col"
