"""Streaming pipelined data plane: windowed ingest (put_batch streaming
mode), chain replication, streaming scan consume (exec_*_iter + engine
frame-by-frame decode), and the loader's windowed multi-step fetch.
Example-based on purpose: must run without hypothesis."""

import threading
import time

import numpy as np
import pytest

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        RowRange, make_store)
from repro.core import objclass as oc
from repro.core.store import OSDDown, PartialWriteError


def make_world(n=4000, n_osds=5, replicas=3, seed=0, **store_kw):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas, **store_kw)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 10,
                                          max_object_bytes=8 << 12))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    return store, vol, omap, table


def _blobs_for(names):
    return [f"blob-{i}".encode() * 40 for i in range(len(names))]


# ------------------------------------------------------ windowed ingest
def test_windowed_put_batch_same_bytes_ops_and_accounting():
    """Streaming mode must change WHEN bytes move, never WHAT moves:
    identical stored bytes, one request per primary OSD, identical
    payload accounting — plus stream_windows > 0 proving the windows
    actually flushed."""
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = _blobs_for(names)
    primaries = {store.cluster.primary(n) for n in names}

    store.fabric.reset()
    store.put_batch(names, blobs)
    buffered = store.fabric.snapshot()
    stored_buffered = {(o, n): store.osds[o].data[n]
                       for n in names for o in store.cluster.locate(n)}

    store.fabric.reset()
    store.put_batch(names, iter(blobs), window_objects=3)
    streamed = store.fabric.snapshot()

    assert streamed["ops"] == buffered["ops"] == len(primaries)
    assert streamed["client_tx"] == buffered["client_tx"]
    assert streamed["replica_bytes"] == buffered["replica_bytes"]
    assert streamed["entry_egress_bytes"] == buffered["entry_egress_bytes"]
    assert streamed["stream_windows"] > 0
    assert buffered["stream_windows"] == 0
    for (o, n), blob in stored_buffered.items():
        assert store.osds[o].data[n] == blob  # bit-exact stored bytes


def test_windowed_put_batch_accepts_lazy_blob_xattr_producer():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = _blobs_for(names)

    def produce():
        for i, b in enumerate(blobs):
            yield b, {"tag": i}

    versions = store.put_batch(names, produce(), window_bytes=1 << 10)
    assert len(versions) == len(names)
    for i, (n, v) in enumerate(zip(names, versions)):
        x = store.xattr(n)
        assert x["tag"] == i and x["version"] == v
        assert store.get(n) == blobs[i]


def test_windowed_put_batch_truncated_producer_raises():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    with pytest.raises(ValueError):
        store.put_batch(names, iter([b"only-one"]), window_objects=1)


def test_windowed_put_batch_overlong_producer_raises():
    """An extra blob beyond len(names) is a caller bug and must raise
    (the buffered path's length validation), never drop data silently."""
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = _blobs_for(names) + [b"one-too-many"]
    with pytest.raises(ValueError):
        store.put_batch(names, iter(blobs), window_objects=2)


def test_checkpoint_streaming_save_restores_bit_exact():
    """With simulated I/O the checkpoint ships as ONE windowed batch
    spanning all leaves (cross-leaf encode/stream overlap): one put
    request per primary OSD for the whole checkpoint + the manifest,
    stream_windows > 0, restore bit-exact."""
    from repro.checkpoint import ckpt
    from repro.core import PartitionPolicy
    store = make_store(4, replicas=2, client_bw=500 << 20)
    state = {"w": np.arange(8192, dtype=np.float32),
             "b": np.ones(256, dtype=np.float32)}
    store.fabric.reset()
    ckpt.save(store, state, step=3,
              policy=PartitionPolicy(target_object_bytes=2 << 10,
                                     max_object_bytes=2 << 10))
    k = len(store.cluster.up_osds)
    assert store.fabric.ops <= k + 1  # ONE streamed batch + manifest
    assert store.fabric.stream_windows > 0
    restored, _ = ckpt.restore(store, state, step=3)
    assert np.array_equal(restored["w"], state["w"])
    assert np.array_equal(restored["b"], state["b"])


def test_windowed_put_batch_overlaps_encode_with_stream():
    """With a simulated NIC, encode time after the first flush must be
    hidden behind the stream (overlap_s > 0) and the windowed wall must
    beat serial encode-then-stream."""
    store, vol, omap, table = make_world(client_bw=100 << 20)
    names = omap.object_names()
    payload = [b"x" * (256 << 10) for _ in names]
    encode_s = 0.004  # simulated per-object encode cost

    # measure what this machine's sleep-based "encoder" actually costs
    # (time.sleep overshoots under load — a nominal sum flakes)
    t0 = time.perf_counter()
    for _ in names:
        time.sleep(encode_s)
    encode_measured = time.perf_counter() - t0

    def produce():
        for b in payload:
            time.sleep(encode_s)
            yield b

    store.fabric.reset()
    t0 = time.perf_counter()
    store.put_batch(names, produce(), window_objects=1)
    wall = time.perf_counter() - t0
    snap = store.fabric.snapshot()
    nic_s = sum(len(b) for b in payload) / (100 << 20)
    assert nic_s > 0.3 * encode_measured  # overlap is non-trivial here
    # the claim, measured directly: all encode after the first flush ran
    # while a stream was active (sleep inflation under load only raises
    # it, so this is machine-load-robust where a wall-clock subtraction
    # is not; the table1 bench gates the wall ratio in a controlled run)
    assert snap["overlap_s"] > 0.5 * encode_measured
    assert wall < encode_measured + nic_s + 0.5  # sanity ceiling only


def test_windowed_put_batch_entry_death_mid_stream_fails_over():
    """The entry OSD dies mid-stream: landed sub-writes keep their
    success, unlanded ones (queued or not yet produced) fail over, and
    payload accounting stays exact."""
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = _blobs_for(names)
    by_primary = {}
    for n in names:
        by_primary.setdefault(store.cluster.primary(n), []).append(n)
    victim, group = max(by_primary.items(), key=lambda kv: len(kv[1]))
    assert len(group) >= 2

    real = store.osds[victim].put_batch
    died = {"yet": False}

    def dies_midway(items, stream=None, landed=None):
        if died["yet"]:
            return real(items, stream=stream, landed=landed)
        died["yet"] = True
        it = iter(items)
        real([next(it)], stream=stream, landed=landed)  # first one lands
        raise OSDDown(victim)

    store.osds[victim].put_batch = dies_midway
    store.fabric.reset()
    versions = store.put_batch(names, iter(blobs), window_objects=2)
    assert len(versions) == len(names)
    for n, b in zip(names, blobs):
        for osd_id in store.cluster.locate(n):
            assert store.osds[osd_id].data[n] == b
    payload = sum(len(b) for b in blobs)
    assert store.fabric.client_tx == payload
    assert store.fabric.replica_bytes == \
        payload * (store.cluster.replicas - 1)


def test_vol_write_windowed_matches_buffered_bit_exact():
    store, vol, omap, table = make_world()
    vol.write(omap, table, window_objects=0)  # force buffered
    stored = {(o, n): store.osds[o].data[n]
              for n in omap.object_names()
              for o in store.cluster.locate(n)}
    store.fabric.reset()
    vol.write(omap, table, window_objects=2)
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    assert store.fabric.ops == len(primaries)  # still O(K)
    assert store.fabric.stream_windows > 0
    for (o, n), blob in stored.items():
        assert store.osds[o].data[n] == blob
    out = vol.read(omap, RowRange(0, omap.dataset.n_rows))
    assert np.allclose(out["x"], table["x"])


# ---------------------------------------------------- chain replication
def test_chain_replication_halves_entry_egress_vs_fanout():
    """Same objects, same total replication bytes — but the entry OSD
    sends each blob ONCE down the chain instead of (replicas-1) times."""
    snaps = {}
    for topo in ("chain", "fanout"):
        store, vol, omap, table = make_world(replicas=3,
                                             replication=topo)
        names = omap.object_names()
        blobs = _blobs_for(names)
        store.fabric.reset()
        store.put_batch(names, blobs)
        snaps[topo] = store.fabric.snapshot()
        for n, b in zip(names, blobs):
            for osd_id in store.cluster.locate(n):
                assert store.osds[osd_id].data[n] == b
    assert snaps["chain"]["replica_bytes"] == \
        snaps["fanout"]["replica_bytes"]
    assert snaps["fanout"]["entry_egress_bytes"] == \
        snaps["fanout"]["replica_bytes"]
    # R=3: fan-out sends 2 copies from the entry, the chain sends 1
    assert snaps["chain"]["entry_egress_bytes"] * 2 == \
        snaps["fanout"]["entry_egress_bytes"]


def test_chain_replication_single_put_matches_batch_accounting():
    store, vol, omap, table = make_world(replicas=3)
    names = omap.object_names()
    blobs = _blobs_for(names)
    store.fabric.reset()
    for n, b in zip(names, blobs):
        store.put(n, b)
    per_obj = store.fabric.snapshot()
    payload = sum(len(b) for b in blobs)
    assert per_obj["replica_bytes"] == payload * 2
    assert per_obj["entry_egress_bytes"] == payload  # chain: one hop out


def test_chain_mid_death_skips_hop_and_keeps_accounting_exact():
    """A mid-chain replica dies between the primary write and its
    replication hop: the chain must skip it (the tail still gets its
    copy, forwarded by the last holder), versions stay monotonic, and
    replica_bytes counts ONLY the hops that actually moved bytes."""
    store, vol, omap, table = make_world(replicas=3)
    name = omap.object_names()[0]
    acting = store.cluster.locate(name)
    middle = acting[1]
    real_put = store.osds[middle].put
    calls = {"n": 0}

    def down_once(*a, **kw):
        if calls["n"] == 0:
            calls["n"] += 1
            raise OSDDown(middle)
        return real_put(*a, **kw)

    store.osds[middle].put = down_once
    store.fabric.reset()
    v1 = store.put(name, b"chain-payload")
    assert store.fabric.replica_bytes == len(b"chain-payload")  # 1 hop
    assert store.fabric.entry_egress_bytes == len(b"chain-payload")
    assert store.osds[acting[2]].data[name] == b"chain-payload"
    assert name not in store.osds[middle].data  # skipped, not retried

    # the next write replicates everywhere again with a bumped version
    v2 = store.put(name, b"chain-payload-2")
    assert v2 > v1
    for osd_id in acting:
        assert store.osds[osd_id].data[name] == b"chain-payload-2"
        assert store.osds[osd_id].xattrs[name]["version"] == v2


def test_recover_heals_skipped_chain_hop():
    store, vol, omap, table = make_world(replicas=3)
    name = omap.object_names()[0]
    acting = store.cluster.locate(name)
    middle = acting[1]
    real_put = store.osds[middle].put
    store.osds[middle].put = lambda *a, **kw: (_ for _ in ()).throw(
        OSDDown(middle))
    store.put(name, b"heal-me")
    store.osds[middle].put = real_put
    store.recover()
    assert store.osds[middle].data[name] == b"heal-me"


# ------------------------------------------------- streaming scan consume
def test_exec_concat_iter_first_frame_before_slow_osd():
    """With one straggler OSD, the fast OSDs' frames must reach the
    consumer while the straggler is still scanning — and the assembled
    result must be bit-exact vs the buffered gather."""
    store, vol, omap, table = make_world(n_osds=4, replicas=2)
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("project", cols=["y"])]
    frames_ref, _ = store.exec_concat(names, ops)
    primaries = {store.cluster.primary(n) for n in names}
    assert len(primaries) >= 3

    slow = sorted(primaries)[0]
    store.osds[slow].latency_s = 0.25
    store.fabric.reset()
    first_rx = None
    frames = []
    for frame in store.exec_concat_iter(names, ops):
        if first_rx is None:
            first_rx = store.fabric.rx_frames
        frames.append(frame)
    store.osds[slow].latency_s = 0.0
    assert first_rx < len(primaries)  # straggler had not answered yet
    assert store.fabric.stream_windows == len(frames) == len(primaries)

    from repro.core.scan import _split_frames
    parts_ref = _split_frames(len(names), frames_ref)
    parts = _split_frames(len(names), frames)
    for a, b in zip(parts, parts_ref):
        assert np.array_equal(a["y"], b["y"])


def test_engine_execute_streams_frames_and_stats_count_windows():
    """vol-level scans ride the streaming consume: stream_windows in
    the emitted stats equals the per-OSD frames delivered."""
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    out, stats = (vol.scan(omap).filter("y", "<", 500)
                  .project("x", "y").execute(omap))
    mask = table["y"] < 500
    assert np.array_equal(out["y"], table["y"][mask])
    assert stats["rx_frames"] == stats["stream_windows"] \
        <= len(primaries)

    res, astats = vol.scan(omap).agg("mean", "x").execute(omap)
    assert res == pytest.approx(table["x"].mean(), rel=1e-12)
    assert astats["stream_windows"] == astats["rx_frames"]


def test_exec_batch_iter_matches_buffered_results():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("project", cols=["y"])]
    buffered = store.exec_batch(names, ops)
    got: dict = {}
    for i, res in store.exec_batch_iter(names, ops):
        got[i] = res
    assert set(got) == set(range(len(names)))
    for i, blob in enumerate(buffered):
        assert got[i] == blob


def test_exec_combine_iter_failover_and_equivalence():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("agg", col="x", fn="sum")]
    expect = oc.combine_partials(ops, store.exec_combine(names, ops))
    victim = names[0]
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].data[victim]
    pruned: list = []
    partials = list(store.exec_combine_iter(names, ops,
                                            pruned_out=pruned))
    assert not pruned
    assert oc.combine_partials(ops, partials) == pytest.approx(
        expect, rel=1e-12)


# -------------------------------------------------- loader windowed mode
def _corpus_world(n_osds=4, n_seqs=64, seq_len=64, obj_kb=2):
    from repro.data.corpus import CorpusSpec, build_corpus
    store = make_store(n_osds, replicas=2)
    vol = GlobalVOL(store)
    build_corpus(vol, CorpusSpec(n_seqs=n_seqs, seq_len=seq_len,
                                 vocab_size=512),
                 PartitionPolicy(target_object_bytes=obj_kb << 10,
                                 max_object_bytes=64 << 10))
    return store, vol


def test_loader_windowed_batches_bit_exact_vs_per_step():
    from repro.data.pipeline import ObjectDataLoader
    store, vol = _corpus_world()
    ref = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=0)
    win = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=2,
                           window_steps=3)
    try:
        for _ in range(7):
            a = next(ref)
            b = next(win)
            assert np.array_equal(a["tokens"], b["tokens"])
            assert np.array_equal(a["labels"], b["labels"])
    finally:
        ref.close()
        win.close()


def test_loader_windowed_yields_first_batch_before_slow_osd():
    """One OSD is a straggler serving only LATER steps' rows: the first
    batch must pop out of the loader while that OSD's frames are still
    in flight (the windowed ingest/scan overlap, loader side)."""
    from repro.data.pipeline import ObjectDataLoader
    store, vol = _corpus_world(n_osds=8, n_seqs=512, obj_kb=16)
    probe = ObjectDataLoader(vol, "corpus", global_batch=4, prefetch=0)
    # find a window start whose FIRST step skips some OSD that serves a
    # LATER step of the window — that OSD's frame cannot gate the first
    # batch out of the loader
    straggler = start = None
    for s0 in range(12):
        runs0 = {e.name for e, _, _, _ in
                 probe._runs_for(probe.rows_for_step(s0))}
        later = set()
        for s in range(s0 + 1, s0 + 4):
            later |= {e.name for e, _, _, _ in
                      probe._runs_for(probe.rows_for_step(s))}
        prim0 = {store.cluster.primary(n) for n in runs0}
        cands = [store.cluster.primary(n) for n in later - runs0
                 if store.cluster.primary(n) not in prim0]
        if cands:
            straggler, start = cands[0], s0
            break
    probe.close()
    assert straggler is not None, "no straggler-free first step found"
    store.osds[straggler].latency_s = 0.3

    win = ObjectDataLoader(vol, "corpus", global_batch=4, prefetch=2,
                           window_steps=4, start_step=start)
    ref = ObjectDataLoader(vol, "corpus", global_batch=4, prefetch=0,
                           start_step=start)
    try:
        t0 = time.perf_counter()
        first = next(win)
        first_wall = time.perf_counter() - t0
        stats = win.last_window_stats
        assert stats is not None
        # the first batch left before the whole window's results landed
        assert stats["results_at_first_yield"] < stats["total_results"]
        assert first_wall < 0.3  # did not wait for the straggler
        assert np.array_equal(first["tokens"], next(ref)["tokens"])
    finally:
        store.osds[straggler].latency_s = 0.0
        win.close()
        ref.close()


def test_loader_windowed_mode_rejects_unservable_configs():
    """window_steps > 1 only runs inside the prefetch producer and
    conflicts with hedged reads — both must fail LOUDLY, not silently
    fall back to the per-step path."""
    from repro.data.pipeline import ObjectDataLoader
    store, vol = _corpus_world()
    with pytest.raises(ValueError):
        ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=0,
                         window_steps=2)
    with pytest.raises(ValueError):
        ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=2,
                         window_steps=2, hedge_timeout_s=0.1)


def test_exec_combine_streaming_fold_is_deterministic():
    """Merged partials feed an order-sensitive float fold: with
    simulated I/O and racing OSD threads, repeated identical aggregate
    scans must still fold in one (dispatch) order — bit-equal results
    run to run."""
    store, vol, omap, table = make_world(n_osds=5, seed=3)
    vol.write(omap, table)
    for osd in store.osds.values():  # jitter completion order
        osd.latency_s = 0.001
    try:
        results = {vol.scan(omap).agg("sum", "x").execute(omap)[0]
                   for _ in range(6)}
    finally:
        for osd in store.osds.values():
            osd.latency_s = 0.0
    assert len(results) == 1, results  # bit-identical every run


def test_loader_seek_repositions_producer_exactly():
    from repro.data.pipeline import ObjectDataLoader
    store, vol = _corpus_world()
    ld = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=2,
                          window_steps=2)
    ref = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=0)
    try:
        next(ld)
        next(ld)
        ld.seek(5)
        got = next(ld)
        ref.seek(5)  # threadless seek: just repositions state
        want = ref.make_batch(5)
        assert np.array_equal(got["tokens"], want["tokens"])
        assert ld.state.step == 6
    finally:
        ld.close()
        ref.close()


def test_device_stream_matches_make_batch():
    pytest.importorskip("jax")
    from repro.data.fused_ingest import device_stream
    from repro.data.pipeline import ObjectDataLoader
    store, vol = _corpus_world()
    win = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=2,
                           packed=True, window_steps=2)
    ref = ObjectDataLoader(vol, "corpus", global_batch=8, prefetch=0,
                           packed=True)
    try:
        stream = device_stream(win, lookahead=1)
        for s in range(4):
            words = next(stream)
            want = ref.make_batch(s)["tokens_packed"]
            assert np.array_equal(np.asarray(words), want)
    finally:
        win.close()
        ref.close()


# ------------------------------------- hedged reads vs in-flight stream
def test_hedged_read_during_windowed_put_batch():
    """A hedged read must share the store's pools with an in-flight
    windowed put_batch without deadlock, and NIC accounting must stay
    exact on both sides."""
    store, vol, omap, table = make_world(n_osds=4, replicas=2,
                                         client_bw=50 << 20)
    target = "hedge/victim"
    blob0 = b"h" * 4096
    store.put(target, blob0)
    store.osds[store.cluster.primary(target)].latency_s = 0.15

    names = [f"stream/{i:03d}" for i in range(24)]
    blobs = [bytes([i % 251]) * (64 << 10) for i in range(24)]

    def produce():
        for b in blobs:
            time.sleep(0.002)  # encoder pacing
            yield b

    store.fabric.reset()
    done: dict = {}

    def writer():
        store.put_batch(names, produce(), window_objects=2)
        done["w"] = True

    th = threading.Thread(target=writer)
    th.start()
    time.sleep(0.02)  # stream is in flight
    got = store.get_hedged(target, timeout_s=0.02)
    th.join(timeout=30)
    assert done.get("w") and got == blob0
    snap = store.fabric.snapshot()
    assert snap["client_tx"] == sum(len(b) for b in blobs)
    assert snap["client_rx"] == len(blob0)
    for n, b in zip(names, blobs):
        assert store.get(n) == b


def test_exec_many_is_retired():
    store, _, _, _ = make_world()
    assert not hasattr(store, "exec_many")


# ------------------------------------------------- bounded write ledger
def test_windowed_put_ledger_peak_stays_window_sized():
    """The bounded streaming write ledger: each sub-write's blob is
    released the moment it AND its replica chain land, so a long
    windowed stream retains O(window) bytes — not the whole batch the
    buffered path pins — with accounting and stored bytes unchanged."""
    n, blob_kib = 256, 32
    names = [f"led/{i:04d}" for i in range(n)]
    blobs = [(b"%04d" % i) * (blob_kib << 8) for i in range(n)]
    total = sum(len(b) for b in blobs)
    window = 64 << 10

    streamed = make_store(2, replicas=2, client_bw=500 << 20)
    streamed.put_batch(names, iter(blobs), window_bytes=window)
    peak = streamed.last_put_ledger_peak_bytes
    # bound: the current window + the bounded feeder queues (8 groups
    # per OSD stream) + in-flight writes/replicas — generous slack, but
    # far below the whole batch
    assert 0 < peak <= 24 * window, (peak, total)
    assert peak < total // 4

    buffered = make_store(2, replicas=2, client_bw=500 << 20)
    buffered.put_batch(names, blobs)
    assert buffered.last_put_ledger_peak_bytes == total  # pins it all

    s1, s2 = streamed.fabric.snapshot(), buffered.fabric.snapshot()
    for key in ("client_tx", "replica_bytes", "entry_egress_bytes",
                "ops"):
        assert s1[key] == s2[key], key
    for nm, b in zip(names, blobs):
        assert streamed.get(nm) == buffered.get(nm) == b


def test_ckpt_streaming_save_ledger_bounded():
    """ckpt.save's whole-checkpoint stream keeps O(window) client
    memory: the serialized state is released window by window as the
    replica chains land, and the checkpoint still restores bit-exact."""
    from repro.checkpoint import ckpt
    from repro.core import PartitionPolicy
    store = make_store(3, replicas=2, client_bw=500 << 20)
    state = {"w": np.arange(4 << 20, dtype=np.float32)}  # 16 MiB
    window = 128 << 10
    ckpt.save(store, state, 0,
              policy=PartitionPolicy(target_object_bytes=64 << 10,
                                     max_object_bytes=128 << 10),
              window_bytes=window)
    peak = store.last_put_ledger_peak_bytes
    # the retained bound is O(streams x queue depth x window) — 3 OSD
    # streams x 8 queued groups + in-flight — NEVER O(checkpoint)
    assert 0 < peak <= 32 * window, peak
    assert peak < state["w"].nbytes // 4
    back, _ = ckpt.restore(store, {"w": np.zeros(4 << 20, np.float32)},
                           step=0)
    assert np.array_equal(back["w"], state["w"])


def test_windowed_ledger_keeps_blobs_for_failover():
    """Releasing must never outrun failover: blobs whose stream died
    before they landed are still pinned and retried on a replica —
    every byte lands despite the mid-stream entry death."""
    store, vol, omap, table = make_world(n_osds=4, replicas=3)
    names = [f"fo/{i:03d}" for i in range(32)]
    blobs = [bytes([i % 251]) * (8 << 10) for i in range(32)]
    victim = store.cluster.primary(names[0])

    def produce():
        for i, b in enumerate(blobs):
            if i == 12:  # entry OSD dies mid-stream
                store.fail_osd(victim)
            yield b

    versions = store.put_batch(names, produce(), window_objects=2)
    assert len(versions) == len(names)
    for nm, b in zip(names, blobs):
        assert store.get(nm) == b  # landed (failover used pinned blobs)


# ------------------------------------------- partial-persist reporting
def test_short_producer_reports_persisted_names_and_versions():
    """A producer that ends early raises only after earlier windows
    persisted: the exception must NAME those sub-writes and their
    stamped versions so the caller can reconcile instead of guessing."""
    store = make_store(3, replicas=2)
    names = [f"pw/{i}" for i in range(10)]
    blobs = [(b"%d" % i) * 100 for i in range(10)]

    def short():
        yield from blobs[:5]

    with pytest.raises(PartialWriteError) as ei:
        store.put_batch(names, short(), window_objects=2)
    err = ei.value
    assert isinstance(err, ValueError)  # old except-clauses still catch
    # items 0..3 flushed in two windows; item 4 was materialized but its
    # window never flushed — NOT persisted, NOT listed
    assert [n for n, _ in err.persisted] == names[:4]
    for nm, version in err.persisted:
        assert store.xattr(nm)["version"] == version  # durable + stamped
    assert not store.exists(names[4])
    assert not store.exists(names[7])


def test_long_producer_reports_whole_batch_persisted():
    store = make_store(3, replicas=2)
    names = [f"pl/{i}" for i in range(9)]
    blobs = [(b"%d" % i) * 64 for i in range(9)]

    def overlong():
        yield from blobs
        yield b"one-too-many"

    with pytest.raises(PartialWriteError) as ei:
        store.put_batch(names, overlong(), window_objects=3)
    assert [n for n, _ in ei.value.persisted] == names  # ALL landed
    for nm, b in zip(names, blobs):
        assert store.get(nm) == b
    assert "persisted" in str(ei.value)
