"""Symmetric per-OSD batch plane: batched writes (put_batch), server-
side per-OSD combine (exec_combine), batched zone-map metadata
(list_zone_maps), and the cross-client version-tag coherence protocol.
Example-based on purpose: must run without hypothesis."""

import numpy as np
import pytest

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        Query, SkyhookDriver, make_store)
from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.store import OSDDown, PER_REQUEST_OVERHEAD_BYTES


def make_world(n=4000, n_osds=5, replicas=3, seed=0):
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32")), n, 64)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 10,
                                          max_object_bytes=8 << 12))
    table = {"x": rng.normal(size=n),
             "y": rng.integers(0, 1000, n).astype(np.int32)}
    return store, vol, omap, table


# -------------------------------------------------------------- put_batch
def test_put_batch_one_request_per_osd_and_same_bytes():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = [f"blob-{i}".encode() * 50 for i in range(len(names))]
    primaries = {store.cluster.primary(n) for n in names}

    store.fabric.reset()
    store.put_batch(names, blobs)
    batched = store.fabric.snapshot()

    store.delete(names[0])  # any state; rewrite per-object for comparison
    store.fabric.reset()
    for n, b in zip(names, blobs):
        store.put(n, b)
    per_obj = store.fabric.snapshot()

    assert per_obj["ops"] == len(names)
    assert batched["ops"] == len(primaries)
    assert batched["ops"] <= len(store.cluster.up_osds)
    assert batched["overhead_bytes"] == \
        batched["ops"] * PER_REQUEST_OVERHEAD_BYTES
    # payload accounting identical: same client bytes, same replication
    assert batched["client_tx"] == per_obj["client_tx"]
    assert batched["replica_bytes"] == per_obj["replica_bytes"]
    # every replica holds every object
    for n, b in zip(names, blobs):
        for osd_id in store.cluster.locate(n):
            assert store.osds[osd_id].data[n] == b


def test_put_batch_stamps_monotonic_versions():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    v1 = store.put_batch(names, [b"a"] * len(names))
    v2 = store.put_batch(names, [b"b"] * len(names))
    assert len(v1) == len(names) and len(set(v1)) == len(names)
    assert min(v2) > max(v1)  # strictly monotonic across writes
    for n, v in zip(names, v2):
        assert store.xattr(n)["version"] == v


def test_put_batch_replica_failover_mid_batch():
    """An entry OSD dies mid-batch (its batched request raises): those
    sub-writes must regroup onto the next replica and land, while the
    other groups stay batched."""
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = [f"v-{i}".encode() * 20 for i in range(len(names))]
    primaries = {store.cluster.primary(n) for n in names}
    victim = store.cluster.primary(names[0])

    calls = {"n": 0}
    real = store.osds[victim].put_batch

    def flaky(items, **kw):
        if calls["n"] == 0:  # dies on the first batched request only
            calls["n"] += 1
            raise OSDDown(victim)
        return real(items, **kw)

    store.osds[victim].put_batch = flaky
    store.fabric.reset()
    versions = store.put_batch(names, blobs)
    # one request per primary + one retry round for the victim's group
    assert store.fabric.ops == len(primaries) + 1
    assert len(versions) == len(names)
    # every object is fully replicated with the right content, including
    # on the victim (the retry's server-side fan-out wrote it back)
    for n, b in zip(names, blobs):
        for osd_id in store.cluster.locate(n):
            assert store.osds[osd_id].data[n] == b


def test_put_batch_failover_on_failed_osd():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    store.fail_osd(store.cluster.primary(names[0]))
    store.put_batch(names, [b"x" * 64] * len(names))
    for n in names:
        assert store.get(n) == b"x" * 64


def test_put_batch_partial_land_then_die_keeps_landed_accounting():
    """The entry OSD lands part of its batch then dies: the landed
    sub-writes keep their success (their replica fan-out is already in
    flight) and only the unlanded remainder fails over, so payload
    accounting stays exact — each object's bytes cross the NIC once and
    are replicated exactly (replicas - 1) times."""
    store, vol, omap, table = make_world()
    names = omap.object_names()
    blobs = [f"w-{i}".encode() * 25 for i in range(len(names))]
    by_primary = {}
    for n in names:
        by_primary.setdefault(store.cluster.primary(n), []).append(n)
    victim, group = max(by_primary.items(), key=lambda kv: len(kv[1]))
    assert len(group) >= 2  # need landed AND unlanded sub-writes

    real = store.osds[victim].put_batch
    died = {"yet": False}

    def dies_midway(items, stream=None, landed=None):
        if died["yet"]:
            return real(items, stream=stream, landed=landed)
        died["yet"] = True
        real(items[:1], stream=stream, landed=landed)  # first one lands
        raise OSDDown(victim)

    store.osds[victim].put_batch = dies_midway
    store.fabric.reset()
    store.put_batch(names, blobs)
    for n, b in zip(names, blobs):
        for osd_id in store.cluster.locate(n):
            assert store.osds[osd_id].data[n] == b
    payload = sum(len(b) for b in blobs)
    assert store.fabric.client_tx == payload
    assert store.fabric.replica_bytes == \
        payload * (store.cluster.replicas - 1)


def test_put_batch_length_mismatch_raises():
    store, vol, omap, table = make_world()
    with pytest.raises(ValueError):
        store.put_batch(["a", "b"], [b"1"])


def test_vol_write_ingest_costs_one_request_per_osd():
    store, vol, omap, table = make_world()
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    assert omap.n_objects > len(primaries)  # N > K or the claim is vacuous
    store.fabric.reset()
    vol.write(omap, table)
    assert store.fabric.ops == len(primaries)
    # and the data reads back exactly
    from repro.core import RowRange
    out = vol.read(omap, RowRange(0, omap.dataset.n_rows))
    assert np.allclose(out["x"], table["x"])
    assert np.array_equal(out["y"], table["y"])


# ------------------------------------------------------- per-OSD combine
ALL_TAILS = [("agg", fn) for fn in ("sum", "count", "min", "max", "mean")]


@pytest.mark.parametrize("tail,fn", ALL_TAILS)
def test_exec_combine_equals_client_side_combine(tail, fn):
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("filter", col="y", cmp="<", value=500),
           oc.op(tail, col="x", fn=fn)]
    per_object = store.exec_batch(names, ops)
    merged = store.exec_combine(names, ops)
    # one partial per OSD, not per object
    primaries = {store.cluster.primary(n) for n in names}
    assert len(merged) <= len(primaries) < len(per_object)
    assert oc.combine_partials(ops, merged) == pytest.approx(
        oc.combine_partials(ops, per_object), rel=1e-12)


def test_exec_combine_quantile_sketch_tail():
    store, vol, omap, table = make_world(n=30_000)
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("quantile_sketch", col="x", lo=-6.0, hi=6.0)]
    merged = store.exec_combine(names, ops)
    per_object = store.exec_batch(names, ops)
    assert oc.combine_partials(ops, merged) == pytest.approx(
        oc.combine_partials(ops, per_object), rel=1e-12)


def test_exec_combine_client_rx_is_o_k():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("agg", col="x", fn="mean")]
    primaries = {store.cluster.primary(n) for n in names}

    store.fabric.reset()
    store.exec_combine(names, ops)
    combined = store.fabric.snapshot()
    store.fabric.reset()
    store.exec_batch(names, ops)
    batched = store.fabric.snapshot()

    assert combined["ops"] == batched["ops"] == len(primaries)
    # rx shrinks from one partial per OBJECT to one per OSD; same scan
    assert combined["client_rx"] == len(primaries) * 16  # {sum,count} f64
    assert batched["client_rx"] == len(names) * 16
    assert combined["local_bytes"] == batched["local_bytes"]


def test_exec_combine_failover_to_replica_mid_batch():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    ops = [oc.op("agg", col="x", fn="sum")]
    expect = oc.combine_partials(ops, store.exec_combine(names, ops))
    # primary silently lost one object: its partial must come from a
    # replica (as a second, batched, request) and the total must match
    victim = names[0]
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].data[victim]
    store.fabric.reset()
    merged = store.exec_combine(names, ops)
    primaries = {store.cluster.primary(n) for n in names}
    assert store.fabric.ops == len(primaries) + 1  # + one retry request
    assert oc.combine_partials(ops, merged) == pytest.approx(expect,
                                                             rel=1e-12)


def test_exec_combine_raises_when_all_replicas_lost():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    name = omap.object_names()[0]
    for osd in store.osds.values():
        with osd.lock:
            osd.data.pop(name, None)
    with pytest.raises(KeyError):
        store.exec_combine([name], [oc.op("agg", col="x", fn="sum")])


def test_exec_combine_rejects_non_mergeable_pipeline():
    store, vol, omap, table = make_world()
    with pytest.raises(ValueError):
        store.exec_combine(omap.object_names(),
                           [oc.op("median", col="x")])


def test_query_and_driver_use_per_osd_combine():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    res, stats = vol.query(omap, [oc.op("agg", col="x", fn="sum")])
    assert res == pytest.approx(table["x"].sum(), rel=1e-12)
    assert stats["ops"] <= len(primaries)
    assert stats["client_rx"] <= len(primaries) * 16

    drv = SkyhookDriver(vol, n_workers=3)
    r, s = drv.execute(Query("t", filter=("y", "<", 500),
                             aggregate=("mean", "x")))
    assert r == pytest.approx(table["x"][table["y"] < 500].mean(),
                              rel=1e-12)
    assert s.fabric_ops <= len(primaries)
    assert s.client_rx_bytes <= len(primaries) * 16


# --------------------------------------------------- zone-map metadata
def test_list_zone_maps_batches_and_fails_over():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    names = omap.object_names()
    primaries = {store.cluster.primary(n) for n in names}

    store.fabric.reset()
    infos = store.list_zone_maps(names)
    assert store.fabric.xattr_ops == len(primaries)  # one per OSD, not N
    assert set(infos) == set(names)
    for n in names:
        assert infos[n]["zone_map"] == store.xattr(n)["zone_map"]
        assert infos[n]["version"] == store.xattr(n)["version"]

    # primary lost one object's xattr: the listing fails over
    victim = names[0]
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].xattrs[victim]
    store.fabric.reset()
    infos = store.list_zone_maps(names)
    assert set(infos) == set(names)
    assert store.fabric.xattr_ops == len(primaries) + 1  # + retry request

    # an object with no xattr anywhere is simply absent
    assert "nowhere" not in store.list_zone_maps(["nowhere"])


def test_plan_warms_cache_in_k_requests():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    fresh = GlobalVOL(store)
    store.fabric.reset()
    fresh.plan(omap, [oc.op("filter", col="y", cmp="<", value=500),
                      oc.op("agg", col="x", fn="sum")])
    assert store.fabric.xattr_ops <= len(primaries)
    assert store.fabric.xattr_ops < omap.n_objects


# --------------------------------------------- cross-client coherence
# (the client-side prune plane: pinned to prune="client" — under the
# default pushed-down prune the OSD always sees its own CURRENT zone
# maps, so there is no cache to go stale; tests/test_scan.py covers
# that side of the symmetry)
def test_two_client_stale_zone_map_caught_by_version_tag():
    """Client A warms its zone-map cache; client B rewrites the data at
    the SAME cluster epoch.  A's next plan must revalidate its
    prune-positive objects against the bumped version tags and un-prune
    the rewritten objects — the stale-prune hazard PR 1 documented."""
    store, vol_a, omap, table = make_world()
    vol_b = GlobalVOL(store)
    vol_a.write(omap, table)

    impossible = [oc.op("filter", col="y", cmp=">", value=2000),
                  oc.op("agg", col="x", fn="count")]
    res, stats = vol_a.query(omap, impossible, prune="client")
    assert res == 0.0 and stats["objects_pruned"] == omap.n_objects

    # client B (same epoch!) rewrites with values that DO match
    assert store.cluster.epoch == 0
    table2 = dict(table, y=(table["y"] + 5000).astype(np.int32))
    vol_b.write(omap, table2)
    assert store.cluster.epoch == 0  # no epoch bump to hide behind

    res2, stats2 = vol_a.query(omap, impossible, prune="client")
    assert res2 == float(len(table2["y"]))  # stale prune would say 0
    assert stats2["objects_pruned"] == 0


def test_revalidated_unprune_preserves_row_order():
    """A revalidation un-prune must slot the object back at its row
    position, not append it — table-out gathers concat in plan order."""
    store, vol_a, omap, table = make_world()
    vol_b = GlobalVOL(store)
    vol_a.write(omap, table)
    # make object 0 (rows at the FRONT) prune-positive for client A
    flt = [oc.op("filter", col="y", cmp="<", value=20_000)]
    first = omap.extents[0]
    low = dict(table)
    low["y"] = table["y"].copy()
    low["y"][first.row_start:first.row_stop] = 50_000  # prunes under flt
    vol_a.write(omap, low)
    plan_a = vol_a.plan(omap, flt)
    assert plan_a.pruned == (first.name,)
    # client B rewrites everything back so nothing should prune
    vol_b.write(omap, table)
    out, _ = vol_a.query(omap, flt, prune="client")  # table-out pipeline
    assert np.array_equal(out["y"], table["y"])  # rows in ROW order


def test_version_revalidation_costs_only_k_requests():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    primaries = {store.cluster.primary(n) for n in omap.object_names()}
    impossible = [oc.op("filter", col="y", cmp=">", value=2000),
                  oc.op("agg", col="x", fn="count")]
    vol.query(omap, impossible, prune="client")  # warm; everything prunes
    store.fabric.reset()
    vol.query(omap, impossible, prune="client")
    # the repeat query pays ONLY the prune revalidation: <= K metadata
    # requests, zero data requests (everything still prunes)
    assert store.fabric.xattr_ops <= len(primaries)
    assert store.fabric.ops == 0


def test_unpruned_scan_needs_no_revalidation():
    store, vol, omap, table = make_world()
    vol.write(omap, table)
    nothing_prunes = [oc.op("filter", col="y", cmp="<", value=2000),
                      oc.op("agg", col="x", fn="count")]
    vol.query(omap, nothing_prunes, prune="client")
    store.fabric.reset()
    vol.query(omap, nothing_prunes, prune="client")
    assert store.fabric.xattr_ops == 0  # kept objects revalidate for free


# --------------------------------------------- consumers of put_batch
def test_checkpoint_save_writes_in_k_requests_per_leaf():
    from repro.checkpoint import ckpt
    store = make_store(4, replicas=2)
    state = {"w": np.arange(4096, dtype=np.float32),
             "b": np.ones(128, dtype=np.float32)}
    store.fabric.reset()
    ckpt.save(store, state, step=10,
              policy=PartitionPolicy(target_object_bytes=2 << 10,
                                     max_object_bytes=2 << 10))
    # each leaf's objects ride one batched request per OSD (2 leaves
    # here) + 1 manifest put — not one request per object
    n_objects = len(store.list_objects("ckpt/")) - 1
    k = len(store.cluster.up_osds)
    assert store.fabric.ops <= 2 * k + 1
    assert n_objects > k  # the claim is non-vacuous
    restored, _ = ckpt.restore(store, state, step=10)
    assert np.array_equal(restored["w"], state["w"])
    assert np.array_equal(restored["b"], state["b"])


# --------------------------------------------- device bitunpack routing
def test_device_bitunpack_bit_exact_vs_numpy():
    jax = pytest.importorskip("jax")
    del jax
    from repro.kernels.bitunpack import bitunpack_words
    rng = np.random.default_rng(7)
    for bits in (1, 7, 13, 17):
        for n in (0, 1, 31, 32, 129, 1000, 4096):
            v = rng.integers(0, 1 << bits, n).astype(np.uint32)
            words = fmt.bitpack_encode(v, bits)
            got = bitunpack_words(words, bits, n, interpret=True)
            assert np.array_equal(got, fmt.bitpack_decode(words, bits, n))


def test_run_pipeline_with_device_bitunpack_backend():
    pytest.importorskip("jax")
    rng = np.random.default_rng(11)
    table = {"a": rng.integers(0, 1 << 9, 500).astype(np.int32),
             "b": rng.normal(size=500)}
    blob = fmt.encode_block(table, codecs={"a": "bitpack9"})
    ops = [oc.op("filter", col="a", cmp=">=", value=100),
           oc.op("agg", col="b", fn="sum")]
    expect = oc.run_pipeline(blob, ops)
    fmt.set_bitunpack_backend("device")  # interpret-mode Pallas on CPU
    try:
        got = oc.run_pipeline(blob, ops)
        dec = fmt.decode_block(blob)
    finally:
        fmt.set_bitunpack_backend("auto")
    assert float(got["sum"]) == float(expect["sum"])
    assert np.array_equal(dec["a"], table["a"])


def test_unpack_tokens_pallas_matches_reference():
    pytest.importorskip("jax")
    from repro.data.fused_ingest import pack_batch, unpack_tokens
    rng = np.random.default_rng(13)
    toks = rng.integers(0, 1 << 11, (4, 128)).astype(np.int32)
    packed = pack_batch(toks, 11)
    ref = np.asarray(unpack_tokens(packed))
    pal = np.asarray(unpack_tokens(packed, use_pallas=True,
                                   interpret=True))
    assert np.array_equal(ref, toks)
    assert np.array_equal(pal, toks)
