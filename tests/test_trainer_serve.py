"""Trainer loop (ckpt/restart determinism, stragglers) + serving."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import GlobalVOL, make_store
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.optimizer import OptConfig
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig


@pytest.fixture(scope="module")
def world():
    store = make_store(5, replicas=2)
    vol = GlobalVOL(store)
    build_corpus(vol, CorpusSpec(n_seqs=128, seq_len=64,
                                 vocab_size=256, seed=1))
    return store, vol


def mk_trainer(store, vol, total=8, ckpt_every=4, packed=False):
    cfg = get_config("yi_9b", smoke=True)
    model = build_model(cfg, remat="none")
    loader = ObjectDataLoader(vol, "corpus", global_batch=8, seed=3,
                              prefetch=0, packed=packed)
    return Trainer(model, loader, store,
                   opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                   cfg=TrainerConfig(total_steps=total,
                                     ckpt_every=ckpt_every, log_every=100,
                                     packed_ingest=packed),
                   log=lambda s: None)


def test_loss_decreases_and_restart_is_bit_deterministic(world):
    store, vol = world
    tr = mk_trainer(store, vol)
    state = tr.run()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]

    tr2 = mk_trainer(store, vol)
    st2, start = tr2.init_or_restore()
    assert start == 8
    # wipe checkpoints except step 4, rerun 4..8, compare params exactly
    for name in store.list_objects("ckpt/train/step-8/"):
        store.delete(name)
    tr3 = mk_trainer(store, vol)
    st3, start3 = tr3.init_or_restore()
    assert start3 == 4
    st3 = tr3.run(st3, start_step=4)
    a = np.asarray(jax.tree.leaves(state["params"])[0])
    b = np.asarray(jax.tree.leaves(st3["params"])[0])
    np.testing.assert_array_equal(a, b)


def test_packed_ingest_training(world):
    store, vol = world
    for name in store.list_objects("ckpt/"):
        store.delete(name)
    tr = mk_trainer(store, vol, total=4, ckpt_every=100, packed=True)
    tr.run()
    assert np.isfinite(tr.history[-1]["loss"])


def test_straggler_monitor_flags_spikes():
    mon = StragglerMonitor(alpha=0.5, factor=2.0)
    assert not mon.observe(0.1)
    assert not mon.observe(0.11)
    assert mon.observe(0.5)
    assert mon.flagged == 1


def test_serve_generate_and_park_resume(world):
    store, vol = world
    cfg = get_config("yi_9b", smoke=True)
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_seq=96, store=store)
    comps = eng.generate([Request(np.arange(6, dtype=np.int32) + 1,
                                  max_new=5)])
    assert comps[0].tokens.shape == (5,)
    eng.park_session("sess")
    cache = eng.resume_session("sess", batch=1)
    parked = jax.tree.map(np.asarray, eng._last_cache)
    resumed = jax.tree.map(np.asarray, cache)
    for a, b in zip(jax.tree.leaves(parked), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(a, b)


def test_serve_eos_stops_early(world):
    store, vol = world
    cfg = get_config("yi_9b", smoke=True)
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_seq=64)
    comps = eng.generate([Request(np.arange(4, dtype=np.int32) + 1,
                                  max_new=8, eos_id=None)])
    assert comps[0].steps <= 8
