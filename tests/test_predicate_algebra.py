"""Expression-tree predicate plane: OR/IN/NOT/Between/StrPrefix
evaluated ON the OSDs, sound interval pruning shared bit-exactly by the
client planner and the pushed-down strategy, OSD-resolved row ranges
(``row_slice``), and the single comparator table all three layers
derive from.  Property tests ride the hypothesis shim (they skip
cleanly when hypothesis is missing)."""

import json

import numpy as np
import pytest

from repro.core import (Column, GlobalVOL, LogicalDataset, PartitionPolicy,
                        RowRange, SkyhookDriver, make_store)
from repro.core import expr as ex
from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core import scan as sc
from repro.core.store import OSD
from tests._hyp import given, settings, st


def make_world(n=4000, n_osds=5, replicas=3, seed=0, sorted_cols=False):
    """A dataset with a float, an int, and a STRING column; with
    ``sorted_cols`` the int/string columns are written in ascending
    order so every object's zone map is a tight interval (what makes
    Or-of-disjoint-ranges pruning observable)."""
    rng = np.random.default_rng(seed)
    ds = LogicalDataset(
        "t", (Column("x", "float64"), Column("y", "int32"),
              Column("tag", "<U8")), n, 64)
    store = make_store(n_osds, replicas=replicas)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=8 << 10,
                                          max_object_bytes=8 << 12))
    y = (np.arange(n) * 1000 // n if sorted_cols
         else rng.integers(0, 1000, n)).astype(np.int32)
    tag = np.array([f"s{v:06d}" for v in
                    (np.arange(n) if sorted_cols
                     else rng.integers(0, n, n))], dtype="<U8")
    table = {"x": rng.normal(size=n), "y": y, "tag": tag}
    vol.write(omap, table)
    return store, vol, omap, table


# ------------------------------------------------------- end-to-end eval
def _cases(table):
    """(builder, row mask) pairs covering every expression node."""
    y, tag = table["y"], table["tag"]
    return [
        (lambda s: s.or_(("y", "<", 50), ("y", ">", 950)),
         (y < 50) | (y > 950)),
        (lambda s: s.isin("y", [3, 5, 7, 500]),
         np.isin(y, [3, 5, 7, 500])),
        (lambda s: s.filter_expr(ex.Not(ex.Cmp("y", "<", 500))),
         ~(y < 500)),
        (lambda s: s.filter_expr(ex.Between("y", 100, 200)),
         (y >= 100) & (y <= 200)),
        (lambda s: s.filter_expr(ex.StrPrefix("tag", "s000")),
         np.char.startswith(tag, "s000")),
        (lambda s: s.filter_expr(
            ex.Or((ex.And((ex.Cmp("y", ">", 100), ex.Cmp("y", "<", 200))),
                   ex.Cmp("y", "==", 7))) & ex.Cmp("x", ">", 0.0)),
         (((y > 100) & (y < 200)) | (y == 7)) & (table["x"] > 0)),
    ]


def test_expression_scans_match_client_filtering_bit_exact():
    """Every expression node, through the pushed-down plane vs the
    no-pushdown client baseline vs prune='none': identical rows."""
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    for build, mask in _cases(table):
        s = build(vol.scan("t")).project("x", "y")
        r_push, st_push = s.execute()
        r_none, _ = s.prune("none").execute()
        r_base, _ = drv.execute_client_side(build(drv.scan("t"))
                                            .project("x", "y"))
        for k in ("x", "y"):
            assert np.array_equal(r_push[k], table[k][mask])
            assert np.array_equal(r_none[k], table[k][mask])
            assert np.array_equal(r_base[k], table[k][mask])
        assert st_push["prune"] == "pushdown"


def test_or_in_scan_zero_zone_map_requests_and_k_frames():
    """The acceptance claim: an OR-group/IN-list scan with pushed-down
    pruning issues ZERO client zone-map requests and returns exactly K
    framed responses for K involved OSDs — even for a cold client."""
    store, vol, omap, table = make_world()
    primaries = {store.cluster.primary(e.name) for e in omap}
    assert omap.n_objects > len(primaries)  # N > K or the claim is weak
    fresh = GlobalVOL(store)
    store.fabric.reset()
    res, stats = (fresh.scan("t").or_(("y", "<", 50), ("y", ">", 950))
                  .isin("tag", ["s000003"]).project("x").execute(omap))
    mask = ((table["y"] < 50) | (table["y"] > 950)) \
        & np.isin(table["tag"], ["s000003"])
    assert np.array_equal(res["x"], table["x"][mask])
    assert store.fabric.xattr_ops == 0
    assert stats["rx_frames"] == len(primaries)
    assert stats["ops"] == len(primaries)
    assert stats["prune"] == "pushdown"


def test_driver_schedules_expression_scans():
    store, vol, omap, table = make_world()
    drv = SkyhookDriver(vol, n_workers=3)
    r, qs = drv.execute(drv.scan("t").or_(("y", "<", 10), ("y", ">", 990))
                        .agg("sum", "x"))
    mask = (table["y"] < 10) | (table["y"] > 990)
    assert r == pytest.approx(table["x"][mask].sum(), rel=1e-12)
    assert qs.prune == "pushdown"
    assert qs.exec_class == sc.EXEC_OSD_COMBINE


# ------------------------------------------------------- prune algebra
def test_or_of_disjoint_ranges_prunes_what_a_conjunction_cannot():
    """With sorted data every object's zone is a tight slice of the
    value space: Or(y<lo, y>hi) provably empties every MIDDLE object —
    a set no flat conjunction could prune — and both strategies prune
    the identical set."""
    store, vol, omap, table = make_world(sorted_cols=True)
    pred = ex.Or((ex.Cmp("y", "<", 100), ex.Cmp("y", ">", 900)))
    # ground truth from the stored zone maps themselves
    expect_pruned = sum(
        1 for e in omap
        if oc.zone_map_prunes(store.xattr(e.name)["zone_map"], pred))
    assert 0 < expect_pruned < omap.n_objects
    s = vol.scan("t").filter_expr(pred).agg("count", "x")
    r_osd, st_osd = s.execute()
    r_cli, st_cli = s.prune("client").execute()
    mask = (table["y"] < 100) | (table["y"] > 900)
    assert r_osd == r_cli == float(mask.sum())
    assert st_osd["objects_pruned"] == st_cli["objects_pruned"] \
        == expect_pruned
    # a middle object prunes because BOTH disjuncts empty it — the Or
    # rule (ALL children prune) at work; the flat plane could not even
    # express this query's rows as a conjunction
    mid = omap.extents[omap.n_objects // 2]
    zm = store.xattr(mid.name)["zone_map"]
    assert pred.prunes(zm)
    assert ex.Cmp("y", "<", 100).prunes(zm)
    assert ex.Cmp("y", ">", 900).prunes(zm)


def test_in_list_and_neq_prune_both_strategies_identically():
    store, vol, omap, table = make_world(sorted_cols=True)
    # IN-list wholly outside every zone: everything prunes, zero rows
    s = vol.scan("t").isin("y", [5000, 6000]).agg("count", "y")
    r_osd, st_osd = s.execute()
    r_cli, st_cli = s.prune("client").execute()
    assert r_osd == r_cli == 0.0
    assert st_osd["objects_pruned"] == st_cli["objects_pruned"] \
        == omap.n_objects
    # != prunes only constant zones (lo == value == hi)
    ds = LogicalDataset("const", (Column("y", "int32"),), 256, 8)
    vol2 = GlobalVOL(make_store(3, replicas=2))
    omap2 = vol2.create(ds, PartitionPolicy(target_object_bytes=256,
                                            max_object_bytes=1024))
    vol2.write(omap2, {"y": np.full(256, 7, np.int32)})
    r, stats = vol2.scan("const").filter("y", "!=", 7) \
                   .agg("count", "y").execute()
    assert r == 0.0
    assert stats["objects_pruned"] == omap2.n_objects
    r2, stats2 = (vol2.scan("const").filter("y", "!=", 8)
                  .agg("count", "y").execute())
    assert r2 == 256.0 and stats2["objects_pruned"] == 0


def test_str_prefix_prunes_on_string_zone_maps():
    store, vol, omap, table = make_world(sorted_cols=True)
    zm = store.xattr(omap.extents[0].name)["zone_map"]
    lo, hi = zm["tag"]
    assert isinstance(lo, str) and isinstance(hi, str)  # string bounds
    s = (vol.scan("t").filter_expr(ex.StrPrefix("tag", "s0000"))
         .project("tag"))
    r_osd, st_osd = s.execute()
    r_cli, st_cli = s.prune("client").execute()
    mask = np.char.startswith(table["tag"], "s0000")
    assert np.array_equal(r_osd["tag"], table["tag"][mask])
    assert np.array_equal(r_cli["tag"], table["tag"][mask])
    assert st_osd["objects_pruned"] == st_cli["objects_pruned"] > 0


def test_not_pushdown_prunes_residual_not_stays_conservative():
    store, vol, omap, table = make_world(sorted_cols=True)
    # ~(y < 5000) matches nothing; the prune payload is normalized
    # (De Morgan push-down), so it ships as y >= 5000 and every zone
    # map NOW proves its object empty — zero rows AND full pruning
    r, stats = (vol.scan("t").filter_expr(ex.Not(ex.Cmp("y", "<", 5000)))
                .agg("count", "y").execute())
    assert r == 0.0
    assert stats["objects_pruned"] == omap.n_objects
    # a negation normalize can't push down (Not over a non-empty In)
    # still never prunes — conservative: zero pruned, zero rows
    r, stats = (vol.scan("t")
                .filter_expr(ex.Not(ex.In("y", list(range(1000)))))
                .agg("count", "y").execute())
    assert r == 0.0
    assert stats["objects_pruned"] == 0
    assert stats["objects_touched"] == omap.n_objects


def test_legacy_triple_prune_payloads_still_work():
    store, vol, omap, table = make_world()
    names = omap.object_names()
    ops = [oc.op("filter", col="y", cmp=">", value=5000),
           oc.op("agg", col="y", fn="count")]
    partials, pruned = store.exec_combine(
        names, ops, prune=(("y", ">", 5000),))
    assert not partials and set(pruned) == set(names)


# ------------------------------------------------------- row_slice plane
def _repartition_world():
    ds = LogicalDataset("rp", (Column("v", "int64"),), 200, 1)
    store = make_store(3, replicas=2)
    vol = GlobalVOL(store)
    omap = vol.create(ds, PartitionPolicy(target_object_bytes=800,
                                          max_object_bytes=1600))
    assert omap.n_objects == 2  # [0,100) and [100,200)
    v = np.arange(200, dtype=np.int64)
    vol.write(omap, {"v": v})
    return store, vol, omap, v


def _reput(store, vol, name, v, start, stop):
    part = {"v": v[start:stop]}
    store.put(name, vol.local.encode(part),
              {"zone_map": fmt.zone_map(part), "rows": [start, stop]})


def test_row_slice_resolves_against_current_extents():
    """The pushed-down row range: one compiled plan keeps serving the
    requested GLOBAL rows after the dataset is re-partitioned under it,
    because each OSD resolves the slice against its objects' CURRENT
    extent xattrs — not against the plan-time ObjectMap."""
    store, vol, omap, v = _repartition_world()
    a, b = omap.object_names()
    s = vol.scan("rp").rows(50, 150).project("v")
    plan = s.explain(omap)
    r0, _ = vol.engine.execute(plan)
    assert np.array_equal(r0["v"], v[50:150])
    # re-partition under the plan: boundary moves 100 -> 120
    _reput(store, vol, a, v, 0, 120)
    _reput(store, vol, b, v, 120, 200)
    r1, _ = vol.engine.execute(plan)
    # plan-time extents would have served v[50:100] + v[120:170]
    assert np.array_equal(r1["v"], v[50:150])


def test_row_slice_disjoint_extent_is_prune_equivalent():
    store, vol, omap, v = _repartition_world()
    a, b = omap.object_names()
    plan = vol.scan("rp").rows(0, 60).project("v").explain(omap)
    assert plan.names == (a,)  # compile-time targeting
    # swap the two objects' contents/extents under the compiled plan
    _reput(store, vol, a, v, 100, 200)
    _reput(store, vol, b, v, 0, 100)
    r, stats = vol.engine.execute(plan)
    assert r == {} or oc.table_n_rows(r) == 0
    assert stats["objects_pruned"] == 1
    assert stats["objects_touched"] == 0


def test_rows_aggregate_rides_combine_plane_zero_metadata():
    store, vol, omap, table = make_world()
    fresh = GlobalVOL(store)
    store.fabric.reset()
    s = (fresh.scan("t").rows(100, 2500).filter("y", "<", 500)
         .agg("sum", "x"))
    plan = s.explain(omap)
    assert plan.exec_cls == sc.EXEC_OSD_COMBINE
    assert plan.prune == "pushdown"
    assert plan.pipelines is None
    r, stats = fresh.engine.execute(plan)
    mask = table["y"][100:2500] < 500
    assert r == pytest.approx(table["x"][100:2500][mask].sum(), rel=1e-12)
    # still zero ZONE-MAP traffic; the single op is the row-slice
    # targeting refresh probing the .objmap version (a standalone
    # execute has no caller-held ObjectMap to vouch for currency —
    # every vol/scan/driver front end passes one and stays at zero)
    assert store.fabric.xattr_ops == 1


def test_row_sliced_scan_fails_over_to_replica():
    """An object missing from its primary must register as MISSING (and
    fail over to a replica) even though the pipeline carries a
    row_slice — absence is checked before extent resolution."""
    store, vol, omap, table = make_world(n_osds=4, replicas=3)
    victim = omap.extents[0].name
    primary = store.cluster.primary(victim)
    with store.osds[primary].lock:
        del store.osds[primary].data[victim]
        del store.osds[primary].xattrs[victim]
    out = vol.read(omap, RowRange(0, 1500), columns=["y"])
    assert np.array_equal(out["y"], table["y"][:1500])
    r, _ = (vol.scan("t").rows(0, 1500).filter("y", "<", 500)
            .agg("count", "y").execute())
    assert r == float((table["y"][:1500] < 500).sum())


def test_rows_past_dataset_end_is_empty_not_an_error():
    store, vol, omap, table = make_world()
    n = len(table["y"])
    r, stats = (vol.scan("t").rows(n + 200, n + 300)
                .agg("count", "y").execute())
    assert r == 0.0 and stats["objects_touched"] == 0
    out = vol.read(omap, RowRange(n + 200, n + 300), columns=["y"])
    assert out == {} or oc.table_n_rows(out) == 0


def test_row_slice_requires_extent_xattr():
    store = make_store(2, replicas=2)
    blob = fmt.encode_block({"v": np.arange(10)})
    store.put("bare", blob)  # no 'rows' xattr
    with pytest.raises(ValueError, match="extent"):
        store.exec("bare", [oc.op("row_slice", rows=(0, 5))])


def test_unresolved_row_slice_refuses_to_run():
    blob = fmt.encode_block({"v": np.arange(10)})
    with pytest.raises(ValueError, match="resolve"):
        oc.run_pipeline(blob, [oc.op("row_slice", rows=(0, 5))])
    resolved = oc.resolve_row_slice(
        [oc.op("row_slice", rows=(3, 30))], (5, 15))
    assert resolved[0].name == "select"
    assert resolved[0].params["rows"] == (0, 10)
    assert oc.resolve_row_slice(
        [oc.op("row_slice", rows=(20, 30))], (5, 15)) is None
    clamped = oc.resolve_row_slice(
        [oc.op("row_slice", rows=(20, 30))], (5, 15), clamp=True)
    assert clamped[0].params["rows"] == (0, 0)


def test_partial_gather_refuses_explicit_pushdown():
    """Every BUILT-IN partial tail is mergeable now that row ranges
    ride the shared row_slice plane, so partial-gather only exists for
    extension ops whose tail has a combine but no associative merge.
    Register one: its positional responses carry no OSD prune info, so
    an EXPLICIT prune='pushdown' must refuse (not silently downgrade
    to the TOCTOU-prone client strategy), while 'auto' serves it via
    the client planner."""
    if "sum_nomerge" not in oc.registered_ops():
        oc.register("sum_nomerge", oc.OpImpl(
            lambda table, col: {"sum": np.asarray(
                table[col], np.float64).sum()},
            lambda parts, col: float(sum(p["sum"] for p in parts)),
            decomposable=True, table_out=False))  # merge=None
    store, vol, omap, table = make_world()
    ops = [oc.op("filter", expr=ex.Cmp("y", "<", 500).to_json()),
           oc.op("sum_nomerge", col="x")]
    plan = vol.engine.compile_ops(omap, ops)
    assert plan.exec_cls == sc.EXEC_PARTIAL_GATHER
    assert plan.prune == "client"  # auto fell back to the planner
    r, stats = vol.engine.execute(plan)
    assert r == pytest.approx(
        table["x"][table["y"] < 500].sum(), rel=1e-12)
    assert stats["exec_class"] == sc.EXEC_PARTIAL_GATHER
    with pytest.raises(ValueError, match="partial-gather"):
        vol.engine.compile_ops(omap, ops, prune="pushdown")


# ------------------------------------------------- one comparator table
def test_comparator_table_is_the_single_source():
    """scan validation, OSD evaluation, and the prune rule all derive
    from expr.CMP_TABLE; a half-defined comparator cannot exist."""
    assert ex.COMPARATORS == tuple(ex.CMP_TABLE)
    with pytest.raises(TypeError):
        ex.Comparator(np.less)  # no prune rule: unregisterable
    with pytest.raises(ValueError):
        ex.Cmp("y", "~", 1)  # unknown comparator refused at construction
    from repro.core import Scan
    with pytest.raises(ValueError):
        Scan(dataset="t").filter("y", "~", 1)
    table = {"y": np.arange(10)}
    for cmp in ex.COMPARATORS:
        leaf = ex.Cmp("y", cmp, 5)
        mask = leaf.mask(table)
        assert mask.dtype == np.bool_ and mask.shape == (10,)
        # every registered comparator has a (sound) prune answer — no
        # silent never-prune for operators outside a hand-written chain
        assert isinstance(leaf.prunes({"y": [0, 4]}), bool)
    assert ex.Cmp("y", "!=", 5).prunes({"y": [5, 5]})
    assert not ex.Cmp("y", "!=", 5).prunes({"y": [4, 5]})


def test_expression_wire_form_roundtrips_and_is_json():
    tree = ex.Or((
        ex.And((ex.Cmp("a", "<", 3), ex.In("b", (1, 2, np.int32(3))))),
        ex.Not(ex.Between("a", 0, 9)),
        ex.StrPrefix("s", "pre")))
    wire = tree.to_json()
    json.dumps(wire)  # numpy scalars normalized: actually serializable
    back = ex.from_json(wire)
    assert back.columns() == tree.columns() == frozenset({"a", "b", "s"})
    zm = {"a": [5, 6], "b": [9, 9], "s": ["zzz", "zzz"]}
    assert back.prunes(zm) == tree.prunes(zm)
    with pytest.raises(ValueError):
        ex.from_json({"t": "nope"})
    with pytest.raises(ValueError):
        ex.And(())
    with pytest.raises(TypeError):
        ex.ensure(42)


def test_builder_expression_validation():
    from repro.core import Scan
    s = Scan(dataset="t")
    with pytest.raises(ValueError):
        s.or_(("y", "<", 1))  # one alternative is not an OR
    two = s.or_(("y", "<", 1), ex.Cmp("y", ">", 9))
    assert isinstance(two.predicate, ex.Or)
    chained = two.filter("x", ">", 0.0).isin("y", [1, 2])
    assert isinstance(chained.predicate, ex.And)
    assert len(chained.predicate.children) == 3  # flat conjunction


# ------------------------------------------------- soundness property
_cols = ("a", "b")
_val = st.integers(-20, 20)
_leaf = st.one_of(
    st.tuples(st.sampled_from(_cols), st.sampled_from(ex.COMPARATORS),
              _val).map(lambda t: ex.Cmp(*t)),
    st.tuples(st.sampled_from(_cols),
              st.lists(_val, max_size=4)).map(
                  lambda t: ex.In(t[0], tuple(t[1]))),
    st.tuples(st.sampled_from(_cols), _val, _val).map(
        lambda t: ex.Between(t[0], min(t[1], t[2]), max(t[1], t[2]))))
_tree = st.recursive(_leaf, lambda ch: st.one_of(
    st.lists(ch, min_size=1, max_size=3).map(lambda l: ex.And(tuple(l))),
    st.lists(ch, min_size=1, max_size=3).map(lambda l: ex.Or(tuple(l))),
    ch.map(ex.Not)), max_leaves=10)
_zone = st.tuples(_val, _val).map(lambda t: [min(t), max(t)])
_zms = st.fixed_dictionaries({"a": _zone, "b": _zone})


@settings(max_examples=200, deadline=None)
@given(_zms, _tree)
def test_prune_soundness_and_strategy_parity(zm, tree):
    """For random zone maps and random expression trees: (1) the wire
    form is lossless, (2) pruned implies ZERO matching rows for any
    table whose values respect the zone bounds (soundness), and (3) the
    client planner's decision equals the OSD's on identical metadata —
    they are literally the same rule."""
    table = {k: np.concatenate(
        [np.array([lo, hi], dtype=np.float64), np.linspace(lo, hi, 9)])
        for k, (lo, hi) in zm.items()}
    wire = tree.to_json()
    assert ex.from_json(wire) == tree
    if oc.zone_map_prunes(zm, tree):         # client planner's call
        assert not tree.mask(table).any()    # ...must be sound
    osd = OSD("osd.prop")
    osd.xattrs["o"] = {"zone_map": zm}
    assert osd._prunes_locally("o", ex.ensure_pred(wire)) \
        == oc.zone_map_prunes(zm, tree)
