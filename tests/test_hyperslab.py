"""N-dimensional dataspaces: hyperslab selection pushdown.

Covers the new array plane end to end — Dataspace/Hyperslab math vs
numpy, chunk->object mapping, OSD-resolved ``hyperslab_slice`` (late
binding against the ``chunks`` xattr, so compiled plans survive
re-partitioning), per-chunk zone-map pruning, the N-d client assembly
— plus the serve-plane satellites that ride this PR: negative caching
of nothing-to-serve dispositions, predicate normalization, and modeled
per-hop replication latency.

The selection-equivalence property test uses hypothesis when installed
and degrades to a seeded random sweep (NOT a skip) otherwise, so the
coverage floor does not depend on an optional dev dependency.
"""

import time

import numpy as np
import pytest

from repro.core import (ArrayObjectMap, Cmp, Const, Dataspace, GlobalVOL,
                        Hyperslab, PartitionPolicy, make_store, normalize,
                        plan_array_partition)
from repro.core import expr as ex
from repro.core import format as fmt
from repro.core.cache import Negative, ResultCache, _MISS
from repro.core.logical import _axis_intersect
from repro.core.partition import load_objmap

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ModuleNotFoundError:
    HAVE_HYP = False


# --------------------------------------------------------------- helpers
def make_array_world(shape, chunk, *, dtype="int64", seed=0, n_osds=4,
                     target_bytes=4096, cache_bytes=1 << 20):
    rng = np.random.default_rng(seed)
    store = make_store(n_osds, replicas=2, cache_bytes=cache_bytes)
    vol = GlobalVOL(store)
    space = Dataspace(name="arr", shape=tuple(shape), dtype=dtype,
                      chunk=tuple(chunk))
    if np.issubdtype(np.dtype(dtype), np.integer):
        arr = rng.integers(0, 1000, size=shape).astype(dtype)
    else:
        arr = rng.normal(size=shape).astype(dtype)
    amap = vol.create_array(
        space, PartitionPolicy(target_object_bytes=target_bytes))
    vol.write_array(amap, arr)
    return store, vol, amap, arr


def brute_chunk_ids(space, hs):
    """Reference chunk cover: every chunk whose slab intersects."""
    out = []
    for cid in range(space.n_chunks):
        if hs.intersect_slab(space.chunk_slab(cid)) is not None:
            out.append(cid)
    return out


# ------------------------------------------------------- dataspace math
def test_dataspace_grid_and_slabs():
    sp = Dataspace(name="a", shape=(10, 7), dtype="int32", chunk=(4, 3))
    assert sp.grid == (3, 3) and sp.n_chunks == 9
    # row-major id <-> coords round trip
    for cid in range(sp.n_chunks):
        assert sp.chunk_id(sp.chunk_coords(cid)) == cid
    # slabs tile the shape exactly (clipped at the ragged edge)
    cover = np.zeros(sp.shape, dtype=np.int32)
    for cid in range(sp.n_chunks):
        slab = sp.chunk_slab(cid)
        cover[tuple(slice(a, b) for a, b in slab)] += 1
    assert (cover == 1).all()
    assert sp.chunk_slab(8) == ((8, 10), (6, 7))  # clipped corner
    # padded chunk payload size
    assert sp.chunk_nbytes == 4 * 3 * 4
    # round trip
    assert Dataspace.from_json(sp.to_json()) == sp


def test_dataspace_validation():
    with pytest.raises(ValueError):
        Dataspace(name="a", shape=(4, 0), dtype="int32", chunk=(2, 1))
    with pytest.raises(ValueError):
        Dataspace(name="a", shape=(4,), dtype="int32", chunk=(2, 2))
    with pytest.raises(ValueError):
        Dataspace(name="a", shape=(4,), dtype="int32", chunk=(0,))


def test_hyperslab_from_key_parsing():
    shape = (10, 8, 6)
    hs = Hyperslab.from_key(shape, np.s_[2:9:3, -5, ...])
    assert hs.starts == (2, 3, 0) and hs.stops == (9, 4, 6)
    assert hs.steps == (3, 1, 1) and hs.squeeze == (1,)
    # out_shape is the UNSQUEEZED selection box (assembly fills it,
    # then drops the squeeze axes last)
    assert hs.out_shape() == (3, 1, 6)
    # scalar / full-slice defaults and negative bounds
    hs2 = Hyperslab.from_key(shape, np.s_[:, -6:-1, 5])
    assert hs2.out_shape() == (10, 5, 1) and hs2.squeeze == (2,)
    with pytest.raises(ValueError):
        Hyperslab.from_key(shape, np.s_[::-1, :, :])  # negative step
    with pytest.raises(IndexError):
        Hyperslab.from_key(shape, np.s_[0, 0, 0, 0])  # too many axes
    with pytest.raises(IndexError):
        Hyperslab.from_key(shape, np.s_[10, :, :])    # out of range
    # squeeze axes survive the wire form (plan refresh recompiles
    # from JSON — losing them would change the result shape)
    back = Hyperslab.from_json(hs.to_json())
    assert back == hs and back.out_shape() == hs.out_shape()


def test_axis_intersect_against_brute_force(rng):
    for _ in range(300):
        s = int(rng.integers(0, 20))
        e = int(rng.integers(s + 1, 40))
        t = int(rng.integers(1, 7))
        c0 = int(rng.integers(0, 30))
        c1 = int(rng.integers(c0 + 1, 45))
        ref = [g for g in range(s, e, t) if c0 <= g < c1]
        got = _axis_intersect(s, e, t, c0, c1)
        if not ref:
            assert got is None
        else:
            first, hi, n = got
            assert first == ref[0] and n == len(ref)
            assert all(first + i * t < hi for i in range(n))


def test_chunk_cover_is_exact(rng):
    """chunk_ids_overlapping returns exactly the intersecting chunks —
    no misses (correctness) and no extras (pruning power)."""
    for _ in range(60):
        nd = int(rng.integers(1, 4))
        shape = tuple(int(rng.integers(1, 13)) for _ in range(nd))
        chunk = tuple(int(rng.integers(1, s + 3)) for s in shape)
        sp = Dataspace(name="a", shape=shape, dtype="int8", chunk=chunk)
        key = tuple(
            slice(int(rng.integers(0, s)),
                  int(rng.integers(1, s + 1)) or None,
                  int(rng.integers(1, 4))) for s in shape)
        hs = Hyperslab.from_key(shape, key)
        assert list(sp.chunk_ids_overlapping(hs)) == \
            brute_chunk_ids(sp, hs)


# ------------------------------------------------- chunk->object mapping
def test_array_objmap_plan_lookup_roundtrip():
    sp = Dataspace(name="a", shape=(30, 20), dtype="float64",
                   chunk=(5, 5))
    amap = plan_array_partition(
        sp, PartitionPolicy(target_object_bytes=3 * sp.chunk_nbytes))
    # contiguous, exhaustive, chunk-aligned
    assert amap.extents[0].chunk_start == 0
    assert amap.extents[-1].chunk_stop == sp.n_chunks
    for a, b in zip(amap.extents, amap.extents[1:]):
        assert a.chunk_stop == b.chunk_start
    # grouped lookup: consecutive chunk ids in one object collapse
    ext, cids = amap.lookup_chunks([0, 1, 2])[0]
    assert cids == [0, 1, 2] and ext.chunk_start == 0
    # serialized kind dispatch (table maps have no kind field)
    back = load_objmap(amap.to_bytes())
    assert isinstance(back, ArrayObjectMap) and back == amap


# ------------------------------------------------- end-to-end selection
def _roundtrip_case(shape, chunk, key, seed):
    store, vol, amap, arr = make_array_world(
        shape, chunk, seed=seed, target_bytes=2048)
    view = vol.array(amap)
    got = view[key]
    ref = arr[key]
    assert got.shape == ref.shape and got.dtype == ref.dtype
    assert np.array_equal(got, ref)


def test_hyperslab_selection_matches_numpy_basic():
    shape, chunk = (13, 17, 5), (4, 6, 3)
    store, vol, amap, arr = make_array_world(shape, chunk,
                                             target_bytes=2048)
    view = vol.array("arr")
    for key in [np.s_[:, :, :], np.s_[2:11, 3:15:2, 1:4],
                np.s_[::3, ::5, ::2], np.s_[5, :, 2],
                np.s_[1:12:2, 4, 0:5:3], np.s_[..., 1],
                np.s_[-4:, -6::2, -1]]:
        assert np.array_equal(view[key], arr[key]), key


if HAVE_HYP:
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_hyperslab_selection_matches_numpy_property(data):
        """Random shape x chunk x selection == numpy, bit-exact,
        through the full store round trip."""
        nd = data.draw(st.integers(1, 3), label="nd")
        shape = tuple(data.draw(st.integers(1, 12), label=f"s{i}")
                      for i in range(nd))
        chunk = tuple(data.draw(st.integers(1, s + 2), label=f"c{i}")
                      for i, s in enumerate(shape))
        key = tuple(
            data.draw(st.one_of(
                st.just(slice(None)),
                st.builds(slice,
                          st.integers(0, max(0, s - 1)),
                          st.integers(1, s),
                          st.integers(1, 4)),
                st.integers(-s, s - 1)), label=f"k{i}")
            for i, s in enumerate(shape))
        _roundtrip_case(shape, chunk, key,
                        data.draw(st.integers(0, 99), label="seed"))
else:
    def test_hyperslab_selection_matches_numpy_property(rng):
        """Seeded fallback sweep for the same property (hypothesis not
        installed in this environment)."""
        for trial in range(25):
            nd = int(rng.integers(1, 4))
            shape = tuple(int(rng.integers(1, 13)) for _ in range(nd))
            chunk = tuple(int(rng.integers(1, s + 3)) for s in shape)
            key = tuple(
                (int(rng.integers(-s, s)) if rng.random() < 0.25 else
                 slice(int(rng.integers(0, s)),
                       int(rng.integers(1, s + 1)),
                       int(rng.integers(1, 4))))
                for s in shape)
            _roundtrip_case(shape, chunk, key, trial)


def test_compiled_plan_survives_repartition():
    """Late binding + refresh: a plan compiled against one chunk->object
    packing keeps returning bit-exact cells after the array is
    re-packed (fewer chunks per object, more objects) under it."""
    store, vol, amap, arr = make_array_world((20, 15, 9), (6, 4, 4),
                                             dtype="float64",
                                             target_bytes=8192)
    hs = Hyperslab.from_key(arr.shape, np.s_[2:19:3, 1:14:2, ::2])
    plan = vol.engine.compile_hyperslab(amap, hs)
    ref = arr[2:19:3, 1:14:2, ::2]
    out, _ = vol.engine.execute(plan, omap=amap)
    assert np.array_equal(out, ref)
    amap2 = vol.repartition_array(
        amap, PartitionPolicy(target_object_bytes=2048))
    assert amap2.n_objects > amap.n_objects
    assert amap2.version > amap.version
    # stale plan, no hint: engine probes .objmap version and recompiles
    out2, _ = vol.engine.execute(plan)
    assert np.array_equal(out2, ref)
    # stale plan with a fresh-map hint (no probe round trip needed)
    out3, _ = vol.engine.execute(plan, omap=amap2)
    assert np.array_equal(out3, ref)
    # squeeze axes survive the recompile
    hs_sq = Hyperslab.from_key(arr.shape, np.s_[7, :, 2])
    plan_sq = vol.engine.compile_hyperslab(amap, hs_sq)
    out4, _ = vol.engine.execute(plan_sq, omap=amap2)
    assert out4.shape == arr[7, :, 2].shape
    assert np.array_equal(out4, arr[7, :, 2])


def test_predicate_prunes_chunks_osd_side():
    store, vol, amap, arr = make_array_world((24, 18), (4, 6),
                                             target_bytes=2048)
    store.fabric.reset()
    got = vol.array(amap).sel(np.s_[:, :], where=Cmp("data", ">", 950))
    mask = arr > 950
    assert np.array_equal(got[mask], arr[mask])
    # pruning is chunk-granule: a cell is either its true value (its
    # chunk survived) or the fill (its whole chunk was provably empty)
    assert ((got == arr) | (got == 0)).all()
    # pruning happened ON the OSDs: chunks dropped, yet the client
    # fetched no zone maps at all
    assert store.fabric.chunks_pruned > 0
    assert store.fabric.xattr_ops == 0
    # bytes shrink vs the unpredicated full read
    rx_pruned = store.fabric.client_rx
    store.fabric.reset()
    full = vol.array(amap)[:, :]
    assert np.array_equal(full, arr)
    assert rx_pruned < store.fabric.client_rx


def test_strided_selection_moves_fewer_bytes():
    store, vol, amap, arr = make_array_world((32, 32), (8, 8),
                                             target_bytes=4096)
    store.fabric.reset()
    assert np.array_equal(vol.array(amap)[:, :], arr)
    full_rx = store.fabric.client_rx
    store.fabric.reset()
    assert np.array_equal(vol.array(amap)[::4, ::4], arr[::4, ::4])
    assert store.fabric.client_rx < full_rx


# ---------------------------------------------------- negative caching
def test_negative_cache_unit():
    rc = ResultCache(1024)
    rc.put_negative(("o", 3, "pipe#neg", "d"), "skipped")
    got = rc.get(("o", 3, "pipe#neg", "d"))
    assert isinstance(got, Negative) and got.reason == "skipped"
    assert rc.resident_bytes == Negative.NBYTES
    rc.invalidate("o")
    assert rc.get(("o", 3, "pipe#neg", "d")) is _MISS
    # disabled cache refuses negatives like everything else
    off = ResultCache(0)
    off.put_negative(("o", 1, "p#neg", "d"), "missing")
    assert off.get(("o", 1, "p#neg", "d")) is _MISS


def test_negative_cache_replays_all_pruned_scan():
    store, vol, amap, arr = make_array_world((12, 8), (3, 4),
                                             target_bytes=256)
    sel = np.s_[:, :]
    view = vol.array(amap)
    out = view.sel(sel, where=Cmp("data", ">", 10_000))
    assert np.array_equal(out, np.zeros(arr.shape, arr.dtype))
    store.fabric.reset()
    out2 = view.sel(sel, where=Cmp("data", ">", 10_000))
    assert np.array_equal(out2, out)
    # every object answered "nothing to serve" from its negative entry
    # without re-resolving or re-pruning
    assert store.fabric.cache_neg_hits >= amap.n_objects
    assert store.fabric.chunks_pruned == 0


def test_negative_cache_distinguishes_predicates():
    """The result-cache key folds the prune digest: the same hyperslab
    under a different predicate must NOT replay the other's entry."""
    store, vol, amap, arr = make_array_world((12, 8), (3, 4),
                                             target_bytes=256)
    view = vol.array(amap)
    empty = view.sel(np.s_[:, :], where=Cmp("data", ">", 10_000))
    assert not empty.any()
    full = view.sel(np.s_[:, :], where=Cmp("data", ">=", 0))
    assert np.array_equal(full, arr)


def test_negative_cache_invalidated_by_rewrite():
    store, vol, amap, arr = make_array_world((12, 8), (3, 4),
                                             target_bytes=256)
    view = vol.array(amap)
    pred = Cmp("data", ">", 10_000)
    view.sel(np.s_[:, :], where=pred)
    view.sel(np.s_[:, :], where=pred)  # negatives now hot
    # rewrite every object with values that DEFEAT the predicate zone
    # prune: stale negatives would wrongly answer "nothing"
    big = arr.astype(np.int64) + 20_000
    vol.write_array(amap, big)
    got = view.sel(np.s_[:, :], where=pred)
    assert np.array_equal(got, big)


def test_negative_cache_replays_missing_object():
    """OSD serve layer: an absent object's miss is negatively cached
    (version -1) and retired when a write lands."""
    from repro.core.store import _serve_meters
    store = make_store(2, replicas=2, cache_bytes=1 << 16)
    name = "ghost"
    osd = store.osds[store.cluster.primary(name)]
    m = _serve_meters()
    st1, _, _ = osd._serve_item(name, [], "concat", "d0", m)
    assert st1 == "missing" and m["neg_hits"] == 0
    st2, _, _ = osd._serve_item(name, [], "concat", "d0", m)
    assert st2 == "missing" and m["neg_hits"] == 1
    # a write through the store plane retires the negative eagerly
    store.put(name, fmt.encode_block({"x": np.arange(3)}))
    assert osd.cache.get((name, -1, "concat#neg", "d0")) is _MISS


# ------------------------------------------------ predicate normalization
def test_normalize_demorgan_and_double_negation():
    e = ex.Not(ex.And((ex.Cmp("y", "<", 5), ex.Cmp("y", ">=", 9))))
    n = normalize(e)
    assert isinstance(n, ex.Or)
    assert {(k.col, k.cmp, k.value) for k in n.children} == \
        {("y", ">=", 5), ("y", "<", 9)}
    assert normalize(ex.Not(ex.Not(ex.Cmp("y", "<", 3)))) == \
        ex.Cmp("y", "<", 3)


def test_normalize_interval_merge_and_contradiction():
    n = normalize(ex.And((ex.Cmp("x", ">=", 4), ex.Cmp("x", "<=", 7),
                          ex.Cmp("x", ">", 2))))
    assert n == ex.Between("x", 4, 7)
    n2 = normalize(ex.And((ex.Cmp("x", ">", 5), ex.Cmp("x", "<", 1))))
    assert n2 == Const(False)
    # point interval collapses to equality
    n3 = normalize(ex.And((ex.Cmp("x", ">=", 6), ex.Cmp("x", "<=", 6))))
    assert n3 == ex.Cmp("x", "==", 6)
    # same-direction bounds tighten
    n4 = normalize(ex.And((ex.Cmp("x", ">", 5), ex.Cmp("x", ">", 3))))
    assert n4 == ex.Cmp("x", ">", 5)


def test_normalize_constant_folding_and_wire():
    t, f = Const(True), Const(False)
    assert normalize(ex.And((t, ex.Cmp("x", "<", 1)))) == \
        ex.Cmp("x", "<", 1)
    assert normalize(ex.And((f, ex.Cmp("x", "<", 1)))) == f
    assert normalize(ex.Or((t, ex.Cmp("x", "<", 1)))) == t
    assert ex.from_json(t.to_json()) == t
    # Const semantics: mask covers all rows, prunes iff False
    tbl = {"x": np.arange(5)}
    assert t.mask(tbl).all() and not f.mask(tbl).any()
    assert f.prunes({}) and not t.prunes({})


def test_normalize_preserves_mask_and_prune_soundness(rng):
    """Normalization never changes row selection, and its (often
    stronger) prune verdicts stay sound: a normalized tree may prune
    objects the original could not — De Morgan exposes intervals to
    the Not-blind interval rule — but never one holding a matching
    row."""
    tbl = {"y": rng.integers(0, 20, 200).astype(np.int64),
           "x": rng.normal(size=200),
           "t": np.array(["ab", "cd"] * 100)}
    exprs = [
        ex.Not(ex.And((ex.Cmp("y", "<", 5), ex.Between("y", 9, 15)))),
        ex.And((ex.Cmp("x", ">=", -0.5), ex.Cmp("x", "<=", 0.5),
                ex.Not(ex.Cmp("y", "==", 3)))),
        ex.Or((ex.Not(ex.In("y", (1, 2, 3))), ex.Cmp("y", ">", 18))),
        ex.Not(ex.Or((ex.StrPrefix("t", "ab"), ex.Cmp("y", "<", 2)))),
        ex.And((ex.Cmp("y", ">", 3), ex.Cmp("y", ">=", 7),
                ex.Cmp("y", "<", 30))),
        ex.And((ex.Cmp("y", ">", 15), ex.Cmp("y", "<", 3))),
    ]
    for e in exprs:
        n = normalize(e)
        assert np.array_equal(e.mask(tbl), n.mask(tbl)), e
        for _ in range(40):
            a = int(rng.integers(0, 190))
            b = a + int(rng.integers(1, 10))
            sub = {k: v[a:b] for k, v in tbl.items()}
            zm = fmt.zone_map(sub)
            if n.prunes(zm):  # prune verdicts must be sound on data
                assert not e.mask(sub).any(), (e, zm)


# ------------------------------------------------- per-hop replication
def _timed_put(repl, hop, replicas=3):
    store = make_store(4, replicas=replicas, replication=repl,
                       hop_latency_s=hop)
    t0 = time.perf_counter()
    store.put("o", b"x" * 64)
    return store, time.perf_counter() - t0


def test_hop_latency_chain_vs_fanout():
    hop = 0.02
    chain, chain_dt = _timed_put("chain", hop)
    fan, fan_dt = _timed_put("fanout", hop)
    # chain pays one hop per transferred copy, sequentially
    assert chain.fabric.replica_lat_s == pytest.approx(2 * hop)
    assert chain_dt >= 2 * hop
    # fan-out sends in parallel: one hop total
    assert fan.fabric.replica_lat_s == pytest.approx(hop)
    assert fan_dt >= hop
    # default is free and untimed (no behavior change for old callers)
    free = make_store(4, replicas=3)
    free.put("o", b"x")
    assert free.fabric.replica_lat_s == 0.0


def test_hop_latency_accrues_on_batched_writes():
    store = make_store(4, replicas=2, replication="chain",
                       hop_latency_s=0.001)
    store.put_batch([f"o{i}" for i in range(6)],
                    [b"x" * 32 for _ in range(6)])
    # 6 objects x 1 transferred hop each
    assert store.fabric.replica_lat_s == pytest.approx(6 * 0.001)
