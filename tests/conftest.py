"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py fakes a 512-chip pod."""

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
