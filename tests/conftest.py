"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py fakes a 512-chip pod.

``--lockcheck`` runs the whole selected suite under the dynamic
lock-order / lock-ownership harness (``repro.analysis.lockcheck``):
every core lock is instrumented, nested acquisitions build a global
order graph, and the run FAILS if the graph has a cycle (deadlock
hazard, even if nothing hung) or a ``_GUARDED_BY`` container was
mutated without its owning lock held.
"""

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--lockcheck", action="store_true", default=False,
        help="instrument repro.core locks: fail on lock-order cycles "
             "or guarded-container mutation without the owning lock")


def pytest_configure(config):
    if config.getoption("--lockcheck"):
        from repro.analysis import lockcheck
        config._lockcheck_state = lockcheck.install()


def pytest_unconfigure(config):
    state = getattr(config, "_lockcheck_state", None)
    if state is not None:
        from repro.analysis import lockcheck
        lockcheck.uninstall(state)
        config._lockcheck_state = None


def pytest_sessionfinish(session, exitstatus):
    state = getattr(session.config, "_lockcheck_state", None)
    if state is not None and not state.report()["ok"] \
            and session.exitstatus == 0:
        session.exitstatus = 1


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    state = getattr(config, "_lockcheck_state", None)
    if state is None:
        return
    rep = state.report()
    tr = terminalreporter
    tr.section("lockcheck")
    tr.line(f"acquisitions: {rep['acquisitions']}  "
            f"locks instrumented: {rep['locks_instrumented']}  "
            f"guarded containers: {rep['containers_instrumented']}")
    for a, bs in rep["order_edges"].items():
        tr.line(f"order: {a} -> {', '.join(bs)}")
    for cyc in rep["cycles"]:
        tr.line(f"LOCK-ORDER CYCLE: {' -> '.join(cyc)}", red=True)
    for v in rep["violations"]:
        tr.line(f"OWNERSHIP VIOLATION: {v}", red=True)
    if rep["ok"]:
        tr.line("lockcheck: no cycles, no ownership violations")
