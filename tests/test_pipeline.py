"""Object-store data pipeline: determinism, slicing, packed mode,
straggler hedging."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GlobalVOL, make_store
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.fused_ingest import fused_batch, pack_batch
from repro.data.pipeline import ObjectDataLoader


@pytest.fixture(scope="module")
def world():
    store = make_store(6, replicas=2)
    vol = GlobalVOL(store)
    from repro.core.partition import PartitionPolicy
    omap = build_corpus(vol, CorpusSpec(n_seqs=256, seq_len=128,
                                        vocab_size=5000, seed=1),
                        policy=PartitionPolicy(target_object_bytes=32 << 10,
                                               max_object_bytes=256 << 10))
    return store, vol, omap


def loader(vol, **kw):
    kw.setdefault("global_batch", 16)
    kw.setdefault("seed", 7)
    kw.setdefault("prefetch", 0)
    return ObjectDataLoader(vol, "corpus", **kw)


def test_batch_shapes_and_labels(world):
    _, vol, _ = world
    b = loader(vol).make_batch(0)
    assert b["tokens"].shape == (16, 128)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert (b["labels"][:, -1] == -1).all()


def test_determinism_and_resume(world):
    _, vol, _ = world
    a = loader(vol).make_batch(5)
    b = loader(vol, start_step=5).make_batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])


def test_epoch_reshuffles(world):
    _, vol, _ = world
    ld = loader(vol)
    e0 = ld.rows_for_step(0)
    e1 = ld.rows_for_step(ld.steps_per_epoch)  # same position, next epoch
    assert not np.array_equal(e0, e1)


def test_rank_slices_partition_batch(world):
    _, vol, _ = world
    rows = [loader(vol, dp_rank=r, dp_size=4).rows_for_step(3)
            for r in range(4)]
    allrows = np.concatenate(rows)
    assert len(allrows) == 16
    assert len(np.unique(allrows)) == 16


def test_packed_equals_plain(world):
    _, vol, _ = world
    plain = loader(vol).make_batch(2)
    packed = loader(vol, packed=True).make_batch(2)
    fb = fused_batch(jnp.asarray(packed["tokens_packed"]))
    assert np.array_equal(np.asarray(fb["tokens"]), plain["tokens"])
    assert np.array_equal(np.asarray(fb["labels"]), plain["labels"])
    raw = plain["tokens"].nbytes + plain["labels"].nbytes
    assert packed["tokens_packed"].nbytes < raw / 3  # 13-bit vocab


def test_pack_batch_matches_loader_packed(world):
    _, vol, _ = world
    plain = loader(vol).make_batch(4)
    packed = loader(vol, packed=True).make_batch(4)
    repacked = pack_batch(plain["tokens"], packed["tokens_packed"].shape[-1])
    assert np.array_equal(repacked, packed["tokens_packed"])


def test_prefetch_thread_yields_same_batches(world):
    _, vol, _ = world
    ld_bg = loader(vol, prefetch=2)
    got = [next(ld_bg)["tokens"] for _ in range(3)]
    ld_bg.close()
    ld_fg = loader(vol)
    for i, t in enumerate(got):
        assert np.array_equal(t, ld_fg.make_batch(i)["tokens"])


def test_hedged_read_beats_straggler(world):
    store, vol, omap = world
    victims = {store.cluster.primary(n) for n in omap.object_names()}
    for v in victims:
        store.osds[v].latency_s = 0.4
    try:
        ld = loader(vol, hedge_timeout_s=0.05)
        t0 = time.time()
        b = ld.make_batch(0)
        dt = time.time() - t0
        assert dt < 0.35, dt
        ref = loader(vol).make_batch(0)  # slow path, same data
        assert np.array_equal(b["tokens"], ref["tokens"])
    finally:
        for v in victims:
            store.osds[v].latency_s = 0.0
