"""Checkpoints AS datasets: train state mapped to objects via core.

The train-state pytree is flattened to named leaves; each leaf's bytes
are partitioned into objects by ``core.partition`` (same grouping /
splitting / sizing machinery as any dataset — the checkpoint IS a mapped
dataset), placed and replicated by CRUSH, and committed atomically with
a manifest-last protocol:

  ckpt/<tag>/step-<n>/<leaf objects...>     (replicated data)
  ckpt/<tag>/step-<n>/.manifest             (commit record, written last)

A checkpoint without a readable manifest is invisible to ``restore`` —
a crash mid-save can never be restored from, and a
``PartialWriteError``'s ``persisted`` listing is sufficient to
reconcile (``reconcile_partial_save`` deletes the orphaned sub-writes
so the retry lands a bit-exact checkpoint).  OSD failures are tolerated
up to replicas-1 per object; ``ObjectStore.recover`` heals the rest.

``CheckpointManager`` adds async double-buffered saves (serialization +
store writes overlap the next train steps) and retention.
"""

from __future__ import annotations

import json
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

from repro.core.logical import Column, LogicalDataset
from repro.core.partition import PartitionPolicy, plan_partition
from repro.core.store import (ObjectNotFound, ObjectStore,
                              PartialWriteError)

_DEFAULT_POLICY = PartitionPolicy(target_object_bytes=8 << 20,
                                  max_object_bytes=32 << 20)


def _flatten(state) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _leaf_dataset(tag: str, step: int, idx: int,
                  arr: np.ndarray) -> LogicalDataset:
    return LogicalDataset(
        f"ckpt/{tag}/step-{step}/leaf-{idx:05d}",
        (Column("bytes", "uint8"),),
        n_rows=arr.nbytes, unit_rows=max(arr.nbytes, 1))


def save(store: ObjectStore, state: Any, step: int, *, tag: str = "train",
         policy: PartitionPolicy = _DEFAULT_POLICY, workers: int = 8,
         extra: dict | None = None,
         window_bytes: int | None = None) -> dict:
    """Write a checkpoint; returns the manifest.

    The object mapping of every leaf is planned up front from shapes
    alone (cheap); the expensive part — serializing each leaf
    (``tobytes``) — happens lazily.  When transfers take simulated time
    the whole checkpoint ships as ONE windowed streaming ``put_batch``
    (one request per primary OSD for the entire checkpoint), so leaf
    i+1 serializes while leaf i's windows are still on the NIC — true
    cross-leaf encode/stream overlap.  The store's write ledger
    releases each sub-write's blob once it AND its replica chain land,
    so the client retains O(window) serialized bytes, never the whole
    checkpoint (``store.last_put_ledger_peak_bytes`` records the
    peak).  In-process stores (no simulated I/O) keep the buffered
    path: one batch per leaf, at most one leaf's blobs in memory.
    ``window_bytes`` overrides the store's default ingest window.
    ``workers`` is kept for API compatibility; parallelism is the
    store's, per OSD group.
    """
    del workers
    leaves = sorted(_flatten(state).items())
    manifest: dict = {"step": step, "tag": tag, "leaves": {},
                      "extra": extra or {}}
    planned = []  # (key, arr, omap) — no serialization yet
    for idx, (key, arr) in enumerate(leaves):
        ds = _leaf_dataset(tag, step, idx, arr)
        planned.append((key, arr, plan_partition(ds, policy)))

    def serialize(key, arr, omap) -> list[bytes]:
        raw = arr.tobytes()
        manifest["leaves"][key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "objects": [[e.name, e.row_start, e.row_stop]
                        for e in omap],
            "crc": zlib.crc32(raw)}
        return [raw[e.row_start:e.row_stop] for e in omap]

    window = store.default_window_bytes() if window_bytes is None \
        else window_bytes
    if window:
        names = [e.name for _, _, omap in planned for e in omap]
        store.put_batch(
            names,
            (blob for leaf in planned for blob in serialize(*leaf)),
            window_bytes=window)
    else:
        for key, arr, omap in planned:
            store.put_batch([e.name for e in omap],
                            serialize(key, arr, omap))

    # commit record LAST — atomicity point (and only after every leaf's
    # meta was filled in by its serialize())
    store.put(f"ckpt/{tag}/step-{step}/.manifest",
              json.dumps(manifest).encode())
    return manifest


def reconcile_partial_save(store: ObjectStore,
                           err: PartialWriteError) -> list[str]:
    """Crash-consistency reconcile for a ``save`` that died mid-stream
    (e.g. its producer was killed, or the entry OSD went down past the
    failover budget): the raised :class:`PartialWriteError` lists
    exactly which sub-writes persisted (``(name, version)`` pairs), and
    since the manifest is written LAST the torn checkpoint is already
    invisible to ``restore`` — so reconciliation is just deleting those
    orphaned data objects and retrying the save from scratch.  Returns
    the names deleted.  Idempotent: already-gone objects are skipped."""
    deleted = []
    for name, _version in err.persisted:
        try:
            store.delete(name)
        except (ObjectNotFound, KeyError):
            continue
        deleted.append(name)
    return deleted


def latest_step(store: ObjectStore, *, tag: str = "train") -> int | None:
    steps = []
    for name in store.list_objects(f"ckpt/{tag}/step-"):
        if name.endswith("/.manifest"):
            try:
                steps.append(int(name.split("step-")[1].split("/")[0]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(store: ObjectStore, state_like: Any, *, step: int | None = None,
            tag: str = "train", workers: int = 8) -> tuple[Any, dict]:
    """Rebuild the pytree (structured like ``state_like``) from objects."""
    if step is None:
        step = latest_step(store, tag=tag)
        if step is None:
            raise FileNotFoundError(f"no checkpoint for tag {tag!r}")
    manifest = json.loads(
        store.get(f"ckpt/{tag}/step-{step}/.manifest").decode())

    def get_leaf(meta: dict) -> np.ndarray:
        raw = b"".join(store.get(n) for n, _, _ in meta["objects"])
        if zlib.crc32(raw) != meta["crc"]:
            raise IOError("checkpoint leaf corrupt")
        return np.frombuffer(raw, dtype=meta["dtype"]).reshape(
            meta["shape"]).copy()

    keys = sorted(manifest["leaves"])
    with ThreadPoolExecutor(max_workers=workers) as pool:
        arrays = list(pool.map(
            lambda k: get_leaf(manifest["leaves"][k]), keys))
    by_key = dict(zip(keys, arrays))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_key[key]
        want = tuple(getattr(leaf, "shape", ()) or ())
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        out.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest


class CheckpointManager:
    """Async saves + retention.  ``maybe_save`` snapshots to host
    (blocking, cheap) then writes to the store on a background thread so
    training overlaps the object writes."""

    def __init__(self, store: ObjectStore, *, tag: str = "train",
                 every_steps: int = 100, keep: int = 3,
                 policy: PartitionPolicy = _DEFAULT_POLICY):
        self.store = store
        self.tag = tag
        self.every_steps = every_steps
        self.keep = keep
        self.policy = policy
        self._pending: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def maybe_save(self, state: Any, step: int,
                   extra: dict | None = None) -> bool:
        if step % self.every_steps:
            return False
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # device->host snap

        def work():
            save(self.store, host_state, step, tag=self.tag,
                 policy=self.policy, extra=extra)
            self.saved_steps.append(step)
            self._retire()

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _retire(self) -> None:
        while len(self.saved_steps) > self.keep:
            old = self.saved_steps.pop(0)
            prefix = f"ckpt/{self.tag}/step-{old}/"
            # delete manifest FIRST so a partially-deleted ckpt is invisible
            try:
                self.store.delete(prefix + ".manifest")
            except ObjectNotFound:
                pass
            for name in self.store.list_objects(prefix):
                self.store.delete(name)
