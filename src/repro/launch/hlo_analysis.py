"""Scan-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes by ~L and hides the
collectives inside the loop.  This analyzer walks the HLO computation
graph, multiplies loop bodies by their trip counts (recovered from the
loop condition's comparison constant), and accumulates:

  flops      — dot ops: 2 * prod(result dims) * prod(contracting dims)
  bytes      — per op: result + operands (fusions count their boundary
               only — internals live in registers, matching XLA's model;
               dynamic-update-slice counts the updated window, not the
               aliased buffer)
  collective — wire bytes per device with ring-algorithm factors,
               grouped by kind

Operand shapes are resolved through per-computation symbol tables (the
optimized dump prints operands by name only).  Cross-checked against
cost_analysis() on while-free programs in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_ATTR_COMP_RE = re.compile(
    r"(body|condition|to_apply|calls|true_computation|"
    r"false_computation)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*(\(?[^,()]*(?:\([^)]*\))?\)?"
                       r"(?:\[[0-9,]*\])?(?:\{[^}]*\})?)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "iota", "partition-id", "replica-id"}
# ops a TPU-grade fusion pass folds into neighbours: their operands/
# results never round-trip HBM.  The CPU backend fuses far less, so
# counting every op overstates the memory term ~5-10x; ``bytes_fused``
# counts only materializing ops (dots, loop/fusion boundaries, layout
# changes, collectives, dynamic slices) and is the TPU-order estimate
# the roofline uses; ``bytes`` (everything) is kept as the upper bound.
_FUSABLE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "negate", "abs",
    "select", "compare", "convert", "and", "or", "xor", "not", "power",
    "rsqrt", "sqrt", "cbrt", "log", "log-plus-one", "logistic", "floor",
    "ceil", "sign", "shift-left", "shift-right-logical", "round-nearest-even",
    "shift-right-arithmetic", "clamp", "broadcast", "reshape", "atan2",
    "is-finite", "remainder", "cosine", "sine", "tan", "erf", "expm1",
    "reduce-precision", "stochastic-convert", "popcnt", "clz", "pad",
    "reverse", "map", "real", "imag",
}


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(segment: str) -> list[list[int]]:
    return [[int(d) for d in dims.split(",")] if dims else []
            for _, dims in _SHAPE_RE.findall(segment)]


def _operand_segment(line: str, opcode: str) -> str:
    """Text inside the opcode's parens (paren-depth matched)."""
    i = line.find(opcode + "(")
    if i < 0:
        return ""
    j = i + len(opcode)
    depth = 0
    for k in range(j, len(line)):
        if line[k] == "(":
            depth += 1
        elif line[k] == ")":
            depth -= 1
            if depth == 0:
                return line[j + 1:k]
    return line[j + 1:]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # upper bound: every op materializes
    bytes_fused: float = 0.0    # TPU-order: fusable elementwise ops free
    coll: dict = dataclasses.field(
        default_factory=lambda: dict.fromkeys(_COLLECTIVES, 0.0))
    coll_count: float = 0.0
    dots: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        for k in self.coll:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult
        self.dots += other.dots * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _group_size(line: str) -> int:
    m = _GROUPS2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[str]] = {}
        self.symtab: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}
        self._fusable_memo: dict[str, bool] = {}

    # ---------------------------------------------------------------- parse
    def _parse(self, hlo: str) -> None:
        cur: str | None = None
        for line in hlo.splitlines():
            if cur is None:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    self.symtab[cur] = {}
                    if m.group(1):
                        self.entry = cur
                    # header params: "name: shape, name: shape"
                    for pname, pshape in _PARAM_RE.findall(m.group(3)):
                        self.symtab[cur][pname] = pshape
            else:
                if line.strip() == "}":
                    cur = None
                    continue
                self.comps[cur].append(line)
                om = _OP_RE.match(line)
                if om:
                    self.symtab[cur][om.group(1)] = om.group(2)

    # ---------------------------------------------------------------- trip
    def trip_count(self, cond_name: str, body_name: str) -> int:
        consts = []
        for line in self.comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        t = max(consts, default=0)
        if t <= 0:
            for line in self.comps.get(body_name, []):
                consts += [int(c) for c in _CONST_RE.findall(line)]
            t = max(consts, default=1)
        return max(t, 1)

    # ---------------------------------------------------------------- ops
    def _operand_bytes(self, comp: str, seg: str) -> tuple[int, list[str]]:
        names = _OPERAND_NAME_RE.findall(seg)
        tab = self.symtab[comp]
        total = 0
        shapes = []
        for n in names:
            s = tab.get(n, "")
            total += _shape_bytes(s)
            shapes.append(s)
        return total, shapes

    def _op_cost(self, comp: str, line: str) -> Cost:
        c = Cost()
        m = _OP_RE.match(line)
        if not m:
            return c
        result_seg, opcode = m.group(2), m.group(3)
        if opcode in _ZERO_COST:
            return c
        result_bytes = _shape_bytes(result_seg)
        operand_seg = _operand_segment(line, opcode)
        operand_bytes, operand_shapes = self._operand_bytes(
            comp, operand_seg)

        attr_comps = dict()
        for k, v in _ATTR_COMP_RE.findall(line):
            attr_comps.setdefault(k, v)

        if opcode == "while":
            body, cond = attr_comps.get("body"), attr_comps.get("condition")
            if body:
                trips = self.trip_count(cond or "", body)
                c.add(self.comp_cost(body), trips)
                if cond:
                    c.add(self.comp_cost(cond), trips)
            return c

        if opcode in ("call", "fusion", "reduce", "reduce-window",
                      "scatter", "sort", "map", "select-and-scatter",
                      "custom-call"):
            fusable_body = True
            for key in ("calls", "to_apply", "true_computation",
                        "false_computation"):
                if key in attr_comps:
                    sub = self.comp_cost(attr_comps[key])
                    fusable_body &= self._all_fusable(attr_comps[key])
                    if opcode == "fusion":
                        part = Cost()
                        part.add(sub)
                        part.bytes = 0.0  # internals stay in registers
                        part.bytes_fused = 0.0
                        c.add(part)
                    else:
                        c.add(sub)
            c.bytes += result_bytes + operand_bytes
            # CPU wraps single elementwise ops in kLoop fusions; a TPU
            # fusion pass would fold those into neighbours entirely.
            if opcode == "fusion":
                if not fusable_body:
                    for key in ("calls",):
                        if key in attr_comps:
                            c.bytes_fused += self._fusion_bytes(
                                attr_comps[key], result_bytes)
            else:
                c.bytes_fused += result_bytes + operand_bytes
            return c

        if opcode == "conditional":
            names = [v for _, v in _ATTR_COMP_RE.findall(line)]
            mb = _BRANCHES_RE.search(line)
            if mb:
                names += [n.strip().lstrip("%")
                          for n in mb.group(1).split(",")]
            for name in set(names):
                c.add(self.comp_cost(name))  # upper bound: all branches
            c.bytes += result_bytes + operand_bytes
            c.bytes_fused += result_bytes + operand_bytes
            return c

        coll = next((k for k in _COLLECTIVES if opcode.startswith(k)), None)
        if coll is not None:
            if opcode.endswith("-done"):
                return c  # counted at -start
            n = max(_group_size(line), 1)
            size = result_bytes
            if coll == "all-gather":
                wire = size * (n - 1) / n
            elif coll == "all-reduce":
                wire = 2.0 * size * (n - 1) / n
            elif coll == "reduce-scatter":
                wire = size * (n - 1)
            elif coll == "all-to-all":
                wire = size * (n - 1) / n
            else:
                wire = float(size)
            c.coll[coll] += wire
            c.coll_count += 1
            c.bytes += result_bytes + operand_bytes
            c.bytes_fused += result_bytes + operand_bytes
            return c

        if opcode == "dot":
            k = 1
            mcon = _CONTRACT_RE.search(line)
            if mcon and operand_shapes:
                lhs_dims = _shape_dims(operand_shapes[0])
                lhs = lhs_dims[0] if lhs_dims else []
                for d in mcon.group(1).split(","):
                    if d != "" and int(d) < len(lhs):
                        k *= lhs[int(d)]
            n_out = 0
            for dt, dims in _SHAPE_RE.findall(result_seg):
                n = 1
                for d in (dims.split(",") if dims else []):
                    n *= int(d)
                n_out += n
            c.flops += 2.0 * n_out * k
            c.dots += 1

        if opcode == "dynamic-update-slice":
            upd = _shape_bytes(operand_shapes[1]) if \
                len(operand_shapes) > 1 else 0
            c.bytes += 2.0 * upd
            c.bytes_fused += 2.0 * upd
        elif opcode == "dynamic-slice":
            c.bytes += 2.0 * result_bytes
            c.bytes_fused += 2.0 * result_bytes
        else:
            c.bytes += result_bytes + operand_bytes
            if opcode not in _FUSABLE:
                c.bytes_fused += result_bytes + operand_bytes
        return c

    def _fusion_bytes(self, name: str, result_bytes: int) -> float:
        """HBM bytes at a fusion boundary: every parameter is read in
        full EXCEPT operands consumed by an inner dynamic-slice /
        dynamic-update-slice — those only move the slice/update window
        (the buffer itself is aliased).  Catches the decode-cache
        pattern where a fusion 'takes' a multi-GB stacked cache but
        touches one layer's page."""
        tab = self.symtab.get(name, {})
        sliced: dict[str, float] = {}
        has_dus = False
        params: list[str] = []
        for line in self.comps.get(name, []):
            m = _OP_RE.match(line)
            if not m:
                continue
            op = m.group(3)
            if op == "parameter":
                params.append(m.group(1))
                continue
            seg = _operand_segment(line, op)
            names = _OPERAND_NAME_RE.findall(seg)
            if op == "dynamic-slice" and names:
                sliced[names[0]] = 2.0 * _shape_bytes(m.group(2))
            elif op == "dynamic-update-slice" and len(names) > 1:
                upd = _shape_bytes(tab.get(names[1], ""))
                sliced[names[0]] = 2.0 * upd
                has_dus = True
        total = 0.0
        for pname in params:
            if pname in sliced:
                total += sliced[pname]
            else:
                total += _shape_bytes(tab.get(pname, ""))
        if not has_dus:  # DUS output aliases its buffer: write counted
            total += result_bytes
        return total

    def _all_fusable(self, name: str) -> bool:
        """True when every op in the computation is elementwise-fusable
        (used to zero the HBM cost of CPU 'wrapped_*' kLoop fusions)."""
        if name not in self._fusable_memo:
            self._fusable_memo[name] = True  # cycle guard
            ok = True
            for line in self.comps.get(name, []):
                m = _OP_RE.match(line)
                if not m:
                    continue
                op = m.group(3)
                if op in _ZERO_COST or op in _FUSABLE:
                    continue
                ok = False
                break
            self._fusable_memo[name] = ok
        return self._fusable_memo[name]

    # ---------------------------------------------------------------- comp
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        total = Cost()
        for line in self.comps.get(name, []):
            total.add(self._op_cost(name, line))
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    cost = HloAnalyzer(hlo_text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "bytes_fused": cost.bytes_fused,
        "collective": dict(cost.coll, total=cost.coll_total),
        "collective_count": cost.coll_count,
        "dots": cost.dots,
    }
