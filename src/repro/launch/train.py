"""Training launcher: ``python -m repro.launch.train --arch yi_9b --smoke``.

Stands up the object store, ingests a synthetic corpus through the VOL,
and runs the Trainer (object-store data path, packed ingest, checkpoint/
restart).  ``--smoke`` selects the reduced config — the full configs are
exercised via ``repro.launch.dryrun`` (this container has one CPU).
On a real pod this same entry point runs under the production mesh with
``--mesh single|multi``.
"""

from __future__ import annotations

import argparse

from repro.configs.base import get_config
from repro.core import GlobalVOL, make_store
from repro.core.partition import PartitionPolicy
from repro.data.corpus import CorpusSpec, build_corpus
from repro.data.pipeline import ObjectDataLoader
from repro.models.archs import build_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--packed", action="store_true", default=True)
    ap.add_argument("--no-packed", dest="packed", action="store_false")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-osds", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.smoke:
        print("WARNING: full config on CPU — expect extreme slowness; "
              "use --smoke or the dryrun for full configs")
    if cfg.frontend != "none" and args.packed:
        print(f"[train] {cfg.name}: frontend stub takes embeddings — "
              "disabling packed ingest")
        args.packed = False
    seq = args.seq or (2 * cfg.ssm.chunk if cfg.ssm is not None
                       and cfg.ssm.chunk <= 64 else 128)

    store = make_store(args.n_osds, replicas=2)
    vol = GlobalVOL(store)
    build_corpus(vol, CorpusSpec(
        n_seqs=max(args.steps * args.global_batch // 2, 256),
        seq_len=seq, vocab_size=cfg.vocab_size, seed=args.seed),
        policy=PartitionPolicy(target_object_bytes=2 << 20,
                               max_object_bytes=16 << 20))

    model = build_model(cfg, remat="none")
    if cfg.frontend != "none":
        raise SystemExit(f"{cfg.name}: modality-frontend archs train via "
                         "examples/train_e2e-style embedding stubs; use a "
                         "token arch here")
    loader = ObjectDataLoader(vol, "corpus", global_batch=args.global_batch,
                              seed=args.seed, packed=args.packed,
                              prefetch=2)
    trainer = Trainer(
        model, loader, store,
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 2),
                      total_steps=args.steps),
        cfg=TrainerConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          log_every=max(args.steps // 10, 1),
                          packed_ingest=args.packed))
    trainer.run()
    loader.close()
    print(f"[train] done: loss {trainer.history[0]['loss']:.3f} -> "
          f"{trainer.history[-1]['loss']:.3f}; "
          f"ckpts: {len(store.list_objects('ckpt/'))} objects")


if __name__ == "__main__":
    main()
