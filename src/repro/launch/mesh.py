"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e-class pods of 256
chips arranged (data=16, model=16); the multi-pod mesh adds a leading
"pod" axis of 2 (512 chips).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before importing jax (dry-run only)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh(shape, axes)
