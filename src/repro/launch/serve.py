"""Serving launcher: ``python -m repro.launch.serve --arch yi_9b --smoke``.

Boots the engine with random weights (or a checkpoint from the store via
--restore), serves synthetic batched requests, and parks the session's
KV pages to the object store.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import make_store
from repro.models.archs import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.frontend != "none":
        raise SystemExit(f"{cfg.name}: frontend-stub archs decode over "
                         "token ids after a stubbed prefill; use the "
                         "dryrun for their serve-step lowering")
    model = build_model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(args.seed))
    store = make_store(4, replicas=2)
    engine = ServeEngine(model, params, max_seq=args.max_seq, store=store)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(
        1, cfg.vocab_size, int(rng.integers(4, 17))).astype(np.int32),
        max_new=args.max_new) for _ in range(args.batch)]
    t0 = time.perf_counter()
    comps = engine.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(c.steps for c in comps)
    print(f"[serve] {args.batch} reqs, {toks} tokens, "
          f"{dt * 1e3:.0f} ms ({toks / dt:.1f} tok/s)")
    engine.park_session("session-0")
    print(f"[serve] parked KV pages: "
          f"{len(store.list_objects('kv/'))} objects")


if __name__ == "__main__":
    main()
