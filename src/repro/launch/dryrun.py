import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a cell
passes when ``jax.jit(step).lower(**abstract_inputs).compile()`` succeeds
on the production mesh, and its compiled artifact yields the roofline
terms (cost_analysis FLOPs/bytes + collective bytes parsed from the
optimized HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  ... --mesh multi       # 2-pod (2,16,16) mesh instead of (16,16)
  ... --variant fused    # packed-ingest train step (perf iteration)
  ... --variant compressed  # int8 cross-pod grad sync (multi mesh only)

Each cell's record lands in results/dryrun/<cell>.json (resume = skip
existing).  NOTE: the XLA_FLAGS line above must execute before any other
jax import in the process — run this module only as __main__.
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, registry
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.archs import build_model
from repro.models.inputs import decode_input_specs, train_input_specs
from repro.train.optimizer import OptConfig
from repro.train.steps import abstract_train_state, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"

# TPU v5e-class constants (assignment)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"= ([^=]*?) (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS2_RE.search(line)          # iota form [n_groups,group_size]
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)           # explicit {{0,1,..},{..}}
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (ring-algorithm factors).

    all-gather: result is the gathered buffer -> (n-1)/n * result
    all-reduce: result == input -> 2 (n-1)/n * result (RS + AG phases)
    reduce-scatter: result is the shard -> (n-1) * result (input transit)
    all-to-all: (n-1)/n * result ; collective-permute: result
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_seg, kind = m.group(1), m.group(2)
        size = _shape_bytes(result_seg)
        n = max(_group_size(line), 1)
        if kind == "all-gather":
            wire = size * (n - 1) / n
        elif kind == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = size * (n - 1)
        elif kind == "all-to-all":
            wire = size * (n - 1) / n
        else:
            wire = float(size)
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------


def _fit_spec(rules: shd.MeshRules, spec: P, shape) -> P:
    """Drop trailing mesh axes from any dim whose size they don't divide
    (e.g. zamba's 32000 vocab over 512-way FSDP -> 32-way)."""
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    out = []
    for d, entry in enumerate(tuple(spec)):
        if entry is None or d >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        prod = 1
        for a in axes:
            if shape[d] % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
            else:
                break
        out.append(tuple(keep) if len(keep) > 1
                   else (keep[0] if keep else None))
    return P(*out)


def resolve_tree(rules: shd.MeshRules, spec_tree, shapes_tree=None):
    if shapes_tree is None:
        return jax.tree.map(lambda s: rules.named(rules.spec(*tuple(s))),
                            spec_tree, is_leaf=lambda s: isinstance(s, P))
    return jax.tree.map(
        lambda s, x: rules.named(_fit_spec(rules, rules.spec(*tuple(s)),
                                           x.shape)),
        spec_tree, shapes_tree,
        is_leaf=lambda s: isinstance(s, P))


def pick_strategy(cfg, shape, multi_pod: bool) -> str:
    """Parallelism strategy per workload (DESIGN.md §5, sharding.py).

    train: pure FSDP on one pod (1 seq/device — weight-gather collectives
    beat Megatron's activation gathers at these batch sizes); Megatron-SP
    when the pod axis shrinks the per-device batch share (multi-pod) or
    when fp32-moment-free giants need TP'd expert storage (grok).  SSM
    families can't sequence-shard (scans are sequential in S), so multi-
    pod falls back to fsdp_dp.  Serving always runs TP+sequence-sharded
    KV.
    """
    if shape.kind != "train":
        return "tp_sp"
    if cfg.family in ("ssm", "hybrid"):
        return "fsdp_dp" if multi_pod else "fsdp"
    if multi_pod or cfg.name.startswith("grok"):
        return "megatron_sp"
    return "fsdp"


SSM_CHUNK_OVERRIDE: int | None = None


def build_cell(arch: str, shape_name: str, multi_pod: bool, variant: str,
               remat: str, strategy: str | None = None):
    cfg = get_config(arch)
    if SSM_CHUNK_OVERRIDE and cfg.ssm is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, ssm=_dc.replace(
            cfg.ssm, chunk=SSM_CHUNK_OVERRIDE))
    shape = SHAPES[shape_name]
    # perf-iteration variants (EXPERIMENTS.md §Perf):
    #   baseline     scan-flash, f32-cast logits, head_dim TP fallback
    #   flashvjp     custom-vjp FlashAttention backward (it. A1)
    #   optimized    flashvjp + mixed-precision logits dot + padded
    #                head-TP (iterations A2/B2)
    #   fused        optimized + packed-ingest train step
    #   compressed   optimized + int8 cross-pod gradient all-reduce
    from repro.models import attention as _attn
    from repro.models import layers as _layers
    from repro.models import transformer as _tfm
    _attn.FLASH_IMPL = "scan" if variant == "baseline" else "vjp"
    _attn.HEAD_TP = "head_dim" if variant in ("baseline", "flashvjp") \
        else "padded"
    _layers.XENT_MM = "cast" if variant in ("baseline", "flashvjp") \
        else "mixed"
    _tfm.KV_CACHE_QUANT = (variant == "kvint8")  # int8 GQA decode cache
    model = build_model(cfg, remat=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.MeshRules(
        mesh, strategy=strategy or pick_strategy(cfg, shape, multi_pod))

    if shape.kind == "train":
        opt = OptConfig()
        # SSM/hybrid multi-pod: activations scale with the 8-seq/device
        # batch share (scans can't sequence-shard) — grad accumulation
        # over 8 microbatches restores the 1-seq/device footprint.
        micro = 8 if rules.strategy in ("fsdp_dp", "tp_dp") else 1
        step = make_train_step(model, opt, microbatches=micro)
        state_shapes, state_specs = abstract_train_state(
            model, cfg.opt_dtype)
        batch, batch_specs = train_input_specs(cfg, shape)
        if variant == "fused":
            if cfg.frontend != "none":
                raise SystemExit("fused variant needs a token frontend")
            from repro.data.fused_ingest import (
                make_fused_train_step, packed_input_spec)
            step = make_fused_train_step(step)
            batch = packed_input_spec(shape.global_batch, shape.seq_len,
                                      cfg.vocab_size)
            batch_specs = P("dp", None, None)
        elif variant == "compressed":
            if not multi_pod:
                raise SystemExit("compressed variant needs the pod axis")
            from repro.distributed.compression import (
                abstract_compressed_state, make_compressed_train_step)
            step = make_compressed_train_step(model, opt, rules)
            state_shapes, state_specs = abstract_compressed_state(
                state_shapes, state_specs, n_pods=2)
        in_sh = (resolve_tree(rules, state_specs, state_shapes),
                 resolve_tree(rules, batch_specs))
        out_sh = (in_sh[0], None)
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
        args = (state_shapes, batch)
    elif shape.kind == "prefill":
        params_shapes, param_specs = model.abstract()
        batch, batch_specs = train_input_specs(cfg, shape)
        batch = {k: v for k, v in batch.items() if k != "labels"}
        batch_specs = {k: v for k, v in batch_specs.items()
                       if k != "labels"}
        _, cache_specs = model.abstract_cache(
            shape.global_batch, shape.seq_len)
        fn = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(resolve_tree(rules, param_specs,
                                                params_shapes),
                                   resolve_tree(rules, batch_specs)),
                     out_shardings=(None,
                                    resolve_tree(rules, cache_specs)))
        args = (params_shapes, batch)
    else:  # decode
        params_shapes, param_specs = model.abstract()
        B, S = shape.global_batch, shape.seq_len
        cache, cache_specs = model.abstract_cache(B, S)
        tokens, tok_spec = decode_input_specs(cfg, shape)
        cache_sh = resolve_tree(rules, cache_specs)
        fn = jax.jit(model.decode_step,
                     in_shardings=(resolve_tree(rules, param_specs,
                                                params_shapes),
                                   resolve_tree(rules, tok_spec),
                                   cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(2,))
        args = (params_shapes, tokens, cache)

    return cfg, shape, mesh, rules, fn, args


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 tok/seq


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             variant: str = "baseline", remat: str = "full",
             strategy: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant, "remat": remat, "ok": False}
    cfg = get_config(arch)
    if shape_name not in cfg.supported_shapes:
        rec.update(skipped=True,
                   reason="long_500k needs sub-quadratic attention")
        return rec
    t0 = time.time()
    cfg, shape, mesh, rules, fn, args = build_cell(
        arch, shape_name, multi_pod, variant, remat, strategy)
    rec["strategy"] = rules.strategy
    with shd.use_rules(rules):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    from repro.launch.hlo_analysis import analyze
    hlo = analyze(compiled.as_text())
    coll = hlo["collective"]
    n_dev = mesh.devices.size

    # scan-aware analyzer terms (XLA's cost_analysis counts while bodies
    # once; keep its raw numbers for reference).  The memory term uses
    # the TPU-order fused-bytes estimate; the count-everything bound is
    # recorded as hlo_bytes_upper.
    flops_dev = float(hlo["flops"])
    bytes_dev = float(hlo["bytes_fused"])
    coll_dev = float(coll["total"])
    mf = model_flops(cfg, shape)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]

    rec.update(
        ok=True,
        n_devices=int(n_dev),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_hbm_bytes=(mem.argument_size_in_bytes
                            + mem.output_size_in_bytes
                            + mem.temp_size_in_bytes
                            - mem.alias_size_in_bytes),
        ),
        hlo_flops_per_dev=flops_dev,
        hlo_bytes_per_dev=bytes_dev,
        hlo_bytes_upper=float(hlo["bytes"]),
        xla_raw_flops=float(cost.get("flops", 0.0)),
        xla_raw_bytes=float(cost.get("bytes accessed", 0.0)),
        collective=coll,
        collective_count=hlo["collective_count"],
        model_flops_total=mf,
        useful_flops_ratio=mf / max(flops_dev * n_dev, 1.0),
        roofline=dict(
            compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
            dominant=dom,
            step_s_bound=max(compute_s, memory_s, coll_s),
            roofline_fraction=compute_s / max(compute_s, memory_s,
                                              coll_s),
        ),
    )
    return rec


def cell_path(rec_or_key) -> pathlib.Path:
    if isinstance(rec_or_key, dict):
        key = (f"{rec_or_key['arch']}.{rec_or_key['shape']}."
               f"{rec_or_key['mesh']}.{rec_or_key['variant']}."
               f"{rec_or_key['remat']}")
    else:
        key = rec_or_key
    return RESULTS_DIR / f"{key}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--strategy", default=None,
                    help="override the parallelism strategy for the cell")
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="override cfg.ssm.chunk (SSD/WKV chunk sweep)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.ssm_chunk:
        global SSM_CHUNK_OVERRIDE
        SSM_CHUNK_OVERRIDE = args.ssm_chunk

    archs = [args.arch] if args.arch else list(registry())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                key = f"{arch}.{shape}.{mesh_name}.{args.variant}." \
                      f"{args.remat}"
                if args.strategy:
                    key += f".{args.strategy}"
                if args.ssm_chunk:
                    key += f".c{args.ssm_chunk}"
                path = cell_path(key)
                if path.exists() and not args.force:
                    print(f"[dryrun] {key}: cached", flush=True)
                    continue
                print(f"[dryrun] {key}: lowering...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=multi,
                                   variant=args.variant, remat=args.remat,
                                   strategy=args.strategy)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "variant": args.variant, "remat": args.remat,
                           "ok": False, "error": repr(e)[:1000],
                           "traceback": traceback.format_exc()[-3000:]}
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
                if rec.get("skipped"):
                    print(f"[dryrun] {key}: SKIP ({rec['reason']})",
                          flush=True)
                elif rec["ok"]:
                    r = rec["roofline"]
                    print(f"[dryrun] {key}: OK compile={rec['compile_s']}s "
                          f"dom={r['dominant']} "
                          f"frac={r['roofline_fraction']:.2f} "
                          f"peak_hbm={rec['memory']['peak_hbm_bytes']/2**30:.2f}GiB",
                          flush=True)
                else:
                    print(f"[dryrun] {key}: FAIL {rec['error'][:200]}",
                          flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
