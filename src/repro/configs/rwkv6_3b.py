"""RWKV6-3B ("Finch") — attention-free RNN LM with data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b; verified-tier: hf]
32L, d_model=2560 (40 heads of size 64), d_ff=8960, vocab=65536.

Runs long_500k: decode is O(1)-state (per-head 64x64 wkv state), no KV cache.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6_3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads (d_model / 64)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    act="relu_sq",         # rwkv channel-mix uses squared relu
    norm="layernorm",
    attention="none",
    ssm=SSMConfig(
        d_state=64,        # state is head_dim x head_dim per head
        head_dim=64,
        chunk=16,  # tuned: EXPERIMENTS §Perf C'2 (bytes ~ c; c=16 is -19% bound)
    ),
    source="arXiv:2404.05892; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="rwkv6_3b_smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=224,
    vocab_size=256,
    act="relu_sq",
    norm="layernorm",
    attention="none",
    ssm=SSMConfig(
        d_state=16,
        head_dim=16,
        chunk=16,
    ),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
