"""Granite-20B (code) — llama-style dense LM with MQA (single KV head).

[arXiv:2405.04324; hf:ibm-granite/granite-20b-code-base; verified-tier: hf]
52L, d_model=6144, 48 heads (kv=1, i.e. multi-query), d_ff=24576, vocab=49152.
Assignment classifies it llama-arch; we use RMSNorm + gated SiLU accordingly.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention="gqa",
    source="arXiv:2405.04324; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="granite_20b_smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=1,          # preserve the MQA property
    head_dim=16,
    d_ff=384,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="gqa",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
