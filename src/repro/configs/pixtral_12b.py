"""Pixtral-12B — VLM: Pixtral-ViT frontend + Mistral-NeMo-style decoder.

[hf:mistralai/Pixtral-12B-2409; verified-tier: unverified]
40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128 so H*hd=4096 != d_model),
d_ff=14336, vocab=131072.

Backbone only per the assignment: the vision tower is a STUB —
``input_specs()`` provides precomputed patch embeddings (B, P, d_model) that
occupy the first P positions of the sequence, with text tokens after them.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attention="gqa",
    frontend="vision_stub",
    n_frontend_tokens=1024,   # precomputed patch-embedding positions
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

SMOKE_CONFIG = ArchConfig(
    name="pixtral_12b_smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,           # H*hd = 64 != d_model, like the real config
    d_ff=256,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="gqa",
    frontend="vision_stub",
    n_frontend_tokens=16,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
