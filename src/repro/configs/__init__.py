from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    get_config,
    registry,
)
