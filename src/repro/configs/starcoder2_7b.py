"""StarCoder2-7B — dense code LM with GQA + RoPE.

[arXiv:2402.19173; hf:bigcode/starcoder2-7b; verified-tier: hf]
32L, d_model=4608, 36 heads (GQA kv=4), d_ff=18432, vocab=49152.
StarCoder2 uses non-gated GELU MLPs and LayerNorm.

TP note: 36 heads % 16 != 0, so the sharding rules shard head_dim (128)
over the model axis for this arch (DESIGN.md §5).
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    rope_theta=100_000.0,
    attention="gqa",
    source="arXiv:2402.19173; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="starcoder2_7b_smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,           # keep the H % mesh != 0 property in miniature
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    act="gelu",
    norm="layernorm",
    attention="gqa",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
