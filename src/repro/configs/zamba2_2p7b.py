"""Zamba2-2.7B — hybrid: Mamba2 backbone + periodic weight-SHARED attention.

[arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B; verified-tier: hf]
54 Mamba2 layers, d_model=2560, ssm_state=64; one shared attention+MLP block
(32 heads, MHA kv=32, d_ff=10240) applied every 6 SSM layers (9 applications,
one weight set).  vocab=32000.

Runs long_500k: the backbone is sub-quadratic; the shared attention block's
KV cache is sequence-sharded at decode.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2_2p7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,           # 2560 / 32
    d_ff=10240,
    vocab_size=32000,
    act="gelu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention="gqa",
    ssm=SSMConfig(
        d_state=64,
        d_conv=4,
        expand=2,
        head_dim=64,
        chunk=256,
        attn_every=6,
    ),
    source="arXiv:2411.15242; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2_2p7b_smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    norm="rmsnorm",
    attention="gqa",
    ssm=SSMConfig(
        d_state=16,
        d_conv=4,
        expand=2,
        head_dim=16,
        chunk=16,
        attn_every=2,
    ),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
