"""Yi-9B — llama-architecture dense LM with GQA.

[arXiv:2403.04652; hf:01-ai/Yi-9B; verified-tier: hf]
48L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
RMSNorm + gated-SiLU MLP + RoPE (theta 5e6 per the Yi release).
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi_9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    attention="gqa",
    source="arXiv:2403.04652; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="yi_9b_smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=320,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="gqa",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
