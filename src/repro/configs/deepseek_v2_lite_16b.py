"""DeepSeek-V2-Lite (16B) — MoE LM with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite; verified-tier: hf]
27L, d_model=2048, 16 heads, MLA kv_lora_rank=512 (no q-lora in Lite),
qk_nope=128 / qk_rope=64 / v=128 per head.  MoE: 64 routed experts top-6
+ 2 shared experts, expert d_ff=1408; the first layer is dense (d_ff=10944).

NOTE: the assignment line reads "MoE 64e top-6 ... 2 shared+160 routed
top-6"; 160 routed is the full DeepSeek-V2 figure — we follow the Lite
config (64 routed), recorded in DESIGN.md §4.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,          # v head dim (MLA overrides per-component dims)
    d_ff=10944,            # dense-layer FFN width (layer 0)
    vocab_size=102400,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_routed=64,
        n_shared=2,
        top_k=6,
        d_ff_expert=1408,
        first_dense=1,
    ),
    source="arXiv:2405.04434; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b_smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="mla",
    mla=MLAConfig(
        kv_lora_rank=32,
        q_lora_rank=0,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    moe=MoEConfig(
        n_routed=8,
        n_shared=1,
        top_k=2,
        d_ff_expert=32,
        first_dense=1,
        # no capacity drops at smoke scale so prefill == decode exactly
        # (the full config keeps the default 1.25)
        capacity_factor=8.0,
    ),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
