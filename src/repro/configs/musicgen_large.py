"""MusicGen-large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf:facebook/musicgen-large; verified-tier: hf]
48L, d_model=2048, 32 heads (MHA), d_ff=8192, vocab=2048 (EnCodec codebook).

Backbone only per the assignment: the EnCodec frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (B, S, d_model)
instead of raw audio; the LM head predicts codebook tokens (vocab 2048).
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,           # 2048 / 32
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    attention="gqa",
    frontend="audio_stub",
    source="arXiv:2306.05284; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="musicgen_large_smoke",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=128,
    act="gelu",
    norm="layernorm",
    attention="gqa",
    frontend="audio_stub",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
