"""DeepSeek-67B — llama-architecture dense LM (deep: 95 layers).

[arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base; verified-tier: hf]
95L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400.
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention="gqa",
    source="arXiv:2401.02954; hf",
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek_67b_smoke",
    family="dense",
    n_layers=3,            # odd layer count, like 95
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=352,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="gqa",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
