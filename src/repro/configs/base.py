"""Architecture / run configuration system.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the full published configuration) and ``SMOKE_CONFIG`` (a reduced
same-family configuration used by CPU smoke tests).  ``registry()`` resolves
``--arch <id>`` names for the launchers.

Input shapes are global: each architecture is paired with the LM shape set
(train_4k / prefill_32k / decode_32k / long_500k); ``supported_shapes``
filters out ``long_500k`` for pure full-attention families per the
assignment (recorded in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Mapping

import jax.numpy as jnp

# --------------------------------------------------------------------------
# Input shapes (assignment-defined; global_batch x seq_len per cell).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Mapping[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# Architecture config.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0          # routed experts
    n_shared: int = 0          # always-on shared experts
    top_k: int = 0
    d_ff_expert: int = 0       # per-expert FFN width
    first_dense: int = 0       # leading dense layers (deepseek-v2 style)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0       # 0 => direct q projection (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64         # mamba2 P / rwkv head size
    chunk: int = 256           # SSD / wkv chunk length
    # zamba-style hybrid: apply one weight-shared attention block every
    # `attn_every` ssm layers (0 = never).
    attn_every: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    act: str = "silu_gated"    # silu_gated | gelu
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    attention: str = "gqa"     # gqa | mla | none (attention-free)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    frontend: str = "none"     # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0  # patch/frame positions supplied pre-embedded
    tie_embeddings: bool = False
    source: str = ""           # provenance tag ([arXiv/hf; tier])

    # numerics
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    opt_dtype: Any = jnp.float32

    # ----------------------------------------------------------------
    @property
    def quadratic_attention(self) -> bool:
        """True when every token attends over the full prefix via softmax
        attention (i.e. no sub-quadratic path exists)."""
        if self.attention == "none":
            return False
        if self.ssm is not None and self.ssm.attn_every:
            return False  # hybrid: SSM backbone, periodic attention
        return True

    @property
    def supported_shapes(self) -> tuple[str, ...]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if not self.quadratic_attention:
            names.append("long_500k")
        return tuple(names)

    # ----------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once unless tied)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "mla":
            assert self.mla is not None
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * self.n_heads * qk_head  # q proj (direct, lite)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down
            per_layer += m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim)  # kv up
            per_layer += self.n_heads * m.v_head_dim * d  # o proj
        elif self.attention == "gqa":
            per_layer += d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            per_layer += self.n_heads * self.head_dim * d
        # FFN
        ff_mult = 3 if self.act == "silu_gated" else 2
        if self.moe is not None:
            experts = self.moe.n_routed + self.moe.n_shared
            per_layer += experts * ff_mult * d * self.moe.d_ff_expert
            per_layer += d * self.moe.n_routed  # router
            dense_ff = self.moe.first_dense * ff_mult * d * self.d_ff
        else:
            per_layer += ff_mult * d * self.d_ff
            dense_ff = 0
        if self.ssm is not None and self.attention != "none":
            # hybrid: per_layer above counted attention for every layer; the
            # shared block is counted once instead.
            pass
        if self.family in ("ssm", "hybrid"):
            per_layer = self._ssm_layer_params()
            shared = 0
            if self.ssm and self.ssm.attn_every:
                shared = (d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                          + self.n_heads * self.head_dim * d
                          + ff_mult * d * self.d_ff)
            return emb + L * per_layer + shared
        return emb + L * per_layer + dense_ff

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        if self.family == "ssm":  # rwkv6: tmix ~4*d*d + cmix ~2*d*ff-ish
            return 4 * d * d + 2 * d * self.d_ff + 6 * d
        assert self.ssm is not None
        d_in = self.ssm.expand * d
        n_heads = d_in // self.ssm.head_dim
        # mamba2: in_proj (z,x,B,C,dt) + out_proj + conv + A,D
        zx = 2 * d_in
        bc = 2 * self.ssm.d_state  # B, C (single group)
        return d * (zx + bc + n_heads) + d_in * d + self.ssm.d_conv * (
            d_in + 2 * self.ssm.d_state) + 2 * n_heads

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k routed)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        ff_mult = 3 if self.act == "silu_gated" else 2
        full = self.param_count()
        experts_all = (self.moe.n_routed + self.moe.n_shared) * ff_mult * d * \
            self.moe.d_ff_expert * self.n_layers
        experts_active = (self.moe.top_k + self.moe.n_shared) * ff_mult * d * \
            self.moe.d_ff_expert * self.n_layers
        return full - experts_all + experts_active


# --------------------------------------------------------------------------
# Registry.
# --------------------------------------------------------------------------

ARCH_IDS = (
    "starcoder2_7b",
    "yi_9b",
    "deepseek_67b",
    "granite_20b",
    "deepseek_v2_lite_16b",
    "grok1_314b",
    "zamba2_2p7b",
    "musicgen_large",
    "rwkv6_3b",
    "pixtral_12b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def registry() -> dict[str, ArchConfig]:
    out = {}
    for arch_id in ARCH_IDS:
        mod = importlib.import_module(f"repro.configs.{arch_id}")
        out[arch_id] = mod.CONFIG
    return out


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    arch_id = _ALIASES.get(name, name)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG
