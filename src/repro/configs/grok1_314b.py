"""Grok-1 (314B) — MoE LM, 8 experts top-2.

[hf:xai-org/grok-1; verified-tier: unverified]
64L, d_model=6144, 48 heads (GQA kv=8), expert d_ff=32768, vocab=131072.
With gated MLPs the analytic total is ~314B params (ArchConfig.param_count).
"""

import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok1_314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    act="silu_gated",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention="gqa",
    moe=MoEConfig(
        n_routed=8,
        n_shared=0,
        top_k=2,
        d_ff_expert=32768,
        first_dense=0,
    ),
    source="hf:xai-org/grok-1; unverified",
    # 314B params on 256 x 16 GB: fp32 Adam moments alone would be
    # 9.8 GB/chip — bf16 moments keep the train state under 10 GB/chip
    # (stochastic-rounding caveat recorded in EXPERIMENTS.md).
    opt_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ArchConfig(
    name="grok1_314b_smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    act="silu_gated",
    norm="rmsnorm",
    attention="gqa",
    moe=MoEConfig(
        n_routed=4,
        n_shared=0,
        top_k=2,
        d_ff_expert=64,
        first_dense=0,
    ),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
)
