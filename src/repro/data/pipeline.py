"""ObjectDataLoader — VOL-planned batch fetch with prefetch overlap.

The loader is the GlobalVOL acting as a training-data client:

  * deterministic: (seed, epoch) -> permutation of sequence rows; a step
    is a pure function of the loader state, so restart-from-checkpoint
    replays the exact same data order (fault tolerance requirement);
  * data-parallel aligned: each host/dp-rank fetches only its slice of
    the global batch (``dp_rank``/``dp_size``), and the per-object
    sub-requests run storage-side (select pushdown) so only that slice
    moves — compiled and executed through the shared ``ScanEngine``
    (``fetch_objects``), so a plain fetch rides the server-concat plane
    (ONE framed table response per OSD) and a packed fetch gathers raw
    word partials, never one request per contiguous run;
  * packed mode: rows are fetched as planar-bitpacked words via the
    zero-decode ``select_packed`` objclass op — bytes on the wire (and
    into HBM) are ~bits/32 of raw, and the unpack happens in the
    compiled step (``data.fused_ingest``);
  * prefetch: a background thread keeps ``prefetch`` batches ahead, so
    storage latency overlaps step compute;
  * windowed streaming (``window_steps > 1``): the producer fetches
    several steps' runs in ONE streaming gather and assembles each
    step's batch the moment ITS frames land (``ScanEngine.
    fetch_objects_stream`` delivers per-OSD frames in arrival order),
    so early batches reach the trainer while the slowest OSD is still
    serving later steps' rows — batches stay bit-identical and in step
    order;
  * straggler mitigation: reads hedge to a replica after
    ``hedge_timeout_s`` (paper: "fully leveraging ... load balancing ...
    of distributed storage systems").
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.core import objclass as oc
from repro.core.logical import RowRange
from repro.core.partition import ObjectMap
from repro.core.vol import GlobalVOL


@dataclasses.dataclass
class LoaderState:
    """Serializable resume point (stored inside checkpoints)."""

    step: int = 0

    def to_json(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def from_json(d: dict) -> "LoaderState":
        return LoaderState(step=int(d["step"]))


class ObjectDataLoader:
    def __init__(
        self,
        vol: GlobalVOL,
        dataset_name: str,
        *,
        global_batch: int,
        dp_rank: int = 0,
        dp_size: int = 1,
        seed: int = 0,
        packed: bool = False,
        prefetch: int = 2,
        window_steps: int = 1,
        hedge_timeout_s: float | None = None,
        start_step: int = 0,
    ):
        if global_batch % dp_size:
            raise ValueError(f"global_batch {global_batch} % dp_size "
                             f"{dp_size} != 0")
        if window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, "
                             f"got {window_steps}")
        if window_steps > 1 and prefetch < 1:
            raise ValueError("window_steps > 1 needs the prefetch "
                             "producer (prefetch >= 1) — the windowed "
                             "streaming fetch runs there")
        if window_steps > 1 and hedge_timeout_s is not None:
            raise ValueError("window_steps > 1 cannot combine with "
                             "hedge_timeout_s (hedged reads bypass the "
                             "engine's streaming gather)")
        self.vol = vol
        self.omap: ObjectMap = vol.open(dataset_name)
        self.ds = self.omap.dataset
        self.global_batch = global_batch
        self.local_batch = global_batch // dp_size
        self.dp_rank, self.dp_size = dp_rank, dp_size
        self.seed = seed
        self.packed = packed
        self.window_steps = window_steps
        self.hedge_timeout_s = hedge_timeout_s
        self.state = LoaderState(step=start_step)
        self.steps_per_epoch = max(self.ds.n_rows // global_batch, 1)
        # streaming-consume observability: set per window by the
        # windowed producer — how many of the window's per-object
        # results had landed when its FIRST batch was assembled (the
        # "first batch out before the slowest OSD finished" claim)
        self.last_window_stats: dict | None = None

        self._prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if prefetch > 0:
            self._thread = threading.Thread(
                target=self._producer, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ ordering
    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        return rng.permutation(self.ds.n_rows)

    def rows_for_step(self, step: int) -> np.ndarray:
        """Global row ids of this dp-rank's slice of the step's batch."""
        epoch = step // self.steps_per_epoch
        within = step % self.steps_per_epoch
        perm = self._epoch_perm(epoch)
        batch = perm[within * self.global_batch:
                     (within + 1) * self.global_batch]
        if batch.size < self.global_batch:  # tail: wrap deterministically
            batch = np.concatenate(
                [batch, perm[:self.global_batch - batch.size]])
        return np.sort(batch[self.dp_rank::self.dp_size])

    # ------------------------------------------------------------ fetch
    def _runs_for(self, rows: np.ndarray) -> list[tuple]:
        """Group sorted rows into per-object contiguous runs:
        (extent, run, lo, hi) tuples."""
        runs: list[tuple] = []
        i = 0
        while i < len(rows):
            subs = self.omap.lookup(RowRange(int(rows[i]),
                                             int(rows[i]) + 1))
            extent, _ = subs[0]
            j = i
            while j < len(rows) and rows[j] < extent.row_stop:
                j += 1
            run = rows[i:j]
            lo = int(run[0] - extent.row_start)
            hi = int(run[-1] - extent.row_start) + 1
            runs.append((extent, run, lo, hi))
            i = j
        return runs

    def _run_pipelines(self, runs: list[tuple]) -> list[list]:
        if self.packed:
            return [[oc.op("select_packed", rows=(lo, hi), col="tokens")]
                    for _, _, lo, hi in runs]
        # row_slice carries GLOBAL dataset rows; each OSD resolves its
        # object's sub-range from its own extent xattr at execute time
        # (same pushed-down row-range plane as Scan.rows)
        return [[oc.op("row_slice", rows=(e.row_start + lo,
                                          e.row_start + hi)),
                 oc.op("project", cols=["tokens"])]
                for e, _, lo, hi in runs]

    def _assemble(self, runs: list[tuple],
                  results: list) -> dict[str, np.ndarray]:
        """Per-run results (aligned with ``runs``) -> one batch."""
        if self.packed:
            packed_parts = []
            for (extent, run, lo, _), res in zip(runs, results):
                words = res["packed"]          # (hi-lo, S/32, bits)
                keep = (run - extent.row_start - lo).astype(np.int64)
                packed_parts.append(words[keep])
            return {"tokens_packed": np.concatenate(packed_parts, axis=0)}

        parts = []
        for (extent, run, lo, _), tab in zip(runs, results):
            keep = (run - extent.row_start - lo).astype(np.int64)
            parts.append(tab["tokens"][keep])
        toks = np.concatenate(parts, axis=0)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1  # no target across sequence boundary
        return {"tokens": toks, "labels": labels}

    def _fetch_rows(self, rows: np.ndarray) -> dict[str, np.ndarray]:
        """Group sorted rows into per-object contiguous runs, then fetch
        ALL runs with one batched objclass request per OSD (packed or
        decoded) — the train input path pays fabric ops per OSD, not per
        run."""
        runs = self._runs_for(rows)
        results = self._exec_runs(runs, self._run_pipelines(runs))
        return self._assemble(runs, results)

    def _fetch_window(self, start_step: int):
        """Windowed streaming fetch: ONE gather for ``window_steps``
        steps' runs, yielding ``(step, batch)`` in step order as each
        step's frames land — the engine streams per-OSD result frames
        in arrival order, so step s's batch goes out the moment ITS
        runs are complete, even while the slowest OSD is still serving
        later steps' rows."""
        steps = list(range(start_step, start_step + self.window_steps))
        runs_per_step = [self._runs_for(self.rows_for_step(s))
                         for s in steps]
        flat_runs = [r for runs in runs_per_step for r in runs]
        owner = [k for k, runs in enumerate(runs_per_step)
                 for _ in runs]
        results: list = [None] * len(flat_runs)
        missing = [len(runs) for runs in runs_per_step]
        emitted = 0
        landed = 0
        for i, res in self.vol.engine.fetch_objects_stream(
                [e.name for e, _, _, _ in flat_runs],
                self._run_pipelines(flat_runs), packed=self.packed):
            results[i] = res
            landed += 1
            missing[owner[i]] -= 1
            # flush every leading step whose runs are all present (step
            # order is the loader's determinism contract)
            while emitted < len(steps) and missing[emitted] == 0:
                if emitted == 0:
                    self.last_window_stats = {
                        "results_at_first_yield": landed,
                        "total_results": len(flat_runs),
                        "window_steps": self.window_steps,
                    }
                lo = sum(len(r) for r in runs_per_step[:emitted])
                runs = runs_per_step[emitted]
                yield steps[emitted], self._assemble(
                    runs, results[lo:lo + len(runs)])
                emitted += 1

    def _exec_runs(self, runs: list[tuple], pipelines: list[list]):
        """Per-run results (decoded tables, or packed word partials),
        aligned with ``runs``."""
        names = [e.name for e, _, _, _ in runs]
        if self.hedge_timeout_s is not None:
            # hedged read of the raw objects, then local pipelines: used
            # when an OSD is straggling (exec would block on the slow
            # primary).  The loader resolves row_slice itself — it
            # knows each run's extent from the omap it planned with.
            return [oc.run_pipeline(
                self.vol.store.get_hedged(e.name, self.hedge_timeout_s),
                oc.resolve_row_slice(p, (e.row_start, e.row_stop),
                                     clamp=True),
                encode=False)
                for (e, _, _, _), p in zip(runs, pipelines)]
        return self.vol.engine.fetch_objects(names, pipelines,
                                             packed=self.packed)

    # ------------------------------------------------------------ iterate
    def make_batch(self, step: int) -> dict[str, np.ndarray]:
        return self._fetch_rows(self.rows_for_step(step))

    def _producer(self) -> None:
        step = self.state.step
        # hedged reads bypass the engine (per-object raw gets), so the
        # windowed streaming consume only applies without them
        windowed = self.window_steps > 1 and self.hedge_timeout_s is None
        while not self._stop.is_set():
            try:
                if windowed:
                    for _, batch in self._fetch_window(step):
                        self._q.put(batch)
                        step += 1
                        if self._stop.is_set():
                            return
                else:
                    self._q.put(self.make_batch(step))
                    step += 1
            except Exception as e:  # surface in consumer
                self._q.put(e)
                return

    def __next__(self) -> dict[str, np.ndarray]:
        if self._thread is None:
            batch = self.make_batch(self.state.step)
        else:
            batch = self._q.get()
            if isinstance(batch, Exception):
                raise batch
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def seek(self, step: int) -> None:
        """Reposition the loader so the NEXT consumed batch is
        ``step``'s.  A batch is a pure function of (seed, step), so a
        seek is exact: the prefetch producer is restarted at the new
        position and re-fills its window from there — how the trainer
        resumes from a checkpoint without losing prefetch/windowed
        overlap.  A seek to the current position is free (the already-
        prefetched batches stay valid)."""
        if step == self.state.step:
            return  # queue holds [state.step, ...) — already positioned
        if self._thread is not None:
            self._stop.set()
            while self._thread.is_alive():  # unblock a parked producer
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    self._thread.join(timeout=0.005)
            self._thread = None
        self.state.step = step
        if self._prefetch > 0:
            self._q = queue.Queue(maxsize=max(self._prefetch, 1))
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._producer, daemon=True)
            self._thread.start()

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            while True:  # drain so the producer can exit
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
