"""Object-store-backed training data pipeline (the paper's infrastructure
applied to the LM input path)."""
