"""Synthetic LM corpus mapped into the object store.

The corpus is a LogicalDataset of whole training sequences:
  columns: tokens  int32 (seq_len,)   — planar-bitpacked at rest
           doc_id  int32              — provenance tag (filter demos)
           quality float32            — score column (filter/agg demos)

Token stream: a two-level Zipf-Markov sampler — cheap, deterministic, and
non-uniform enough that compression and loss curves behave like text.
Everything is written through GlobalVOL so partitioning, placement,
replication, and codecs all come from the paper's machinery.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.logical import Column, LogicalDataset, RowRange
from repro.core.partition import ObjectMap, PartitionPolicy
from repro.core.vol import GlobalVOL


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    name: str = "corpus"
    n_seqs: int = 1024
    seq_len: int = 256
    vocab_size: int = 50_000
    seed: int = 0

    def dataset(self) -> LogicalDataset:
        if self.seq_len % 32:
            raise ValueError("seq_len must be a multiple of 32 "
                             "(planar bitpack group size)")
        return LogicalDataset(
            self.name,
            (Column("tokens", "int32", (self.seq_len,)),
             Column("doc_id", "int32"),
             Column("quality", "float32")),
            n_rows=self.n_seqs,
            unit_rows=max(1, min(64, self.n_seqs)),
        )


def synth_tokens(rng: np.random.Generator, n_seqs: int, seq_len: int,
                 vocab: int) -> np.ndarray:
    """Zipf unigrams + short Markov motifs (repeat-prev with p=0.3)."""
    # Zipf ranks -> token ids; clip to vocab
    z = rng.zipf(1.3, size=(n_seqs, seq_len)).astype(np.int64)
    toks = (z % vocab).astype(np.int32)
    rep = rng.random((n_seqs, seq_len)) < 0.3
    rep[:, 0] = False
    out = toks.copy()
    for j in range(1, seq_len):
        out[:, j] = np.where(rep[:, j], out[:, j - 1], toks[:, j])
    return out


def build_corpus(vol: GlobalVOL, spec: CorpusSpec,
                 policy: PartitionPolicy | None = None,
                 *, chunk_rows: int = 512) -> ObjectMap:
    """Generate and ingest the corpus through the VOL (chunked so memory
    stays bounded for big corpora)."""
    ds = spec.dataset()
    policy = policy or PartitionPolicy(
        target_object_bytes=4 << 20, max_object_bytes=32 << 20)
    omap = vol.create(ds, policy)
    rng = np.random.default_rng(spec.seed)
    for start in range(0, spec.n_seqs, chunk_rows):
        stop = min(start + chunk_rows, spec.n_seqs)
        n = stop - start
        table = {
            "tokens": synth_tokens(rng, n, spec.seq_len, spec.vocab_size),
            "doc_id": rng.integers(0, max(spec.n_seqs // 16, 1),
                                   n).astype(np.int32),
            "quality": rng.beta(4, 2, n).astype(np.float32),
        }
        vol.write(omap, table, rows=RowRange(start, stop))
    return omap
