"""Fused in-step ingest: storage-side compression decoded on-device.

The paper's `compress` offload, adapted to the TPU input path: objects
store tokens planar-bitpacked; the loader ships the *packed words* to the
device, and the unpack (+ label derivation, which the storage layer knows
is a row shift — dataset semantics made available to the system, paper
goal 1) happens inside the compiled train step, shard-locally.

Input-path bytes per token: 8 (tokens+labels int32) -> bits/8 (~2.1 for a
17-bit vocab) — a 3.8x reduction in host->device and HBM traffic for the
batch, with zero collectives added (elementwise unpack).
"""

from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.format import bitpack_width
from repro.core.pushdown_jax import unpack_bitpacked


def pack_batch(tokens: np.ndarray, bits: int) -> np.ndarray:
    """(B, S) int32 -> (B, S//32, bits) uint32 planar words (host side —
    i.e. what the OSD already stores; see objclass.select_packed)."""
    from repro.core.format import bitpack_encode
    B, S = tokens.shape
    if S % 32:
        raise ValueError("S must be a multiple of 32")
    return bitpack_encode(tokens.ravel(), bits).reshape(B, S // 32, bits)


def unpack_tokens(packed: jax.Array, *, use_pallas: bool = False,
                  interpret: bool = False) -> jax.Array:
    """(B, G, bits) uint32 -> (B, G*32) int32, in-graph.

    ``use_pallas`` routes the unpack through the hand-tiled VPU kernel
    (``kernels/bitunpack``; raises unless G % 4 == 0, the 128-lane row
    requirement) instead of the GSPMD-partitionable jnp reference —
    same planar layout, bit-identical values, but with explicit VMEM
    tiling for the TPU input path.  ``interpret`` runs that kernel in
    interpret mode (CPU tests).
    """
    B, G, bits = packed.shape
    if use_pallas:
        if G % 4:
            raise ValueError(f"use_pallas needs G % 4 == 0 "
                             f"(128-lane rows), got G={G}")
        from repro.kernels.bitunpack import bitunpack, pad_to_grid
        rows = B * (G // 4)
        bm, padded = pad_to_grid(rows)
        w = packed.reshape(rows, 4, bits)
        if padded != rows:
            w = jnp.pad(w, ((0, padded - rows), (0, 0), (0, 0)))
        vals = bitunpack(w, bits=bits, block_r=bm, interpret=interpret)
        return vals[:rows].reshape(B, G * 32)
    return unpack_bitpacked(packed, bits)


def derive_labels(tokens: jax.Array) -> jax.Array:
    """labels[t] = tokens[t+1]; last position masked.  The shift is the
    dataset's logical schema, applied where the shard lives."""
    labels = jnp.roll(tokens, -1, axis=1)
    return labels.at[:, -1].set(-1)


def fused_batch(packed: jax.Array) -> dict[str, jax.Array]:
    tokens = unpack_tokens(packed)
    return {"tokens": tokens, "labels": derive_labels(tokens)}


def device_stream(loader, *, lookahead: int = 1):
    """Iterate a *packed* loader as device-resident packed words with
    transfer lookahead — the device tail of the streaming input path.

    The loader (ideally ``prefetch > 0`` and ``window_steps > 1``)
    assembles host batches while slow OSDs are still serving later
    steps; this generator keeps ``lookahead`` batches' packed words
    already ``jax.device_put`` while the caller computes on the current
    one, so OSD frames -> host window -> device words -> in-graph
    unpack (``make_fused_train_step``) form one pipeline with no serial
    hop.  Yields the device array a fused step consumes directly.
    """
    q: deque = deque()
    it = iter(loader)

    def pull() -> None:
        try:
            q.append(jax.device_put(next(it)["tokens_packed"]))
        except StopIteration:
            pass

    for _ in range(max(lookahead, 0) + 1):
        pull()
    while q:
        words = q.popleft()
        pull()
        yield words


def make_fused_train_step(base_train_step):
    """Wrap a (state, batch)->(state, metrics) step to take packed words.

    The unpack lands inside the same XLA program, so cost_analysis of the
    fused step shows the input-bytes reduction directly (benchmarked in
    benchmarks/ingest_fused.py).
    """

    def fused_step(state, packed):
        return base_train_step(state, fused_batch(packed))

    return fused_step


def packed_input_spec(global_batch: int, seq_len: int, vocab: int):
    """ShapeDtypeStruct for the packed batch (dry-run input stand-in)."""
    bits = bitpack_width(vocab - 1)
    return jax.ShapeDtypeStruct((global_batch, seq_len // 32, bits),
                                jnp.uint32)
