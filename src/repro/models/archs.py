"""Config -> model dispatch."""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.recurrent import RWKVModel, ZambaModel
from repro.models.transformer import TransformerLM


def build_model(cfg: ArchConfig, remat: str = "full"):
    if cfg.family == "ssm":
        return RWKVModel(cfg, remat=remat)
    if cfg.family == "hybrid":
        return ZambaModel(cfg, remat=remat)
    return TransformerLM(cfg, remat=remat)
