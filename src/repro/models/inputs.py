"""Input specifications per (architecture x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation) together with a
matching PartitionSpec tree — the dry-run lowers against these directly.
``make_batch`` materializes concrete random inputs at smoke scale.

Modality frontends are STUBS per the assignment: audio (musicgen) receives
precomputed EnCodec frame embeddings; vlm (pixtral) receives precomputed
ViT patch embeddings occupying the first ``n_frontend_tokens`` positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Inputs for train_step / prefill_step: the full-sequence batch."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_stub":
        batch = {"frame_embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
                 "labels": sds((B, S), jnp.int32)}
        specs = {"frame_embeds": P("dp", None, None), "labels": P("dp", None)}
    elif cfg.frontend == "vision_stub":
        Pn = cfg.n_frontend_tokens
        assert S > Pn, (S, Pn)
        batch = {"patch_embeds": sds((B, Pn, cfg.d_model), jnp.bfloat16),
                 "tokens": sds((B, S - Pn), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        specs = {"patch_embeds": P("dp", None, None),
                 "tokens": P("dp", None), "labels": P("dp", None)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        specs = {"tokens": P("dp", None), "labels": P("dp", None)}
    if shape.global_batch == 1:  # long-context: can't shard batch
        specs = jax.tree.map(
            lambda s: P(*((None,) + tuple(s)[1:])), specs,
            is_leaf=lambda s: isinstance(s, P))
    return batch, specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Inputs for serve_step: one new token per sequence."""
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    tokens = sds((B, 1), jnp.int32)
    spec = P("dp", None) if B > 1 else P(None, None)
    return tokens, spec


def make_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Concrete random batch at smoke scale."""
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio_stub":
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
                * 0.02, cfg.compute_dtype),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        Pn = cfg.n_frontend_tokens
        labels = rng.integers(0, cfg.vocab_size, (batch, seq))
        labels[:, :Pn] = -1  # no loss on patch positions
        return {
            "patch_embeds": jnp.asarray(
                rng.normal(size=(batch, Pn, cfg.d_model)).astype(np.float32)
                * 0.02, cfg.compute_dtype),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - Pn)),
                jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32),
        }
    tokens = rng.integers(0, cfg.vocab_size, (batch, seq))
    return {"tokens": jnp.asarray(tokens, jnp.int32),
            "labels": jnp.asarray(
                np.roll(tokens, -1, axis=1), jnp.int32)}
