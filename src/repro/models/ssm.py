"""State-space layers: Mamba2 (SSD, chunked) and RWKV6 ("Finch").

Both use the chunked linear-attention formulation for train/prefill —
an outer ``lax.scan`` carries the recurrent state across chunks while the
intra-chunk part is a masked einsum with decay tensors whose exponents are
all <= 0 (no overflow; see DESIGN.md §5).  Decode is the exact one-step
recurrence, so prefill-then-decode equals full-sequence processing
(asserted by tests).

Head-carrying weights are (D, H, P) so head tensors are produced and
consumed by einsum without sharded-dim reshapes.  TP: Mamba2 shards heads
(zamba2: 80 heads), RWKV6 shards the value head_dim (40 heads don't divide
the model axis).

Simplifications vs. the reference CUDA implementations (recorded here and
in DESIGN.md): Mamba2 convolves only the x-branch (not B/C); RWKV6 uses
static token-shift lerps for r/k/v/g and data-dependent (LoRA) decay for w
— the paper's defining feature.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, init_linear

# ==========================================================================
# Mamba2
# ==========================================================================


def mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.head_dim, s.d_state


def init_mamba2(key, cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, Pd, N = mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    params = {
        "wz": _dense_init(ks[0], d, (d, H, Pd), dt),
        "wx": _dense_init(ks[1], d, (d, H, Pd), dt),
        "wB": _dense_init(ks[2], d, (d, N), dt),
        "wC": _dense_init(ks[3], d, (d, N), dt),
        "wdt": _dense_init(ks[4], d, (d, H), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),        # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_w": _dense_init(ks[5], s.d_conv, (s.d_conv, H, Pd), dt),
        "norm_scale": jnp.ones((H, Pd), jnp.float32),
        "wo": _dense_init(ks[6], d_in, (H, Pd, d), dt),
    }
    specs = {
        "wz": P("fsdp", "tp", None), "wx": P("fsdp", "tp", None),
        "wB": P("fsdp", None), "wC": P("fsdp", None),
        "wdt": P("fsdp", None), "dt_bias": P(None), "A_log": P(None),
        "D_skip": P(None), "conv_w": P(None, "tp", None),
        "norm_scale": P("tp", None), "wo": P("tp", None, "fsdp"),
    }
    return params, specs


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: (B,S,H,P); w: (K,H,P)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out


def _mamba_gated_out(p, y, z, x_dtype):
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + 1e-5) * p["norm_scale"])
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bshp,hpd->bsd", y.astype(x_dtype), p["wo"])


def _mamba_proj(p, x):
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"])
    xs = jnp.einsum("bsd,dhp->bshp", x, p["wx"])
    B_ = x @ p["wB"]
    C_ = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xs, B_, C_, dt


def mamba2_forward(cfg: ArchConfig, p, x: jax.Array,
                   state_in: jax.Array | None = None,
                   *, state_out: bool = False):
    """Chunked SSD.  x: (B,S,D).  state: (B,H,P,N)."""
    s = cfg.ssm
    B, S, D = x.shape
    d_in, H, Pd, N = mamba_dims(cfg)
    c = min(s.chunk, S)
    assert S % c == 0
    nc = S // c

    z, xs_raw, B_, C_, dt = _mamba_proj(p, x)
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_w"]))
    a_log = -jnp.exp(p["A_log"]) * dt                 # (B,S,H), <= 0

    xc = jnp.moveaxis(xs.reshape(B, nc, c, H, Pd), 1, 0)
    Bc = jnp.moveaxis(B_.reshape(B, nc, c, N), 1, 0)
    Cc = jnp.moveaxis(C_.reshape(B, nc, c, N), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nc, c, H), 1, 0)
    ac = jnp.moveaxis(a_log.reshape(B, nc, c, H), 1, 0)

    if state_in is None:
        state_in = jnp.zeros((B, H, Pd, N), jnp.float32)

    def chunk_step(h0, xs_):
        xk, Bk, Ck, dtk, ak = xs_
        cum = jnp.cumsum(ak, axis=1)                  # (B,c,H) inclusive
        # SSD recurrence h_t = a_t h_{t-1} + dt_t B_t x_t; y_t = C_t h_t
        # unrolls to y_t = sum_{j<=t} (C_t.B_j) exp(cum_t - cum_j) dt_j x_j
        # (INCLUSIVE cumsum on the query side — the j == t diagonal gets
        # exp(0) = 1, so the triangle includes the diagonal).
        G = jnp.einsum("btn,bsn->bts", Ck, Bk,
                       preferred_element_type=jnp.float32)
        dec = jnp.exp(jnp.clip(cum[:, :, None, :] - cum[:, None, :, :],
                               max=0.0))              # (B,t,s,H)
        tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=0)
        W = G[..., None] * dec * tri[None, :, :, None]
        W = W * dtk[:, None, :, :]                    # weight by dt_j
        y = jnp.einsum("btsh,bshp->bthp", W, xk.astype(jnp.float32))
        # inter-chunk: y_t += C_t . (exp(cum_t) * h0)
        y = y + jnp.einsum("btn,bhpn,bth->bthp", Ck.astype(jnp.float32),
                           h0, jnp.exp(cum))
        # state update: h1 = exp(cum_last) h0 + sum_j exp(cum_last - cum_j) dt_j Bj xj
        last = cum[:, -1][:, None]                    # (B,1,H)
        w_state = jnp.exp(jnp.clip(last - cum, max=0.0)) * dtk  # (B,c,H)
        h1 = (jnp.exp(last[:, 0])[:, :, None, None] * h0
              + jnp.einsum("bsh,bshp,bsn->bhpn", w_state,
                           xk.astype(jnp.float32), Bk.astype(jnp.float32)))
        return h1, y

    state, yc = jax.lax.scan(jax.checkpoint(chunk_step), state_in,
                             (xc, Bc, Cc, dtc, ac))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, Pd)
    y = y + p["D_skip"][:, None] * xs.astype(jnp.float32)
    out = _mamba_gated_out(p, y, z, x.dtype)
    if state_out:
        conv_state = xs_raw[:, S - (s.d_conv - 1):]   # pre-conv tail
        return out, {"ssd": state, "conv": conv_state}
    return out, None


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    _, H, Pd, N = mamba_dims(cfg)
    return {
        "ssd": jnp.zeros((batch, H, Pd, N), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, H, Pd), dtype),
    }


def mamba2_decode(cfg: ArchConfig, p, x: jax.Array, state):
    """One-token recurrence.  x: (B,1,D)."""
    z, xs, B_, C_, dt = _mamba_proj(p, x)
    window = jnp.concatenate([state["conv"], xs.astype(state["conv"].dtype)],
                             axis=1)                  # (B, K, H, P)
    xs = jax.nn.silu(jnp.einsum("bkhp,khp->bhp", window, p["conv_w"]))[:, None]
    new_conv = window[:, 1:]
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt[:, 0])      # (B,H)
    kv = jnp.einsum("bhp,bn,bh->bhpn", xs[:, 0].astype(jnp.float32),
                    B_[:, 0].astype(jnp.float32), dt[:, 0])
    h = a[:, :, None, None] * state["ssd"] + kv
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), h)[:, None]
    y = y + p["D_skip"][:, None] * xs.astype(jnp.float32)
    out = _mamba_gated_out(p, y, z, x.dtype)
    return out, {"ssd": h, "conv": new_conv}


# ==========================================================================
# RWKV6 (Finch)
# ==========================================================================

_W_LORA = 64


def init_rwkv6(key, cfg: ArchConfig):
    d = cfg.d_model
    H, Pd = cfg.n_heads, cfg.head_dim
    F = cfg.d_ff
    ks = jax.random.split(key, 10)
    dt = cfg.param_dtype
    tmix = {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": _dense_init(ks[0], d, (d, H, Pd), dt),
        "wk": _dense_init(ks[1], d, (d, H, Pd), dt),
        "wv": _dense_init(ks[2], d, (d, H, Pd), dt),
        "wg": _dense_init(ks[3], d, (d, H, Pd), dt),
        "w0": jnp.full((H, Pd), -1.0, jnp.float32),   # base decay ~ exp(-e^-1)
        "wlA": _dense_init(ks[4], d, (d, _W_LORA), jnp.float32),
        "wlB": _dense_init(ks[5], _W_LORA, (_W_LORA, H, Pd), jnp.float32),
        "u": jnp.zeros((H, Pd), jnp.float32),         # per-channel bonus
        "ln_scale": jnp.ones((H, Pd), jnp.float32),   # per-head group norm
        "wo": _dense_init(ks[6], d, (H, Pd, d), dt),
    }
    tmix_specs = {
        "mu_r": P(None), "mu_k": P(None), "mu_v": P(None), "mu_g": P(None),
        "mu_w": P(None),
        "wr": P("fsdp", None, None), "wk": P("fsdp", None, None),
        "wv": P("fsdp", None, "tp"), "wg": P("fsdp", None, "tp"),
        "w0": P(None, None), "wlA": P("fsdp", None), "wlB": P(None, None, None),
        "u": P(None, None), "ln_scale": P(None, "tp"),
        "wo": P(None, "tp", "fsdp"),
    }
    cmix = {
        "mu_ck": jnp.full((d,), 0.5, jnp.float32),
        "mu_cr": jnp.full((d,), 0.5, jnp.float32),
        "wk_c": init_linear(ks[7], d, F, dt),
        "wv_c": init_linear(ks[8], F, d, dt),
        "wr_c": init_linear(ks[9], d, d, dt),
    }
    cmix_specs = {
        "mu_ck": P(None), "mu_cr": P(None),
        "wk_c": P("fsdp", "tp"), "wv_c": P("tp", "fsdp"),
        "wr_c": P("fsdp", None),
    }
    return {"tmix": tmix, "cmix": cmix}, \
        {"tmix": tmix_specs, "cmix": cmix_specs}


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)  # keep carry dtype stable


def _rwkv_project(p, x, x_prev):
    """x: (B,S,D); x_prev: previous-token hidden (B,S,D)."""
    r = jnp.einsum("bsd,dhp->bshp", _lerp(x, x_prev, p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,dhp->bshp", _lerp(x, x_prev, p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,dhp->bshp", _lerp(x, x_prev, p["mu_v"]), p["wv"])
    g = jnp.einsum("bsd,dhp->bshp", _lerp(x, x_prev, p["mu_g"]), p["wg"])
    xw = _lerp(x, x_prev, p["mu_w"]).astype(jnp.float32)
    lora = jnp.einsum("bsl,lhp->bshp", jnp.tanh(xw @ p["wlA"]), p["wlB"])
    logw = -jnp.exp(p["w0"] + lora)                   # (B,S,H,P) decay < 0
    return r, k, v, g, logw


def _rwkv_out(p, wkv, g, r_dtype):
    yf = wkv.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = yf * jax.lax.rsqrt(ms + 1e-5) * p["ln_scale"]
    y = y * jax.nn.silu(g.astype(jnp.float32))
    return jnp.einsum("bshp,hpd->bsd", y.astype(r_dtype), p["wo"])


def rwkv6_tmix(cfg: ArchConfig, p, x: jax.Array,
               state_in: jax.Array | None = None, *, state_out: bool = False):
    """Chunked WKV6.  x: (B,S,D).  state: (B,H,P,P) [k-dim x v-dim]."""
    B, S, D = x.shape
    H, Pd = cfg.n_heads, cfg.head_dim
    c = min(cfg.ssm.chunk if cfg.ssm else 32, S)
    assert S % c == 0
    nc = S // c

    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_project(p, x, x_prev)

    rc = jnp.moveaxis(r.reshape(B, nc, c, H, Pd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nc, c, H, Pd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, c, H, Pd), 1, 0)
    wc = jnp.moveaxis(logw.reshape(B, nc, c, H, Pd), 1, 0)

    if state_in is None:
        state_in = jnp.zeros((B, H, Pd, Pd), jnp.float32)

    def chunk_step(S0, xs_):
        rk, kk, vk, lw = xs_
        rk = rk.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vk = vk.astype(jnp.float32)
        cum = jnp.cumsum(lw, axis=1)                  # (B,c,H,P) inclusive
        cum_excl = cum - lw
        # A[t,j] = sum_p r[t,p] k[j,p] exp(cum_excl[t,p] - cum[j,p]), j < t
        dec = jnp.exp(jnp.clip(cum_excl[:, :, None] - cum[:, None], max=0.0))
        A = jnp.einsum("bthp,bjhp,btjhp->bhtj", rk, kk, dec)
        A = A * jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)[None, None]
        # bonus term: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bthp,hp,bthp->bth", rk, p["u"], kk)
        y = jnp.einsum("bhtj,bjhp->bthp", A, vk)
        y = y + bonus[..., None] * vk
        # inter-chunk: r_t decayed to chunk start reads S0
        y = y + jnp.einsum("bthp,bhpq->bthq", rk * jnp.exp(cum_excl), S0)
        # state: S1 = diag(exp(cum_last)) S0 + sum_j exp(cum_last - cum_j) k_j v_j
        last = cum[:, -1]                             # (B,H,P)
        S1 = jnp.exp(last)[..., None] * S0 + jnp.einsum(
            "bjhp,bjhp,bjhq->bhpq", jnp.exp(jnp.clip(
                last[:, None] - cum, max=0.0)), kk, vk)
        return S1, y

    state, yc = jax.lax.scan(jax.checkpoint(chunk_step), state_in,
                             (rc, kc, vc, wc))
    wkv = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, Pd)
    out = _rwkv_out(p, wkv, g, x.dtype)
    if state_out:
        return out, state
    return out, None


def rwkv6_tmix_decode(cfg: ArchConfig, p, x: jax.Array, x_prev: jax.Array,
                      state: jax.Array):
    """One-step WKV.  x: (B,1,D); x_prev: (B,1,D); state: (B,H,P,P)."""
    r, k, v, g, logw = _rwkv_project(p, x, x_prev)
    rk = r[:, 0].astype(jnp.float32)
    kk = k[:, 0].astype(jnp.float32)
    vk = v[:, 0].astype(jnp.float32)
    w = jnp.exp(logw[:, 0])                           # (B,H,P)
    kv = jnp.einsum("bhp,bhq->bhpq", kk, vk)
    out_state = state + p["u"][..., None] * kv
    wkv = jnp.einsum("bhp,bhpq->bhq", rk, out_state)[:, None]
    new_state = w[..., None] * state + kv
    out = _rwkv_out(p, wkv, g, x.dtype)
    return out, new_state


def rwkv6_cmix(cfg: ArchConfig, p, x: jax.Array,
               x_prev: jax.Array | None = None):
    """Channel mix with token shift.  x: (B,S,D)."""
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = _lerp(x, x_prev, p["mu_ck"])
    xr = _lerp(x, x_prev, p["mu_cr"])
    h = jnp.square(jax.nn.relu(xk @ p["wk_c"]))
    return jax.nn.sigmoid(xr @ p["wr_c"]) * (h @ p["wv_c"])
