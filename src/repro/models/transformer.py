"""Model assembly: scanned decoder stacks for every assigned family.

Public API (all models):
  model = build_model(cfg)            # repro.models.archs
  params = model.init(key)                        # eager (smoke scale)
  shapes, specs = model.abstract()                # no allocation (dry-run)
  loss, metrics = model.loss(params, batch)       # train forward + CE
  logits, cache = model.prefill(params, batch)    # build decode cache
  logits, cache = model.decode_step(params, tokens, cache)
  cache, cache_specs = model.abstract_cache(B, S) # ShapeDtypeStructs + specs

Layers are stacked (leading L axis) and driven by ``lax.scan`` so the HLO
holds one copy of each distinct block (zamba2 uses a two-level scan:
9 groups x 6 mamba layers + one weight-shared attention block applied as a
scan-constant).  Remat policy is configurable per step builder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import hint
from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    chunked_softmax_xent,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
)
from repro.models.moe import init_moe, moe_ffn

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def specs_of(init_fn, *args):
    """Capture the spec tree of an init function without allocating."""
    box = {}

    def f(key):
        params, specs = init_fn(key, *args)
        box["specs"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["specs"]


def stack_specs(specs, n_axes: int = 1):
    """Prepend scan axes (replicated) to every PartitionSpec leaf."""
    pre = (None,) * n_axes
    return jax.tree.map(lambda s: P(*pre, *s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def stacked_init(init_fn, key, n: int, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args)[0])(keys)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


# --------------------------------------------------------------------------
# dense / MoE / MLA transformer (also audio + vlm backbones)
# --------------------------------------------------------------------------


# int8 KV cache for GQA decode (halves cache HBM; per-(token, kv-head)
# symmetric scales; dryrun variant "kvint8")
KV_CACHE_QUANT = False


class TransformerLM:
    """Families: dense, moe (incl. MLA), audio (embeds in), vlm (patch+text)."""

    def __init__(self, cfg: ArchConfig, remat: str = "full"):
        self.cfg = cfg
        self.remat = remat
        self.n_scanned = cfg.n_layers - (cfg.moe.first_dense if cfg.moe else 0)
        self.n_dense_pre = cfg.moe.first_dense if cfg.moe else 0

    # ------------------------------------------------------------ params
    def _init_attn(self, key):
        if self.cfg.attention == "mla":
            return attn.init_mla(key, self.cfg)
        return attn.init_attention(key, self.cfg)

    def _init_block(self, key, moe_layer: bool):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        a, a_s = self._init_attn(ks[0])
        n1, n1_s = init_norm(cfg, cfg.d_model)
        n2, n2_s = init_norm(cfg, cfg.d_model)
        params = {"ln1": n1, "ln2": n2, "attn": a}
        specs = {"ln1": n1_s, "ln2": n2_s, "attn": a_s}
        if moe_layer:
            m, m_s = init_moe(ks[1], cfg)
            params["moe"], specs["moe"] = m, m_s
        else:
            m, m_s = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff,
                              cfg.param_dtype)
            params["mlp"], specs["mlp"] = m, m_s
        return params, specs

    def _build(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        emb, emb_s = init_embed(ks[0], cfg)
        fln, fln_s = init_norm(cfg, cfg.d_model)
        moe_layer = cfg.moe is not None
        blocks = stacked_init(
            lambda k: self._init_block(k, moe_layer), ks[1], self.n_scanned)
        block_specs = stack_specs(
            specs_of(lambda k: self._init_block(k, moe_layer)))
        params = {"embed": emb, "blocks": blocks, "final_norm": fln}
        specs = {"embed": emb_s, "blocks": block_specs, "final_norm": fln_s}
        if self.n_dense_pre:
            pre = [self._init_block(k, False)
                   for k in jax.random.split(ks[2], self.n_dense_pre)]
            params["pre_blocks"] = [p for p, _ in pre]
            specs["pre_blocks"] = [s for _, s in pre]
        return params, specs

    def init(self, key):
        return self._build(key)[0]

    def abstract(self):
        box = {}

        def f(key):
            params, specs = self._build(key)
            box["specs"] = specs
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["specs"]

    # ------------------------------------------------------------ embed
    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            h = batch["frame_embeds"].astype(cfg.compute_dtype)
        elif cfg.frontend == "vision_stub":
            text = embed_tokens(params["embed"], batch["tokens"],
                                cfg.compute_dtype)
            patches = batch["patch_embeds"].astype(cfg.compute_dtype)
            h = jnp.concatenate([patches, text], axis=1)
        else:
            h = embed_tokens(params["embed"], batch["tokens"],
                             cfg.compute_dtype)
        return hint(h, "dp", "act_seq", None)

    # ------------------------------------------------------------ blocks
    def _block_fwd(self, p, h, positions, kv_out: bool = False,
                   moe_layer: bool | None = None):
        cfg = self.cfg
        moe_layer = (cfg.moe is not None) if moe_layer is None else moe_layer
        a_in = apply_norm(cfg, p["ln1"], h)
        # megatron_sp: re-gather the full sequence ONCE here so the flash
        # scan loops below stay collective-free (no-op otherwise)
        a_in = hint(a_in, "dp", None, None)
        if cfg.attention == "mla":
            a_out, kv = attn.mla_forward(cfg, p["attn"], a_in, positions,
                                         kv_out=kv_out)
        else:
            a_out, kv = attn.gqa_forward(cfg, p["attn"], a_in, positions,
                                         kv_out=kv_out)
        h = hint(h + a_out, "dp", "act_seq", None)
        m_in = apply_norm(cfg, p["ln2"], h)
        if moe_layer and "moe" in p:
            f_out, aux = moe_ffn(cfg, p["moe"], m_in)
        else:
            f_out, aux = apply_mlp(cfg, p["mlp"], m_in), 0.0
        h = hint(h + f_out, "dp", "act_seq", None)
        return h, aux, kv

    def _pre_fwd(self, params, h, positions, kv_out: bool = False):
        """Leading dense layers (deepseek-v2 style), applied exactly once."""
        aux = jnp.zeros((), jnp.float32)
        kvs = []
        for p in params.get("pre_blocks", []):
            h, a, kv = self._block_fwd(p, h, positions, kv_out=kv_out,
                                       moe_layer=False)
            aux = aux + a
            kvs.append(kv)
        return h, aux, kvs

    def _stack_fwd(self, params, h, positions, collect_kv: bool = False):
        body0 = functools.partial(self._block_fwd)

        def body(carry, p):
            h, aux = carry
            h2, aux2, kv = body0(p, h, positions, kv_out=collect_kv)
            return (h2, aux + aux2), kv

        aux = jnp.zeros((), jnp.float32)
        (h, aux), kvs = jax.lax.scan(
            _remat(body, self.remat), (h, aux), params["blocks"])
        return h, aux, kvs

    # ------------------------------------------------------------ train
    def loss(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch)
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, aux0, _ = self._pre_fwd(params, h, positions)
        h, aux, _ = self._stack_fwd(params, h, positions)
        aux = aux + aux0
        h = apply_norm(cfg, params["final_norm"], h)
        loss, metrics = chunked_softmax_xent(
            h, params["embed"]["head"], batch["labels"])
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    # ------------------------------------------------------------ serve
    def abstract_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        L = self.n_scanned + self.n_dense_pre
        dt = cfg.compute_dtype
        bdp = None if batch == 1 else "dp"
        sp = "all" if batch == 1 else "sp"
        if KV_CACHE_QUANT and cfg.attention == "gqa":
            K, hd = cfg.n_kv_heads, cfg.head_dim
            cache = {
                "k": jax.ShapeDtypeStruct((L, batch, max_seq, K, hd),
                                          jnp.int8),
                "v": jax.ShapeDtypeStruct((L, batch, max_seq, K, hd),
                                          jnp.int8),
                "k_scale": jax.ShapeDtypeStruct((L, batch, max_seq, K),
                                                jnp.float32),
                "v_scale": jax.ShapeDtypeStruct((L, batch, max_seq, K),
                                                jnp.float32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
            specs = {"k": P(None, bdp, sp, None, None),
                     "v": P(None, bdp, sp, None, None),
                     "k_scale": P(None, bdp, sp, None),
                     "v_scale": P(None, bdp, sp, None),
                     "pos": P()}
            return cache, specs
        if cfg.attention == "mla":
            m = cfg.mla
            cache = {
                "ckv": jax.ShapeDtypeStruct((L, batch, max_seq,
                                             m.kv_lora_rank), dt),
                "krope": jax.ShapeDtypeStruct((L, batch, max_seq,
                                               m.qk_rope_head_dim), dt),
            }
            specs = {"ckv": P(None, bdp, sp, None),
                     "krope": P(None, bdp, sp, None)}
        else:
            K, hd = cfg.n_kv_heads, cfg.head_dim
            cache = {
                "k": jax.ShapeDtypeStruct((L, batch, max_seq, K, hd), dt),
                "v": jax.ShapeDtypeStruct((L, batch, max_seq, K, hd), dt),
            }
            specs = {"k": P(None, bdp, sp, None, None),
                     "v": P(None, bdp, sp, None, None)}
        cache["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        specs["pos"] = P()
        return cache, specs

    def init_cache(self, batch: int, max_seq: int):
        shapes, _ = self.abstract_cache(batch, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def prefill(self, params, batch):
        """Process a full prompt; returns (last-token logits, cache)."""
        cfg = self.cfg
        h = self._embed(params, batch)
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, _, pre_kvs = self._pre_fwd(params, h, positions, kv_out=True)
        h, aux, kvs = self._stack_fwd(params, h, positions, collect_kv=True)
        if pre_kvs:
            kvs = _concat_pre(pre_kvs, kvs)
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        if cfg.attention == "mla":
            cache = {"ckv": kvs[0], "krope": kvs[1]}
        elif KV_CACHE_QUANT:
            kq, ks = attn.quantize_kv(kvs[0])
            vq, vs = attn.quantize_kv(kvs[1])
            cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            cache = {"k": kvs[0], "v": kvs[1]}
        cache = {k: hint(v, None, "dp" if B > 1 else None,
                         "sp" if B > 1 else "all", *([None] * (v.ndim - 3)))
                 for k, v in cache.items()}
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    def _decode_step_q8(self, params, cache, h, pos):
        cfg = self.cfg
        assert not self.n_dense_pre, "q8 decode: no pre-block GQA archs"

        def body(h, xs):
            p, c1, c2, s1, s2 = xs
            h, new = _decode_step_q8_layer(cfg, p, h, pos,
                                           (c1, c2, s1, s2))
            return h, new

        h, (k, v, ks, vs) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        return logits, {"k": k, "v": v, "k_scale": ks, "v_scale": vs,
                        "pos": pos + 1}

    def decode_step(self, params, tokens, cache):
        """tokens: (B, 1) int32.  Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        h = embed_tokens(params["embed"], tokens, cfg.compute_dtype)

        n_pre = self.n_dense_pre
        quant = KV_CACHE_QUANT and cfg.attention == "gqa"
        if quant:
            return self._decode_step_q8(params, cache, h, pos)
        if cfg.attention == "mla":
            layer_cache = (cache["ckv"], cache["krope"])
        else:
            layer_cache = (cache["k"], cache["v"])

        def layer(h, p, c1, c2):
            a_in = apply_norm(cfg, p["ln1"], h)
            if cfg.attention == "mla":
                a_out, c1, c2 = attn.mla_decode(cfg, p["attn"], a_in, pos,
                                                c1, c2)
            else:
                a_out, c1, c2 = attn.gqa_decode(cfg, p["attn"], a_in, pos,
                                                c1, c2)
            h = h + a_out
            m_in = apply_norm(cfg, p["ln2"], h)
            if "moe" in p:
                f_out, _ = moe_ffn(cfg, p["moe"], m_in)
            else:
                f_out = apply_mlp(cfg, p["mlp"], m_in)
            return h + f_out, c1, c2

        new1, new2 = [], []
        for i, p in enumerate(params.get("pre_blocks", [])):
            h, c1, c2 = layer(h, p, layer_cache[0][i], layer_cache[1][i])
            new1.append(c1)
            new2.append(c2)

        def body(h, xs):
            p, c1, c2 = xs
            h, c1, c2 = layer(h, p, c1, c2)
            return h, (c1, c2)

        h, (s1, s2) = jax.lax.scan(
            body, h, (params["blocks"],
                      layer_cache[0][n_pre:], layer_cache[1][n_pre:]))
        if new1:
            s1 = jnp.concatenate([jnp.stack(new1), s1])
            s2 = jnp.concatenate([jnp.stack(new2), s2])
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        if cfg.attention == "mla":
            new_cache = {"ckv": s1, "krope": s2}
        else:
            new_cache = {"k": s1, "v": s2}
        new_cache["pos"] = pos + 1
        return logits, new_cache


def _decode_step_q8_layer(cfg, p, h, pos, caches):
    c1, c2, s1, s2 = caches
    a_in = apply_norm(cfg, p["ln1"], h)
    a_out, c1, c2, s1, s2 = attn.gqa_decode_q8(cfg, p["attn"], a_in, pos,
                                               c1, c2, s1, s2)
    h = h + a_out
    m_in = apply_norm(cfg, p["ln2"], h)
    if "moe" in p:
        f_out, _ = moe_ffn(cfg, p["moe"], m_in)
    else:
        f_out = apply_mlp(cfg, p["mlp"], m_in)
    return h + f_out, (c1, c2, s1, s2)


def _concat_pre(pre_kvs, kvs):
    """Stack per-pre-layer kv tuples and concatenate before the scanned kvs."""
    a = jnp.concatenate([jnp.stack([kv[0] for kv in pre_kvs]), kvs[0]])
    b = jnp.concatenate([jnp.stack([kv[1] for kv in pre_kvs]), kvs[1]])
    return (a, b)
