"""Shared neural-net building blocks (pure-functional, pytree params).

Every ``init_*`` returns ``(params, specs)`` — a params pytree and a
structurally identical pytree of ``PartitionSpec`` leaves.  Logical axis
names used in specs: "fsdp" (ZeRO-3 storage sharding over the data axes),
"tp" (tensor parallel over the model axis); they are resolved against the
active mesh by ``repro.distributed.sharding``.

Head-carrying weights are kept in (D, H, head_dim) form and consumed with
einsum so no sharded-dim reshapes are ever needed (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def _dense_init(key, d_in: int, shape, dtype) -> jax.Array:
    scale = d_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in: int, d_out, dtype, *, fan_in_dims: int = 1):
    """Weight of shape (d_in, *d_out) — no bias (llama-style)."""
    if isinstance(d_out, int):
        d_out = (d_out,)
    return _dense_init(key, d_in, (d_in, *d_out), dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm":
        params = {"scale": jnp.ones((d,), jnp.float32),
                  "bias": jnp.zeros((d,), jnp.float32)}
        specs = {"scale": P(None), "bias": P(None)}
    else:
        params = {"scale": jnp.ones((d,), jnp.float32)}
        specs = {"scale": P(None)}
    return params, specs


def apply_norm(cfg: ArchConfig, p, x: jax.Array, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads: (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    if cfg.act == "silu_gated":
        params = {
            "w1": init_linear(ks[0], d, d_ff, dtype),
            "w3": init_linear(ks[1], d, d_ff, dtype),
            "w2": init_linear(ks[2], d_ff, d, dtype),
        }
        specs = {"w1": P("fsdp", "tp"), "w3": P("fsdp", "tp"),
                 "w2": P("tp", "fsdp")}
    else:
        params = {
            "w1": init_linear(ks[0], d, d_ff, dtype),
            "w2": init_linear(ks[2], d_ff, d, dtype),
        }
        specs = {"w1": P("fsdp", "tp"), "w2": P("tp", "fsdp")}
    return params, specs


def apply_act(cfg: ArchConfig, h: jax.Array, gate: jax.Array | None):
    if cfg.act == "silu_gated":
        return jax.nn.silu(gate) * h
    if cfg.act == "gelu":
        return jax.nn.gelu(h)
    if cfg.act == "relu_sq":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(cfg.act)


def apply_mlp(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    h = x @ p["w1"]
    gate = x @ p["w3"] if "w3" in p else None
    h = apply_act(cfg, h, gate)
    return h @ p["w2"]


# --------------------------------------------------------------------------
# embeddings / logits / loss
# --------------------------------------------------------------------------


def init_embed(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    params = {
        "tok": _dense_init(k1, cfg.d_model, (cfg.vocab_size, cfg.d_model),
                           cfg.param_dtype),
        "head": init_linear(k2, cfg.d_model, cfg.vocab_size, cfg.param_dtype),
    }
    specs = {"tok": P("fsdp", None), "head": P("fsdp", "tp")}
    return params, specs


def embed_tokens(p, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


XENT_MM = "mixed"  # "mixed" | "cast" (dryrun baseline comparison)


def chunked_softmax_xent(
    hidden: jax.Array,        # (B, S, D) final hidden states
    head: jax.Array,          # (D, V) output projection
    labels: jax.Array,        # (B, S) int32; -1 = masked position
    *,
    chunk: int = 1024,
    z_loss: float = 1e-4,
):
    """Cross entropy with the vocab projection fused into an S-chunked scan.

    Keeps the (B, chunk, V) logits block as the peak — never materializes
    (B, S, V).  Works with V sharded over the model axis: the label pick is
    a one-hot einsum and the logsumexp reduces over the sharded dim, both of
    which GSPMD partitions without gathering logits.
    """
    B, S, D = hidden.shape
    V = head.shape[-1]
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    hs = hidden.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot_loss, tot_z, tot_cnt, tot_correct = carry
        h, lab = xs
        if XENT_MM == "mixed":
            # bf16 operands, f32 accumulate — native on the MXU; avoids
            # materializing an f32 copy of the (D, V) head every chunk
            logits = jax.lax.dot_general(
                h, head, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            logits = (h.astype(jnp.float32) @ head.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)                  # (B, c)
        # label pick via iota-compare masked sum: fuses away — never
        # materializes a (B, c, V) one-hot, and partitions over sharded V
        vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        tgt = jnp.sum(jnp.where(vidx == lab[..., None], logits, 0.0),
                      axis=-1)
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        zterm = jnp.square(lse) * mask
        correct = (jnp.argmax(logits, -1) == lab).astype(jnp.float32) * mask
        return (tot_loss + nll.sum(), tot_z + zterm.sum(),
                tot_cnt + mask.sum(), tot_correct + correct.sum()), None

    init = (jnp.zeros((), jnp.float32),) * 4
    (loss_sum, z_sum, cnt, correct), _ = jax.lax.scan(
        jax.checkpoint(body), init, (hs, ls))
    cnt = jnp.maximum(cnt, 1.0)
    loss = loss_sum / cnt + z_loss * z_sum / cnt
    metrics = {"nll": loss_sum / cnt, "accuracy": correct / cnt,
               "tokens": cnt}
    return loss, metrics
