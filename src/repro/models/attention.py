"""Attention: GQA/MHA/MQA flash-style scan attention, MLA, and decode paths.

Layout conventions (no sharded-dim reshapes, DESIGN.md §5):
  q weights  (D, H, hd)      TP on heads when H % tp == 0 else on head_dim
  kv weights (D, K, hd)      replicated over TP (K is small for GQA/MQA)
  o weights  (H, hd, D)      TP matches q; FSDP on D

Train/prefill attention is a nested lax.scan over (q-block, kv-block) with
online softmax — O(S·block) memory so prefill_32k never materializes an
S×S score tensor.  Decode attends a single query against a KV cache whose
sequence axis is sharded over the model axis ("SP"); softmax over the
sharded axis becomes a GSPMD all-reduce (flash-decode combine).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, init_linear

_NEG = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


HEAD_TP = "padded"  # "padded" | "head_dim" (dryrun variant comparison)


def init_attention(key, cfg: ArchConfig):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    params = {
        "wq": init_linear(ks[0], d, (H, hd), dt),
        "wk": init_linear(ks[1], d, (K, hd), dt),
        "wv": init_linear(ks[2], d, (K, hd), dt),
        "wo": (init_linear(ks[3], H * hd, d, dt)).reshape(H, hd, d),
    }
    if cfg.n_heads % 16 == 0 or HEAD_TP == "padded":
        # TP on heads.  When H % tp != 0 (starcoder2: 36) GSPMD pads the
        # head dim to ceil(H/tp)/rank — 75% attention efficiency, but the
        # flash loops stay collective-free, which beats head_dim TP's
        # psum-per-block by orders of magnitude (EXPERIMENTS §Perf B2).
        specs = {"wq": P("fsdp", "tp", None), "wk": P("fsdp", None, None),
                 "wv": P("fsdp", None, None), "wo": P("tp", None, "fsdp")}
    else:  # head_dim (contraction) TP — kept for the perf comparison
        specs = {"wq": P("fsdp", None, "tp"), "wk": P("fsdp", None, "tp"),
                 "wv": P("fsdp", None, "tp"), "wo": P(None, "tp", "fsdp")}
    return params, specs


def init_mla(key, cfg: ArchConfig):
    assert cfg.mla is not None
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    params = {
        "wq": init_linear(ks[0], d, (H, qk_head), dt),
        "wdkv": init_linear(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "wuk": init_linear(ks[2], m.kv_lora_rank, (H, m.qk_nope_head_dim), dt),
        "wuv": init_linear(ks[3], m.kv_lora_rank, (H, m.v_head_dim), dt),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dt).reshape(
            H, m.v_head_dim, d),
    }
    specs = {
        "wq": P("fsdp", "tp", None),
        "wdkv": P("fsdp", None),
        "wuk": P(None, "tp", None),
        "wuv": P(None, "tp", None),
        "wo": P("tp", None, "fsdp"),
    }
    return params, specs


# --------------------------------------------------------------------------
# flash attention (train / prefill)
# --------------------------------------------------------------------------
#
# Two implementations, selected by FLASH_IMPL (dryrun variants compare):
#   "scan" — nested lax.scan with online softmax; autodiff of the scans
#            stacks (nq x nk) checkpointed inner carries in the backward:
#            correct but HBM-heavy (the §Perf baseline).
#   "vjp"  — custom_vjp with the REAL FlashAttention backward: forward
#            saves only (q, k, v, out, LSE); backward replays the block
#            loops computing p = exp(s - L) directly and accumulates
#            dq/dk/dv — O(S) residuals, one extra attention pass.

FLASH_IMPL = "vjp"


def _mask_scores(s, causal, qp, kp):
    if not causal:
        return s, jnp.ones((qp.shape[0], kp.shape[0]), jnp.float32)
    mask = (qp[:, None] >= kp[None, :]).astype(jnp.float32)
    return s * mask + _NEG * (1.0 - mask), mask


def _flash_fwd_scan(q, k, v, causal, q_offset, block_q, block_k,
                    *, checkpoint_inner: bool, need_lse: bool):
    B, Sq, H, hd = q.shape
    _, Sk, K, hdv = v.shape
    G = H // K
    scale = hd ** -0.5

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, K, hdv), 1, 0)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, bq)
    k_pos = jnp.arange(Sk).reshape(nk, bk)

    def outer(_, qxs):
        q_i, qp = qxs  # (B, bq, H, hd), (bq,)

        def inner(carry, kxs):
            m, l, acc = carry
            k_j, v_j, kp = kxs
            k_rep = jnp.repeat(k_j, G, axis=2)      # (B, bk, H, hd)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_rep,
                           preferred_element_type=jnp.float32) * scale
            s, mask = _mask_scores(s, causal, qp, kp)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask  # zero masked rows
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            v_rep = jnp.repeat(v_j, G, axis=2)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_rep.dtype), v_rep,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, bq), _NEG, jnp.float32),
            jnp.zeros((B, H, bq), jnp.float32),
            jnp.zeros((B, H, bq, hdv), jnp.float32),
        )
        body = jax.checkpoint(inner) if checkpoint_inner else inner
        (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, k_pos))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-20))    # (B, H, bq)
        return None, (jnp.moveaxis(out, 1, 2), lse)

    _, (ob, lseb) = jax.lax.scan(outer, None, (qb, q_pos))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, Sq, H, hdv).astype(q.dtype)
    if not need_lse:
        return out, None
    # lseb: (nq, B, H, bq) -> (B, H, Sq)
    lse = jnp.moveaxis(lseb, 0, 2).reshape(B, H, Sq)
    return out, lse


def _flash_core(q, k, v, causal, q_offset, block_q, block_k):
    out, _ = _flash_fwd_scan(q, k, v, causal, q_offset, block_q, block_k,
                             checkpoint_inner=False, need_lse=False)
    return out


def _flash_core_fwd(q, k, v, causal, q_offset, block_q, block_k):
    out, lse = _flash_fwd_scan(q, k, v, causal, q_offset, block_q,
                               block_k, checkpoint_inner=False,
                               need_lse=True)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, q_offset, block_q, block_k, res, dout):
    """FlashAttention backward: per (q, kv) block pair recompute
    p = exp(s - LSE) and accumulate dq (per-q-block output), dk/dv
    (stacked carry with indexed adds) — no O(nq*nk) residuals."""
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Sk, K, hdv = v.shape
    G = H // K
    scale = hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, hd), 1, 0)
    dob = jnp.moveaxis(dout.reshape(B, nq, bq, H, hdv), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, K, hdv), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, H, nq, bq), 2, 0)   # (nq, B, H, bq)
    # D_i = rowsum(dout * out) (f32) — the softmax-grad diagonal term
    D = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                   out.astype(jnp.float32))
    Db = jnp.moveaxis(D.reshape(B, nq, bq, H), 1, 0)       # (nq, B, bq, H)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, bq)
    k_pos = jnp.arange(Sk).reshape(nk, bk)

    def outer(carry, qxs):
        dk_acc, dv_acc = carry        # (nk, B, bk, K, hd/v) f32
        q_i, do_i, L_i, D_i, qp = qxs

        def inner(c2, kxs):
            dq_i, dk_acc, dv_acc = c2
            k_j, v_j, kp, j = kxs
            k_rep = jnp.repeat(k_j, G, axis=2)
            v_rep = jnp.repeat(v_j, G, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_rep,
                           preferred_element_type=jnp.float32) * scale
            s, mask = _mask_scores(s, causal, qp, kp)
            p = jnp.exp(s - L_i[..., None]) * mask         # (B, H, bq, bk)
            dp = jnp.einsum("bqhd,bkhd->bhqk",
                            do_i.astype(jnp.float32),
                            v_rep.astype(jnp.float32))
            ds = p * (dp - jnp.swapaxes(D_i, 1, 2)[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     k_rep.astype(jnp.float32))
            dk_j = jnp.einsum("bhqk,bqhd->bkhd", ds,
                              q_i.astype(jnp.float32))
            dv_j = jnp.einsum("bhqk,bqhd->bkhd", p,
                              do_i.astype(jnp.float32))
            # fold grouped q heads back onto their kv head
            dk_j = dk_j.reshape(B, bk, K, G, hd).sum(axis=3)
            dv_j = dv_j.reshape(B, bk, K, G, hdv).sum(axis=3)
            dk_acc = dk_acc.at[j].add(dk_j)
            dv_acc = dv_acc.at[j].add(dv_j)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, bq, H, hd), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            inner, (dq0, dk_acc, dv_acc),
            (kb, vb, k_pos, jnp.arange(nk)))
        return (dk_acc, dv_acc), dq_i

    init = (jnp.zeros((nk, B, bk, K, hd), jnp.float32),
            jnp.zeros((nk, B, bk, K, hdv), jnp.float32))
    (dk_acc, dv_acc), dqb = jax.lax.scan(
        outer, init, (qb, dob, lseb, Db, q_pos))
    dq = jnp.moveaxis(dqb, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk_acc, 0, 1).reshape(B, Sk, K, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv_acc, 0, 1).reshape(B, Sk, K, hdv).astype(v.dtype)
    return dq, dk, dv


_flash_vjp = jax.custom_vjp(_flash_core, nondiff_argnums=(3, 4, 5, 6))
_flash_vjp.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: jax.Array,             # (B, Sq, H, hd)
    k: jax.Array,             # (B, Sk, K, hd)
    v: jax.Array,             # (B, Sk, K, hdv)
    *,
    causal: bool = True,
    q_offset: int = 0,        # absolute position of q[0] (prefill cont.)
    block_q: int = 512,
    block_k: int = 512,
    impl: str | None = None,
) -> jax.Array:
    impl = impl or FLASH_IMPL
    if impl == "vjp":
        return _flash_vjp(q, k, v, causal, q_offset, block_q, block_k)
    out, _ = _flash_fwd_scan(q, k, v, causal, q_offset, block_q, block_k,
                             checkpoint_inner=True, need_lse=False)
    return out


# --------------------------------------------------------------------------
# GQA layer application
# --------------------------------------------------------------------------


def gqa_forward(cfg: ArchConfig, p, x: jax.Array, positions: jax.Array,
                *, q_offset: int = 0, kv_out: bool = False):
    """Train/prefill attention.  Returns (out, (k, v)) — k/v for the cache."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, q_offset=q_offset)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, ((k, v) if kv_out else None)


def gqa_decode(cfg: ArchConfig, p, x: jax.Array, pos: jax.Array,
               k_cache: jax.Array, v_cache: jax.Array):
    """Single-token decode against an S-sharded cache.

    x: (B, 1, D); pos: scalar int32 — the position being written.
    cache: (B, S_max, K, hd).  Returns (out, k_cache, v_cache).
    """
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    B, S = k_cache.shape[0], k_cache.shape[1]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)

    qg = q.reshape(B, K, G, hd)  # q is TP-replicated at decode; reshape is free
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = (jnp.arange(S) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, _NEG)
    w = jax.nn.softmax(s, axis=-1)  # sharded-S reduce -> flash-decode combine
    o = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache)
    out = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd), p["wo"])[:, None, :]
    return out.astype(x.dtype), k_cache, v_cache


def quantize_kv(x: jax.Array):
    """Per-(token, kv-head) symmetric int8 over head_dim.
    x: (..., hd) -> (int8 values, f32 scale without the hd dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127)
    return q.astype(jnp.int8), scale


def gqa_decode_q8(cfg: ArchConfig, p, x: jax.Array, pos: jax.Array,
                  k_cache, v_cache, k_scale, v_scale):
    """gqa_decode against an int8-quantized cache (KV bytes halve; the
    dequant is fused into the attention reads on TPU).  caches:
    (B, S, K, hd) int8 + (B, S, K) f32 scales."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    B, S = k_cache.shape[0], k_cache.shape[1]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, pos, axis=1)
    k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, pos, axis=1)
    v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, pos, axis=1)

    qg = q.reshape(B, K, G, hd)
    # dequant folded into the contraction: s = (q . k_int8) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (hd ** -0.5)
    s = s * jnp.swapaxes(k_scale, 1, 2)[:, :, None, :]
    valid = (jnp.arange(S) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    wv = w * jnp.swapaxes(v_scale, 1, 2)[:, :, None, :]
    o = jnp.einsum("bkgs,bskd->bkgd", wv, v_cache.astype(jnp.float32))
    out = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd).astype(x.dtype),
                     p["wo"])[:, None, :]
    return out.astype(x.dtype), k_cache, v_cache, k_scale, v_scale


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed-latent KV cache
# --------------------------------------------------------------------------


def _mla_project(cfg: ArchConfig, p, x, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    dkv = x @ p["wdkv"]                              # (B, S, r + rope)
    c_kv = dkv[..., : m.kv_lora_rank]
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)              # (B, S, 1, rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(cfg: ArchConfig, p, x: jax.Array, positions: jax.Array,
                *, kv_out: bool = False):
    """Train/prefill MLA: up-project the latent and run flash with K == H."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _mla_project(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"])
    H = cfg.n_heads
    k_rope_rep = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, m.qk_rope_head_dim))
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate([k_nope, k_rope_rep], axis=-1)
    o = flash_attention(q_cat, k_cat, v, causal=True)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, ((c_kv, k_rope[:, :, 0, :]) if kv_out else None)


def mla_decode(cfg: ArchConfig, p, x: jax.Array, pos: jax.Array,
               ckv_cache: jax.Array, krope_cache: jax.Array):
    """Absorbed-weight MLA decode: scores/values computed in latent space so
    the cache stays (B, S, r) + (B, S, rope) — MLA's compression benefit."""
    m = cfg.mla
    B = x.shape[0]
    S = ckv_cache.shape[1]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_project(cfg, p, x, posv)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv.astype(ckv_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope[:, :, 0, :].astype(krope_cache.dtype), pos, axis=1)

    # absorb W_uk into q:  (B,1,H,dn)·(r,H,dn) -> (B,H,r)
    q_lat = jnp.einsum("bshk,rhk->bhr", q_nope, p["wuk"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhp,bsp->bhs", q_rope[:, 0], krope_cache,
                      preferred_element_type=jnp.float32)) * scale
    valid = (jnp.arange(S) <= pos)[None, None, :]
    s = jnp.where(valid, s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["wuv"])
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return out.astype(x.dtype), ckv_cache, krope_cache
