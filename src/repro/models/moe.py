"""Mixture-of-Experts FFN with shard-local sort-based dispatch.

Tokens are routed *locally on each shard* — every shard sorts its own
tokens by expert, packs them into capacity-bounded per-expert segments
with pure gathers (no O(T^2) one-hot dispatch einsum), and runs batched
expert matmuls.  Three sharded paths, selected by the active strategy
(DESIGN.md §5 / distributed.sharding):

  token path (fsdp / fsdp_dp / tp_sp) — tokens arrive pre-sharded over
      the token axes; expert weights are ZeRO-gathered inside the
      shard_map; if TP is on, expert-F partials psum once at the end.
  megatron path (megatron_sp) — the residual stream is sequence-sharded
      over 'model': the body all-gathers the sequence once, routes the
      full local batch identically on every model rank, computes with
      the F-shard, and returns via psum_scatter — one AG + one RS of the
      activations per MoE layer, collective-free inside.

Without an active mesh (CPU smoke tests) the same body runs unsharded.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import _dense_init, init_linear
from repro.distributed import sharding as shd


def init_moe(key, cfg: ArchConfig):
    assert cfg.moe is not None
    m, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    E, F = m.n_routed, m.d_ff_expert
    params: dict[str, Any] = {
        "router": _dense_init(ks[0], d, (d, E), jnp.float32),
        "w1": _dense_init(ks[1], d, (E, d, F), dt),
        "w3": _dense_init(ks[2], d, (E, d, F), dt),
        "w2": _dense_init(ks[3], F, (E, F, d), dt),
    }
    specs = {
        "router": P(None, None),
        "w1": P(None, "fsdp_expert", "tp"),
        "w3": P(None, "fsdp_expert", "tp"),
        "w2": P(None, "tp", "fsdp_expert"),
    }
    if m.n_shared:
        Fs = m.n_shared * F  # fused shared experts (mathematically identical)
        params.update({
            "sw1": init_linear(ks[4], d, Fs, dt),
            "sw3": init_linear(ks[5], d, Fs, dt),
            "sw2": init_linear(ks[6], Fs, d, dt),
        })
        specs.update({"sw1": P("fsdp_expert", "tp"),
                      "sw3": P("fsdp_expert", "tp"),
                      "sw2": P("tp", "fsdp_expert")})
    return params, specs


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.n_routed * m.capacity_factor)
    c = max(8, min(n_tokens, (c + 7) // 8 * 8))
    return c


def _gather_weights(fsdp_axes, tp_axis, router, w1, w3, w2, shared):
    """ZeRO-3: reassemble the expert weights' storage shards (the TP dim,
    if any, stays sharded — it is contracted with a psum)."""
    if fsdp_axes:
        w1 = jax.lax.all_gather(w1, fsdp_axes, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp_axes, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axes, axis=2, tiled=True)
        if shared:
            sw1, sw3, sw2 = shared
            sw1 = jax.lax.all_gather(sw1, fsdp_axes, axis=0, tiled=True)
            sw3 = jax.lax.all_gather(sw3, fsdp_axes, axis=0, tiled=True)
            sw2 = jax.lax.all_gather(sw2, fsdp_axes, axis=1, tiled=True)
            shared = (sw1, sw3, sw2)
    return router, w1, w3, w2, shared


def _moe_math(cfg: ArchConfig, x, router, w1, w3, w2, shared,
              reduce_axes):
    """Shard-local routing + expert compute.  x: (T, D).  Returns the
    (possibly TP-partial) output and psum-averaged aux losses."""
    m = cfg.moe
    T, D = x.shape
    E = m.n_routed
    C = _capacity(T, cfg)

    # ---- routing (fp32) ----
    logits = x.astype(jnp.float32) @ router          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)       # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_routed = counts / (T * m.top_k)
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac_routed * mean_prob) * m.aux_loss_coef
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) \
        * m.router_z_coef
    if reduce_axes:
        n = jax.lax.psum(1.0, reduce_axes)
        aux = jax.lax.psum(aux, reduce_axes) / n
        zloss = jax.lax.psum(zloss, reduce_axes) / n

    # ---- sort-based dispatch ----
    e_flat = idx.reshape(-1)                          # (T*k,)
    tok_of_slot = jnp.arange(T * m.top_k) // m.top_k
    order = jnp.argsort(e_flat)                       # stable groups by expert
    sorted_e = e_flat[order]
    sorted_tok = tok_of_slot[order]
    sorted_gate = gates.reshape(-1)[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(T * m.top_k) - first
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # OOB -> dropped

    buf = jnp.zeros((E * C, D), x.dtype).at[slot].add(
        x[sorted_tok], mode="drop").reshape(E, C, D)

    # ---- expert compute (TP on F when sharded; partial over tp) ----
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    g = jnp.einsum("ecd,edf->ecf", buf, w3)
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2).reshape(E * C, D)

    # ---- combine: weighted scatter-add back to token order ----
    padded = jnp.concatenate([out_buf, jnp.zeros((1, D), out_buf.dtype)])
    vals = padded[jnp.where(keep, slot, E * C)]
    vals = vals * (sorted_gate * keep).astype(vals.dtype)[:, None]
    out = jnp.zeros((T, D), vals.dtype).at[sorted_tok].add(vals)

    # ---- shared experts (dense path, fused) ----
    if shared:
        sw1, sw3, sw2 = shared
        hs = jax.nn.silu(x @ sw3) * (x @ sw1)
        out = out + hs @ sw2
    return out, aux, zloss


def _token_body(cfg, fsdp_axes, tp_axis, x, router, w1, w3, w2, *shared):
    """Per-shard MoE over pre-sharded tokens.  x: (T_local, D)."""
    router, w1, w3, w2, shared = _gather_weights(
        fsdp_axes, tp_axis, router, w1, w3, w2, shared)
    out, aux, zloss = _moe_math(cfg, x, router, w1, w3, w2, shared,
                                reduce_axes=fsdp_axes)
    if tp_axis:  # combine TP partials once, for routed + shared together
        out = jax.lax.psum(out, tp_axis)
    return out, aux, zloss


def _megatron_body(cfg, fsdp_axes, tp_axis, x, router, w1, w3, w2,
                   *shared):
    """Sequence-sharded residual stream: AG once, RS once.
    x: (B_local, S_local, D) with S sharded over tp_axis."""
    B, S_loc, D = x.shape
    x_full = jax.lax.all_gather(x, tp_axis, axis=1, tiled=True)
    T = B * x_full.shape[1]
    router, w1, w3, w2, shared = _gather_weights(
        fsdp_axes, tp_axis, router, w1, w3, w2, shared)
    out2, aux, zloss = _moe_math(cfg, x_full.reshape(T, D), router,
                                 w1, w3, w2, shared,
                                 reduce_axes=fsdp_axes)
    out3 = out2.reshape(B, x_full.shape[1], D)
    out = jax.lax.psum_scatter(out3, tp_axis, scatter_dimension=1,
                               tiled=True)
    return out, aux, zloss


def moe_ffn(cfg: ArchConfig, p, x: jax.Array):
    """x: (B, S, D) -> (out, aux_loss).  Dispatch is shard-local."""
    B, S, D = x.shape
    shared = tuple(p[k] for k in ("sw1", "sw3", "sw2") if k in p)
    rules = shd.active_rules()
    if rules is None:
        out, aux, zloss = _moe_math(
            cfg, x.reshape(B * S, D), p["router"], p["w1"], p["w3"],
            p["w2"], shared if shared else None, reduce_axes=None)
        return out.reshape(B, S, D).astype(x.dtype), aux + zloss

    t = rules.table
    fsdp_e = t["fsdp_expert"]
    tp = t["tp"]
    w_specs = [P(None, None),
               P(None, fsdp_e, tp), P(None, fsdp_e, tp),
               P(None, tp, fsdp_e)]
    if shared:
        w_specs += [P(fsdp_e, tp), P(fsdp_e, tp), P(tp, fsdp_e)]

    if rules.strategy == "megatron_sp":
        dp = t["dp"]
        body = functools.partial(_megatron_body, cfg, fsdp_e, tp)
        out, aux, zloss = shard_map(
            body, mesh=rules.mesh,
            in_specs=tuple([P(dp, tp, None)] + w_specs),
            out_specs=(P(dp, tp, None), P(), P()),
            check_rep=False,
        )(x, p["router"], p["w1"], p["w3"], p["w2"], *shared)
        return out.astype(x.dtype), aux + zloss

    tok = rules.token_axes
    tok_spec = tok if len(tok) > 1 else tok[0]
    body = functools.partial(_token_body, cfg, fsdp_e, tp)
    out, aux, zloss = shard_map(
        body, mesh=rules.mesh,
        in_specs=tuple([P(tok_spec, None)] + w_specs),
        out_specs=(P(tok_spec, None), P(), P()),
        check_rep=False,
    )(x.reshape(B * S, D), p["router"], p["w1"], p["w3"], p["w2"],
      *shared)
    return out.reshape(B, S, D).astype(x.dtype), aux + zloss
