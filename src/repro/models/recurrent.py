"""Recurrent-family models: RWKV6 (attention-free) and Zamba2 (hybrid).

Both are O(S) in sequence length and therefore run the ``long_500k`` shape.
Zamba2: 9 groups of 6 Mamba2 layers, each group followed by ONE
weight-shared attention+MLP block (the shared weights are scan constants,
so the HLO contains a single copy).  RWKV6: stacked time-mix/channel-mix
blocks with exact one-step decode recurrence.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import hint
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    chunked_softmax_xent,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
)
from repro.models.transformer import _remat, specs_of, stack_specs, stacked_init

# ==========================================================================
# RWKV6
# ==========================================================================


class RWKVModel:
    def __init__(self, cfg: ArchConfig, remat: str = "full"):
        self.cfg = cfg
        self.remat = remat

    def _init_block(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        core, core_s = ssm.init_rwkv6(ks[0], cfg)
        n1, n1_s = init_norm(cfg, cfg.d_model)
        n2, n2_s = init_norm(cfg, cfg.d_model)
        params = {"ln1": n1, "ln2": n2, **core}
        specs = {"ln1": n1_s, "ln2": n2_s, **core_s}
        return params, specs

    def _build(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        emb, emb_s = init_embed(ks[0], cfg)
        ln_in, ln_in_s = init_norm(cfg, cfg.d_model)
        fln, fln_s = init_norm(cfg, cfg.d_model)
        blocks = stacked_init(self._init_block, ks[1], cfg.n_layers)
        params = {"embed": emb, "ln_in": ln_in, "blocks": blocks,
                  "final_norm": fln}
        specs = {"embed": emb_s, "ln_in": ln_in_s,
                 "blocks": stack_specs(specs_of(self._init_block)),
                 "final_norm": fln_s}
        return params, specs

    def init(self, key):
        return self._build(key)[0]

    def abstract(self):
        box = {}

        def f(key):
            params, specs = self._build(key)
            box["specs"] = specs
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["specs"]

    # ------------------------------------------------------------ forward
    def _stack_fwd(self, params, h, *, collect_state: bool = False):
        cfg = self.cfg

        def body(h, p):
            a_in = apply_norm(cfg, p["ln1"], h)
            t_out, wkv_state = ssm.rwkv6_tmix(cfg, p["tmix"], a_in,
                                              state_out=collect_state)
            h = hint(h + t_out, "dp", "act_seq", None)
            m_in = apply_norm(cfg, p["ln2"], h)
            c_out = ssm.rwkv6_cmix(cfg, p["cmix"], m_in)
            h = hint(h + c_out, "dp", "act_seq", None)
            ys = (wkv_state, a_in[:, -1], m_in[:, -1]) if collect_state \
                else None
            return h, ys

        return jax.lax.scan(_remat(body, self.remat), h, params["blocks"])

    def loss(self, params, batch):
        cfg = self.cfg
        h = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
        h = apply_norm(cfg, params["ln_in"], h)
        h, _ = self._stack_fwd(params, h)
        h = apply_norm(cfg, params["final_norm"], h)
        loss, metrics = chunked_softmax_xent(
            h, params["embed"]["head"], batch["labels"])
        metrics["aux_loss"] = jnp.zeros((), jnp.float32)
        return loss, metrics

    # ------------------------------------------------------------ serve
    def abstract_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        L, H, Pd, D = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.d_model
        bdp = None if batch == 1 else "dp"
        cache = {
            "wkv": jax.ShapeDtypeStruct((L, batch, H, Pd, Pd), jnp.float32),
            "tprev": jax.ShapeDtypeStruct((L, batch, D), cfg.compute_dtype),
            "cprev": jax.ShapeDtypeStruct((L, batch, D), cfg.compute_dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = {"wkv": P(None, bdp, None, None, "tp"),
                 "tprev": P(None, bdp, None), "cprev": P(None, bdp, None),
                 "pos": P()}
        return cache, specs

    def init_cache(self, batch: int, max_seq: int):
        shapes, _ = self.abstract_cache(batch, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def prefill(self, params, batch):
        cfg = self.cfg
        h = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
        h = apply_norm(cfg, params["ln_in"], h)
        h, (wkv, tprev, cprev) = self._stack_fwd(params, h,
                                                 collect_state=True)
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        cache = {"wkv": wkv, "tprev": tprev, "cprev": cprev,
                 "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
        return logits, cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens, cfg.compute_dtype)
        h = apply_norm(cfg, params["ln_in"], h)

        def body(h, xs):
            p, wkv, tprev, cprev = xs
            a_in = apply_norm(cfg, p["ln1"], h)
            t_out, wkv = ssm.rwkv6_tmix_decode(
                cfg, p["tmix"], a_in, tprev[:, None].astype(a_in.dtype), wkv)
            h = h + t_out
            m_in = apply_norm(cfg, p["ln2"], h)
            c_out = ssm.rwkv6_cmix(cfg, p["cmix"], m_in,
                                   cprev[:, None].astype(m_in.dtype))
            h = h + c_out
            return h, (wkv, a_in[:, 0], m_in[:, 0])

        h, (wkv, tprev, cprev) = jax.lax.scan(
            body, h, (params["blocks"], cache["wkv"], cache["tprev"],
                      cache["cprev"]))
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        return logits, {"wkv": wkv, "tprev": tprev, "cprev": cprev,
                        "pos": cache["pos"] + 1}


# ==========================================================================
# Zamba2 hybrid
# ==========================================================================


class ZambaModel:
    def __init__(self, cfg: ArchConfig, remat: str = "full"):
        assert cfg.ssm is not None and cfg.ssm.attn_every
        self.cfg = cfg
        self.remat = remat
        self.n_inner = cfg.ssm.attn_every                      # 6
        self.n_groups = cfg.n_layers // self.n_inner           # 9

    def _init_mamba_layer(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        core, core_s = ssm.init_mamba2(ks[0], cfg)
        n, n_s = init_norm(cfg, cfg.d_model)
        return {"ln": n, "mamba": core}, {"ln": n_s, "mamba": core_s}

    def _init_shared(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        a, a_s = attn.init_attention(ks[0], cfg)
        m, m_s = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff, cfg.param_dtype)
        n1, n1_s = init_norm(cfg, cfg.d_model)
        n2, n2_s = init_norm(cfg, cfg.d_model)
        return ({"ln1": n1, "attn": a, "ln2": n2, "mlp": m},
                {"ln1": n1_s, "attn": a_s, "ln2": n2_s, "mlp": m_s})

    def _build(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        emb, emb_s = init_embed(ks[0], cfg)
        fln, fln_s = init_norm(cfg, cfg.d_model)
        G, K = self.n_groups, self.n_inner
        keys = jax.random.split(ks[1], G * K).reshape(G, K, -1)
        mamba = jax.vmap(jax.vmap(
            lambda k: self._init_mamba_layer(k)[0]))(keys)
        shared, shared_s = self._init_shared(ks[2])
        params = {"embed": emb, "mamba": mamba, "shared": shared,
                  "final_norm": fln}
        specs = {"embed": emb_s,
                 "mamba": stack_specs(specs_of(self._init_mamba_layer), 2),
                 "shared": shared_s, "final_norm": fln_s}
        return params, specs

    def init(self, key):
        return self._build(key)[0]

    def abstract(self):
        box = {}

        def f(key):
            params, specs = self._build(key)
            box["specs"] = specs
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["specs"]

    # ------------------------------------------------------------ forward
    def _shared_fwd(self, shared, h, positions, kv_out=False):
        cfg = self.cfg
        a_in = apply_norm(cfg, shared["ln1"], h)
        a_in = hint(a_in, "dp", None, None)  # full seq for attention
        a_out, kv = attn.gqa_forward(cfg, shared["attn"], a_in, positions,
                                     kv_out=kv_out)
        h = hint(h + a_out, "dp", "act_seq", None)
        m_in = apply_norm(cfg, shared["ln2"], h)
        h = hint(h + apply_mlp(cfg, shared["mlp"], m_in), "dp", "act_seq", None)
        return h, kv

    def _stack_fwd(self, params, h, positions, collect: bool = False):
        cfg = self.cfg
        shared = params["shared"]

        def inner(h, p):
            a_in = apply_norm(cfg, p["ln"], h)
            out, state = ssm.mamba2_forward(cfg, p["mamba"], a_in,
                                            state_out=collect)
            return hint(h + out, "dp", "act_seq", None), state

        def group(h, gp):
            h, states = jax.lax.scan(inner, h, gp)
            h, kv = self._shared_fwd(shared, h, positions, kv_out=collect)
            return h, (states, kv) if collect else None

        return jax.lax.scan(_remat(group, self.remat), h, params["mamba"])

    def loss(self, params, batch):
        cfg = self.cfg
        h = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
        h = hint(h, "dp", "act_seq", None)
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, _ = self._stack_fwd(params, h, positions)
        h = apply_norm(cfg, params["final_norm"], h)
        loss, metrics = chunked_softmax_xent(
            h, params["embed"]["head"], batch["labels"])
        metrics["aux_loss"] = jnp.zeros((), jnp.float32)
        return loss, metrics

    # ------------------------------------------------------------ serve
    def abstract_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        s = cfg.ssm
        G, Kn = self.n_groups, self.n_inner
        d_in, H, Pd, N = ssm.mamba_dims(cfg)
        K, hd = cfg.n_kv_heads, cfg.head_dim
        dt = cfg.compute_dtype
        bdp = None if batch == 1 else "dp"
        sp = "all" if batch == 1 else "sp"
        cache = {
            "ssd": jax.ShapeDtypeStruct((G, Kn, batch, H, Pd, N),
                                        jnp.float32),
            "conv": jax.ShapeDtypeStruct((G, Kn, batch, s.d_conv - 1, H, Pd),
                                         dt),
            "k": jax.ShapeDtypeStruct((G, batch, max_seq, K, hd), dt),
            "v": jax.ShapeDtypeStruct((G, batch, max_seq, K, hd), dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = {
            "ssd": P(None, None, bdp, "tp", None, None),
            "conv": P(None, None, bdp, None, "tp", None),
            "k": P(None, bdp, sp, None, None),
            "v": P(None, bdp, sp, None, None),
            "pos": P(),
        }
        return cache, specs

    def init_cache(self, batch: int, max_seq: int):
        shapes, _ = self.abstract_cache(batch, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def prefill(self, params, batch):
        cfg = self.cfg
        h = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, (states, kvs) = self._stack_fwd(params, h, positions, collect=True)
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        cache = {"ssd": states["ssd"], "conv": states["conv"],
                 "k": kvs[0], "v": kvs[1],
                 "pos": jnp.asarray(S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        pos = cache["pos"]
        h = embed_tokens(params["embed"], tokens, cfg.compute_dtype)
        shared = params["shared"]

        def inner(h, xs):
            p, ssd_st, conv_st = xs
            a_in = apply_norm(cfg, p["ln"], h)
            out, new_state = ssm.mamba2_decode(
                cfg, p["mamba"], a_in, {"ssd": ssd_st, "conv": conv_st})
            return h + out, (new_state["ssd"], new_state["conv"])

        def group(h, xs):
            gp, ssd_g, conv_g, k_g, v_g = xs
            h, (ssd_n, conv_n) = jax.lax.scan(inner, h, (gp, ssd_g, conv_g))
            a_in = apply_norm(cfg, shared["ln1"], h)
            a_out, k_n, v_n = attn.gqa_decode(cfg, shared["attn"], a_in,
                                              pos, k_g, v_g)
            h = h + a_out
            m_in = apply_norm(cfg, shared["ln2"], h)
            h = h + apply_mlp(cfg, shared["mlp"], m_in)
            return h, (ssd_n, conv_n, k_n, v_n)

        h, (ssd, conv, k, v) = jax.lax.scan(
            group, h, (params["mamba"], cache["ssd"], cache["conv"],
                       cache["k"], cache["v"]))
        h = apply_norm(cfg, params["final_norm"], h)
        logits = h[:, -1].astype(jnp.float32) @ \
            params["embed"]["head"].astype(jnp.float32)
        return logits, {"ssd": ssd, "conv": conv, "k": k, "v": v,
                        "pos": pos + 1}
