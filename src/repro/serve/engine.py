"""Batched serving engine: prefill -> decode loop over fixed batch slots.

A deliberately small continuous-batching core: requests queue up, get
packed into the next prefill batch (padded to a common prompt length),
then decode runs lockstep for all slots with per-slot stop handling.
Session state (the KV cache) can be parked to / revived from the object
store between turns (``park_session`` / ``resume_session``), which is the
serving-side payoff of KV-pages-as-objects.

Serving also reads *data*: per-request feature/context lookups are
analytics scans against the same store that holds the KV pages.  At
high request fan-in those scans are massively redundant (every request
for a hot entity re-scans the same hot objects), so the engine can
attach a :class:`~repro.core.session.ScanSession` front-end
(``attach_analytics``) and route lookups through it
(``analytics``) — identical concurrent scans single-flight into one
OSD round trip and the OSD-side result caches absorb the repeats.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.session import ScanSession
from repro.core.store import ObjectStore
from repro.serve import kvcache


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray          # (<=max_new,) int32
    steps: int


class ServeEngine:
    def __init__(self, model, params, *, max_seq: int = 512,
                 greedy: bool = True, store: ObjectStore | None = None):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.greedy = greedy
        self.store = store
        # hot-data serve plane: the analytics front-end for per-request
        # feature/context scans (attach_analytics)
        self.analytics_session: ScanSession | None = None
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------ data
    def attach_analytics(self, vol, *,
                         window_s: float = 0.0) -> ScanSession:
        """Attach the analytics front-end: per-request scans issued via
        ``analytics`` dedup through one shared :class:`ScanSession`
        (single-flight + column coalescing) over ``vol``."""
        self.analytics_session = ScanSession(vol, window_s=window_s)
        return self.analytics_session

    def analytics(self, scan) -> tuple[Any, dict]:
        """Run one per-request analytics scan through the serve plane.
        Falls back to a direct execution when no session is attached
        (cold engines stay usable, they just skip the dedup layer)."""
        if self.analytics_session is None:
            return scan.execute()
        return self.analytics_session.execute(scan)

    # ------------------------------------------------------------ batch
    def generate(self, reqs: list[Request]) -> list[Completion]:
        if not reqs:
            return []
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        cache = self._pad_cache(cache)  # prompt-length -> max_seq slots
        max_new = max(r.max_new for r in reqs)
        out = np.full((B, max_new), -1, np.int32)
        done = np.zeros(B, bool)
        tok = self._pick(logits)
        for t in range(max_new):
            out[:, t] = np.where(done, -1, np.asarray(tok))
            for i, r in enumerate(reqs):
                if r.eos_id is not None and out[i, t] == r.eos_id:
                    done[i] = True
                if t + 1 >= r.max_new:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params,
                                         jnp.asarray(out[:, t:t + 1]),
                                         cache)
            tok = self._pick(logits)
        comps = []
        for i, r in enumerate(reqs):
            toks = out[i][out[i] >= 0][:r.max_new]
            comps.append(Completion(tokens=toks, steps=len(toks)))
        self._last_cache = cache
        return comps

    def _pick(self, logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _pad_cache(self, cache):
        """Grow sequence-axis leaves from prompt length to max_seq so
        decode has slots to write into."""
        out = dict(cache)
        for key in ("k", "v", "ckv", "krope"):
            if key in out:
                arr = out[key]
                pad = self.max_seq - arr.shape[2]
                if pad > 0:
                    widths = [(0, 0)] * arr.ndim
                    widths[2] = (0, pad)
                    out[key] = jnp.pad(arr, widths)
        return out

    # ------------------------------------------------------------ park
    def park_session(self, session: str, cache=None) -> None:
        if self.store is None:
            raise RuntimeError("no store attached")
        cache = self._last_cache if cache is None else cache
        seq_axes = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            key = jax.tree_util.keystr(path)
            if any(tag in key for tag in ("'k'", "'v'", "'ckv'", "'krope'")):
                seq_axes[key] = 2  # (L, B, S, ...)
        kvcache.cache_to_objects(self.store, jax.device_get(cache),
                                 session, seq_axes=seq_axes)

    def resume_session(self, session: str, batch: int):
        if self.store is None:
            raise RuntimeError("no store attached")
        like = self.model.init_cache(batch, self.max_seq)
        host = kvcache.objects_to_cache(self.store,
                                        jax.device_get(like), session)
        return jax.tree.map(jnp.asarray, host)
