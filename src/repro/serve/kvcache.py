"""KV-cache pages as store objects.

Decode caches are the serving system's hot state; mapping cache *pages*
(fixed-size sequence stripes) to objects gives serving the same
durability story as training checkpoints: a preempted replica's sessions
resume on another host from the store.  MLA's latent cache (kv_lora 512)
is ~8x smaller per token than GQA kv=8 — the "semantic compression"
noted in DESIGN.md §4 — so its pages are proportionally cheaper.
"""

from __future__ import annotations

import json
from typing import Any

import jax
import numpy as np

from repro.core.store import ObjectStore

PAGE_TOKENS = 2048


def _leaf_pages(key: str, arr: np.ndarray, seq_axis: int) -> list[tuple]:
    S = arr.shape[seq_axis]
    pages = []
    for p0 in range(0, S, PAGE_TOKENS):
        sl = [slice(None)] * arr.ndim
        sl[seq_axis] = slice(p0, min(p0 + PAGE_TOKENS, S))
        pages.append((p0, arr[tuple(sl)]))
    return pages


def cache_to_objects(store: ObjectStore, cache: Any, session: str,
                     *, seq_axes: dict[str, int]) -> dict:
    """Persist a decode cache; ``seq_axes`` maps leaf name -> sequence
    axis (leaves absent from the map are stored whole, e.g. SSM states).
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(cache)
    manifest: dict = {"session": session, "leaves": {}}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        meta = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                "pages": []}
        names: list[str] = []
        blobs: list[bytes] = []
        axis = seq_axes.get(key)
        if axis is None:
            name = f"kv/{session}/{len(manifest['leaves']):04d}/whole"
            names.append(name)
            blobs.append(arr.tobytes())
            meta["pages"].append([name, -1])
        else:
            meta["seq_axis"] = axis
            for p0, page in _leaf_pages(key, arr, axis):
                name = (f"kv/{session}/{len(manifest['leaves']):04d}/"
                        f"p{p0:08d}")
                names.append(name)
                blobs.append(np.ascontiguousarray(page).tobytes())
                meta["pages"].append([name, p0])
        # each leaf's pages ride the batched write plane (one request
        # per OSD per leaf, and at most one leaf buffered in memory —
        # pages are already materialized here, so the windowed
        # streaming mode would add feeder overhead with nothing left
        # to overlap)
        store.put_batch(names, blobs)
        manifest["leaves"][key] = meta
    # manifest LAST — the commit point stays ordered after the data
    store.put(f"kv/{session}/.manifest", json.dumps(manifest).encode())
    return manifest


def objects_to_cache(store: ObjectStore, cache_like: Any,
                     session: str) -> Any:
    manifest = json.loads(store.get(f"kv/{session}/.manifest").decode())
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        meta = manifest["leaves"][key]
        shape = tuple(meta["shape"])
        if meta["pages"][0][1] == -1:
            raw = store.get(meta["pages"][0][0])
            arr = np.frombuffer(raw, meta["dtype"]).reshape(shape).copy()
        else:
            axis = meta["seq_axis"]
            arr = np.empty(shape, meta["dtype"])
            for name, p0 in meta["pages"]:
                raw = store.get(name)
                sl = [slice(None)] * arr.ndim
                stop = min(p0 + PAGE_TOKENS, shape[axis])
                sl[axis] = slice(p0, stop)
                page_shape = list(shape)
                page_shape[axis] = stop - p0
                arr[tuple(sl)] = np.frombuffer(raw, meta["dtype"]).reshape(
                    page_shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
