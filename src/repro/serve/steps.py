"""Serving step builders: prefill (prompt -> cache) and decode (1 token)."""

from __future__ import annotations


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return serve_step
