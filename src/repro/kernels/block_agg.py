"""Pallas TPU kernel: masked blockwise partial aggregation.

The terminal ``agg`` objclass op when the validity mask is already
materialized (e.g. tokens != pad, or a composed upstream filter).  One
VMEM pass per (block_rows, 128) tile emitting [sum, count, min, max]
partials — associative, so partials combine across tiles, shards, and
pods in any order (composability, paper §3.2).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _block_agg_kernel(v_ref, m_ref, o_ref):
    v = v_ref[...].astype(jnp.float32)              # (bm, 128)
    m = m_ref[...] != 0
    big = jnp.float32(3.4e38)
    s = jnp.sum(jnp.where(m, v, 0.0))
    c = jnp.sum(m.astype(jnp.float32))
    lo = jnp.min(jnp.where(m, v, big))
    hi = jnp.max(jnp.where(m, v, -big))
    row = jnp.stack([s, c, lo, hi])
    o_ref[...] = jnp.broadcast_to(row[:, None], (4, 128))[None]


def block_agg(values: jax.Array, mask: jax.Array, *,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False) -> jax.Array:
    """values: (N,) float; mask: (N,) int/bool.  N % (block_rows*128) == 0.
    Returns (n_blocks, 4, 128) partials (see filter_agg.combine_partials).
    """
    N = values.shape[0]
    tile = block_rows * 128
    if N % tile:
        raise ValueError(f"N={N} not divisible by tile={tile}")
    grid = (N // tile,)
    v2 = values.reshape(N // 128, 128)
    m2 = mask.astype(jnp.int32).reshape(N // 128, 128)
    return pl.pallas_call(
        _block_agg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4, 128), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N // tile, 4, 128), jnp.float32),
        interpret=interpret,
    )(v2, m2)
