"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bitunpack_ref(words: jax.Array, bits: int) -> jax.Array:
    """(R, 4, bits) uint32 -> (R, 128) int32."""
    lane = jnp.arange(32, dtype=jnp.uint32)
    sel = (words[..., None] >> lane) & jnp.uint32(1)      # (R,4,b,32)
    weight = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32))
    vals = jnp.sum(sel * weight[None, None, :, None], axis=2,
                   dtype=jnp.uint32)                       # (R,4,32)
    return vals.reshape(words.shape[0], 128).astype(jnp.int32)


_PREDS = {
    "<": jnp.less, "<=": jnp.less_equal, ">": jnp.greater,
    ">=": jnp.greater_equal, "==": jnp.equal, "!=": jnp.not_equal,
}


def filter_agg_ref(values: jax.Array, filter_col: jax.Array, cmp: str,
                   threshold: float) -> dict[str, jax.Array]:
    v = values.astype(jnp.float32)
    m = _PREDS[cmp](filter_col.astype(jnp.float32), jnp.float32(threshold))
    big = jnp.float32(3.4e38)
    return {
        "sum": jnp.sum(jnp.where(m, v, 0.0)),
        "count": jnp.sum(m.astype(jnp.float32)),
        "min": jnp.min(jnp.where(m, v, big)),
        "max": jnp.max(jnp.where(m, v, -big)),
    }


def block_agg_ref(values: jax.Array, mask: jax.Array) -> dict:
    v = values.astype(jnp.float32)
    m = mask != 0
    big = jnp.float32(3.4e38)
    return {
        "sum": jnp.sum(jnp.where(m, v, 0.0)),
        "count": jnp.sum(m.astype(jnp.float32)),
        "min": jnp.min(jnp.where(m, v, big)),
        "max": jnp.max(jnp.where(m, v, -big)),
    }
