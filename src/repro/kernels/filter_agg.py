"""Pallas TPU kernel: fused predicate filter + partial aggregation.

The SkyhookDM `filter -> agg` pipeline as one VMEM pass: stream (8k, 128)
tiles of the value and filter columns through VMEM, evaluate the
predicate, and emit one (8, 128) partial accumulator per grid step
holding [sum, count, min, max] replicated across lanes (row 0..3; rows
4-7 padding) — reduced to 4 scalars outside.  Only the partials leave
the block: the kernel is the device twin of ``objclass`` filter+agg and
the unit the collective-bytes term sees is O(grid), not O(N).

Predicates are compile-time (op id baked into the kernel), values fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OPS = ("<", "<=", ">", ">=", "==", "!=")
DEFAULT_BLOCK_ROWS = 64  # x 128 lanes = 8192 values/tile


def _pred(opi: int, x, thr):
    return [
        lambda: x < thr, lambda: x <= thr, lambda: x > thr,
        lambda: x >= thr, lambda: x == thr, lambda: x != thr,
    ][opi]()


def _filter_agg_kernel(v_ref, f_ref, o_ref, *, opi: int, thr: float):
    v = v_ref[...].astype(jnp.float32)              # (bm, 128)
    f = f_ref[...].astype(jnp.float32)
    m = _pred(opi, f, jnp.float32(thr))
    big = jnp.float32(3.4e38)
    s = jnp.sum(jnp.where(m, v, 0.0))
    c = jnp.sum(m.astype(jnp.float32))
    lo = jnp.min(jnp.where(m, v, big))
    hi = jnp.max(jnp.where(m, v, -big))
    row = jnp.stack([s, c, lo, hi])                 # (4,)
    o_ref[...] = jnp.broadcast_to(row[:, None], (4, 128))[None]


def filter_agg(values: jax.Array, filter_col: jax.Array, cmp: str,
               threshold: float, *,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False) -> jax.Array:
    """values/filter_col: (N,) with N % (block_rows*128) == 0.
    Returns (n_blocks, 4, 128) partials; combine with ``combine_partials``.
    """
    opi = _OPS.index(cmp)
    N = values.shape[0]
    tile = block_rows * 128
    if N % tile:
        raise ValueError(f"N={N} not divisible by tile={tile}")
    grid = (N // tile,)
    v2 = values.reshape(N // 128, 128)
    f2 = filter_col.reshape(N // 128, 128)
    return pl.pallas_call(
        functools.partial(_filter_agg_kernel, opi=opi,
                          thr=float(threshold)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4, 128), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N // tile, 4, 128), jnp.float32),
        interpret=interpret,
    )(v2, f2)


def combine_partials(partials: jax.Array) -> dict[str, jax.Array]:
    """(n_blocks, 4, 128) -> scalars.  Associative; safe under psum."""
    p = partials[..., 0]                            # lanes identical
    return {"sum": jnp.sum(p[:, 0]), "count": jnp.sum(p[:, 1]),
            "min": jnp.min(p[:, 2]), "max": jnp.max(p[:, 3])}
