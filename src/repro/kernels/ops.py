"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU backend the same call sites compile to Mosaic.  ``_interpret()``
keys off the default backend so call sites never branch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitunpack as _bu
from repro.kernels import block_agg as _ba
from repro.kernels import filter_agg as _fa


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "block_r"))
def bitunpack_tokens(words: jax.Array, *, bits: int,
                     block_r: int = _bu.DEFAULT_BLOCK_R) -> jax.Array:
    """(B, G, bits) packed batch -> (B, G*32) int32 tokens.

    Reshapes to the kernel's (R, 4, bits) row form; requires G % 4 == 0
    (i.e. seq_len % 128 == 0 — true for every assigned shape).
    """
    B, G, b = words.shape
    if b != bits or G % 4:
        raise ValueError(f"bad packed shape {words.shape}")
    rows = words.reshape(B * G // 4, 4, bits)
    out = _bu.bitunpack(rows, bits=bits,
                        block_r=min(block_r, rows.shape[0]),
                        interpret=_interpret())
    return out.reshape(B, G * 32)


@functools.partial(jax.jit,
                   static_argnames=("cmp", "threshold", "block_rows"))
def filter_aggregate(values: jax.Array, filter_col: jax.Array, cmp: str,
                     threshold, *,
                     block_rows: int = _fa.DEFAULT_BLOCK_ROWS) -> dict:
    """Fused filter+agg; pads N up to a tile boundary with mask-failing
    rows so any N works."""
    N = values.shape[0]
    tile = block_rows * 128
    pad = (-N) % tile
    if pad:
        values = jnp.pad(values, (0, pad))
        # pad filter with a value that fails the predicate: NaN compares
        # False under < <= > >= ==; for != use the threshold itself.
        pad_val = float(threshold) if cmp == "!=" else float("nan")
        filter_col = jnp.pad(filter_col.astype(jnp.float32), (0, pad),
                             constant_values=pad_val)
    partials = _fa.filter_agg(values, filter_col, cmp, float(threshold),
                              block_rows=block_rows,
                              interpret=_interpret())
    return _fa.combine_partials(partials)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def masked_aggregate(values: jax.Array, mask: jax.Array, *,
                     block_rows: int = _ba.DEFAULT_BLOCK_ROWS) -> dict:
    N = values.shape[0]
    tile = block_rows * 128
    pad = (-N) % tile
    if pad:
        values = jnp.pad(values, (0, pad))
        mask = jnp.pad(mask.astype(jnp.int32), (0, pad))
    partials = _ba.block_agg(values, mask, block_rows=block_rows,
                             interpret=_interpret())
    return _fa.combine_partials(partials)
