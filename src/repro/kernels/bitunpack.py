"""Pallas TPU kernel: planar bitpack decode (storage codec offload).

Input layout (core.format planar codec): each group of 32 values is b
uint32 words; word k holds bit k of all 32 values.  We process 4 groups
per output row so the output tile is 128-lane aligned for the VPU:

  words  (R, 4, b)  uint32   ->   values (R, 128) int32

Tiling: a (BLOCK_R, 4, b) word tile is (BLOCK_R * 4 * b * 4) bytes of
VMEM; with BLOCK_R=256 and b=17 that's ~70 KiB in + 128 KiB out — well
inside the ~16 MiB VMEM budget, leaving room for double buffering.  The
unpack is shift/mask/sum VPU work with zero MXU involvement, so it
overlaps cleanly with neighbouring matmul stages when fused into a step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_R = 256


def _bitunpack_kernel(w_ref, o_ref, *, bits: int):
    w = w_ref[...]                                  # (bm, 4, bits) uint32
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    sel = (w[..., None] >> lane) & jnp.uint32(1)    # (bm, 4, bits, 32)
    weight = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32)
              )[None, None, :, None]
    vals = jnp.sum(sel * weight, axis=2, dtype=jnp.uint32)  # (bm, 4, 32)
    bm = vals.shape[0]
    o_ref[...] = vals.reshape(bm, 128).astype(jnp.int32)


def bitunpack(words: jax.Array, *, bits: int,
              block_r: int = DEFAULT_BLOCK_R,
              interpret: bool = False) -> jax.Array:
    """(R, 4, bits) uint32 -> (R, 128) int32 via pallas_call."""
    R = words.shape[0]
    if words.shape[1:] != (4, bits):
        raise ValueError(f"want (R, 4, {bits}), got {words.shape}")
    bm = min(block_r, R)
    if R % bm:
        raise ValueError(f"R={R} not divisible by block_r={bm}")
    grid = (R // bm,)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, 4, bits), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bm, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 128), jnp.int32),
        interpret=interpret,
    )(words)
