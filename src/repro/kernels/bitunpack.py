"""Pallas TPU kernel: planar bitpack decode (storage codec offload).

Input layout (core.format planar codec): each group of 32 values is b
uint32 words; word k holds bit k of all 32 values.  We process 4 groups
per output row so the output tile is 128-lane aligned for the VPU:

  words  (R, 4, b)  uint32   ->   values (R, 128) int32

Tiling: a (BLOCK_R, 4, b) word tile is (BLOCK_R * 4 * b * 4) bytes of
VMEM; with BLOCK_R=256 and b=17 that's ~70 KiB in + 128 KiB out — well
inside the ~16 MiB VMEM budget, leaving room for double buffering.  The
unpack is shift/mask/sum VPU work with zero MXU involvement, so it
overlaps cleanly with neighbouring matmul stages when fused into a step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


DEFAULT_BLOCK_R = 256


def pad_to_grid(rows: int, block_r: int = DEFAULT_BLOCK_R
                ) -> tuple[int, int]:
    """Choose (block_r, padded_rows) for an R-row launch: the grid-step
    count comes from ``block_r``, then the block height is rebalanced to
    ceil(rows / n_blocks), so padding is bounded by n_blocks - 1 rows —
    padding straight up to a ``block_r`` multiple would nearly double
    the kernel work at rows = block_r + 1."""
    n_blocks = max(1, -(-rows // block_r))
    bm = -(-rows // n_blocks)
    return bm, n_blocks * bm


def _bitunpack_kernel(w_ref, o_ref, *, bits: int):
    w = w_ref[...]                                  # (bm, 4, bits) uint32
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 1, 32), 3)
    sel = (w[..., None] >> lane) & jnp.uint32(1)    # (bm, 4, bits, 32)
    weight = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32)
              )[None, None, :, None]
    vals = jnp.sum(sel * weight, axis=2, dtype=jnp.uint32)  # (bm, 4, 32)
    bm = vals.shape[0]
    o_ref[...] = vals.reshape(bm, 128).astype(jnp.int32)


def bitunpack(words: jax.Array, *, bits: int,
              block_r: int = DEFAULT_BLOCK_R,
              interpret: bool = False) -> jax.Array:
    """(R, 4, bits) uint32 -> (R, 128) int32 via pallas_call."""
    R = words.shape[0]
    if words.shape[1:] != (4, bits):
        raise ValueError(f"want (R, 4, {bits}), got {words.shape}")
    bm = min(block_r, R)
    if R % bm:
        raise ValueError(f"R={R} not divisible by block_r={bm}")
    grid = (R // bm,)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, 4, bits), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bm, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 128), jnp.int32),
        interpret=interpret,
    )(words)


def bitunpack_words(words: np.ndarray, bits: int, n: int, *,
                    interpret: bool | None = None) -> np.ndarray:
    """(G, bits) uint32 planar words -> (n,) uint32 via the Pallas kernel.

    Host-side adapter for the storage scan path
    (``format._decode_column`` / ``objclass.run_pipeline``): pads the
    group count up to a legal (R, 4, bits) tile, runs the kernel on the
    selected jax backend (interpret mode on CPU, so the exact code path
    stays testable without a TPU), and slices the padding back off.
    Bit-exact with ``format.bitpack_decode`` — the zero pad groups decode
    to zeros and are dropped.
    """
    w = np.ascontiguousarray(words, dtype=np.uint32).reshape(-1, bits)
    n_groups = w.shape[0]
    if n_groups == 0:
        return np.zeros((0,), np.uint32)[:n]
    rows = -(-n_groups // 4)                    # 4 groups per 128-lane row
    bm, rows = pad_to_grid(rows)
    if rows * 4 != n_groups:
        padded = np.zeros((rows * 4, bits), np.uint32)
        padded[:n_groups] = w
        w = padded
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    vals = bitunpack(jnp.asarray(w.reshape(rows, 4, bits)), bits=bits,
                     block_r=bm, interpret=interpret)
    return np.asarray(vals).astype(np.uint32).ravel()[:n]
