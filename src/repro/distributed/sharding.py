"""Logical-axis sharding rules — strategy tables per workload.

Models are written against *logical* axes; a MeshRules instance resolves
them to mesh axes under one of four parallelism strategies:

  fsdp         TRAIN, single pod.  Pure ZeRO-3: the batch covers EVERY
               mesh axis (1 seq/device at the assigned shapes), weights
               are all-gathered layer-by-layer inside the scan.  No
               tensor parallelism — collectives are per-layer weight
               gathers (O(params/L)), independent of batch, which beats
               Megatron's activation gathers whenever
               tokens/device * D > params/layer.
  megatron_sp  TRAIN, multi-pod (and MoE giants that cannot hold fp32
               moments under pure FSDP).  Batch over (pod, data), TP
               over model (heads / d_ff / experts' F / vocab), residual
               stream sequence-sharded over model between layers
               (Megatron-LM SP); attention inputs are re-gathered to
               full sequence ONCE per layer via an explicit hint so the
               flash scan loops stay collective-free.
  fsdp_dp      TRAIN, multi-pod, SSM/hybrid families.  Like fsdp but the
               batch only covers (pod, data): sequence scans (Mamba/WKV)
               are sequential in S, so activations stay seq-local.
  tp_sp        SERVE (prefill/decode).  Params FSDP over data axes + TP
               over model; decode KV caches sequence-sharded over model
               ("sp") with the flash-decode softmax combine.

Logical axes:
  dp           batch dimension of inputs/activations
  fsdp         dim-0 storage sharding of dense weights
  fsdp_expert  storage sharding of MoE expert weights (middle dim)
  tp           tensor-parallel dim (heads / d_ff / vocab / expert F)
  act_seq      sequence dim of the residual stream between layers
  sp           sequence dim of decode KV caches
  tokens       flattened token dim for shard-local MoE dispatch
  all          every mesh axis

``hint(x, *axes)`` applies with_sharding_constraint when a mesh is
active and is a no-op otherwise, so model code runs unchanged in
single-device smoke tests.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar["MeshRules | None"] = contextvars.ContextVar(
    "repro_mesh_rules", default=None)

STRATEGIES = ("fsdp", "megatron_sp", "fsdp_dp", "tp_dp", "tp_sp")


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    strategy: str = "tp_sp"
    # axes already manual in an enclosing shard_map: resolve() drops them
    # so inner with_sharding_constraints only touch auto axes
    manual_axes: tuple = ()

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")

    # ------------------------------------------------------------------
    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def all_axes(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def table(self) -> dict:
        dp, allax = self.dp_axes, self.all_axes
        model = "model" if "model" in allax else None
        if self.strategy == "fsdp":
            full = dp + ((model,) if model else ())
            return {"dp": full, "fsdp": full, "fsdp_expert": full,
                    "tp": None, "act_seq": None, "sp": model,
                    "tokens": full}
        if self.strategy == "megatron_sp":
            return {"dp": dp, "fsdp": dp, "fsdp_expert": dp,
                    "tp": model, "act_seq": model, "sp": model,
                    "tokens": dp + ((model,) if model else ())}
        if self.strategy == "fsdp_dp":
            full = dp + ((model,) if model else ())
            return {"dp": dp, "fsdp": full, "fsdp_expert": full,
                    "tp": None, "act_seq": None, "sp": model,
                    "tokens": dp}
        if self.strategy == "tp_dp":
            # Megatron-1D without sequence parallelism: batch over
            # (pod, data), heads/d_ff/state-heads TP over model, full-seq
            # activations (pair with gradient-accumulation microbatching).
            # The TP split works for SSM scans too: heads are independent
            # through time, so Mamba2/WKV states shard over model.
            return {"dp": dp, "fsdp": dp, "fsdp_expert": dp,
                    "tp": model, "act_seq": None, "sp": model,
                    "tokens": dp}
        return {"dp": dp, "fsdp": dp, "fsdp_expert": dp,  # tp_sp
                "tp": model, "act_seq": None, "sp": model,
                "tokens": dp}

    # ------------------------------------------------------------------
    def resolve(self, logical: Any):
        """Translate one logical axis name to mesh axes (or None)."""
        out = self._resolve(logical)
        if not self.manual_axes or out is None:
            return out
        axes = out if isinstance(out, tuple) else (out,)
        kept = tuple(a for a in axes if a not in self.manual_axes)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    def _resolve(self, logical: Any):
        if logical is None:
            return None
        if logical in self.mesh.axis_names:  # explicit mesh axis: pass
            return logical
        if logical == "all":
            return self.all_axes
        if isinstance(logical, str) and logical.endswith("_nopod"):
            # variant of a logical axis excluding 'pod' (used when an
            # array carries an explicit leading pod dim, e.g. per-pod
            # error-feedback state)
            axes = self.resolve(logical[:-len("_nopod")])
            if axes is None:
                return None
            if not isinstance(axes, tuple):
                return None if axes == "pod" else axes
            rest = tuple(a for a in axes if a != "pod")
            return rest if len(rest) > 1 else (rest[0] if rest else None)
        if logical in self.table:
            axes = self.table[logical]
            if isinstance(axes, tuple):
                if not axes:
                    return None
                return axes if len(axes) > 1 else axes[0]
            return axes
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical: Any) -> P:
        return P(*[self.resolve(ax) for ax in logical])

    def sharding(self, *logical: Any) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------ moe
    @property
    def token_axes(self) -> tuple[str, ...]:
        t = self.table["tokens"]
        return t if isinstance(t, tuple) else (t,)

    @property
    def moe_tp(self) -> str | None:
        return self.table["tp"]


def active_rules() -> MeshRules | None:
    return _ACTIVE.get()


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    token = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(token)


def hint(x: jax.Array, *logical: Any) -> jax.Array:
    """Sharding constraint by logical axes; no-op without an active mesh."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(*logical))


def spec_tree_to_shardings(rules: MeshRules, spec_tree):
    """Map a pytree of PartitionSpecs to NamedShardings."""
    return jax.tree.map(
        lambda s: rules.named(s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
