"""Elastic scaling: cluster resize planning with minimal data movement.

Two layers, mirroring the paper's separation:

  * storage: adding/removing OSDs is a ClusterMap epoch bump; the
    object movement plan is ``placement.pg_delta`` and the expected
    movement fraction is ~ changed_capacity / total_capacity (HRW's
    minimal-movement property, hypothesis-tested);
  * training: changing dp_size re-slices the *same* deterministic
    (seed, step) -> rows mapping, so a resized job continues the exact
    global data order with zero re-shuffling — hosts just take different
    slices.  ``replan_loader`` returns the per-rank slices before/after
    and verifies coverage.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.placement import ClusterMap, movement_fraction, pg_delta
from repro.core.store import ObjectStore


@dataclasses.dataclass(frozen=True)
class ResizePlan:
    old_osds: tuple[str, ...]
    new_osds: tuple[str, ...]
    pgs_remapped: int
    movement_fraction: float
    epoch: int


def plan_storage_resize(cluster: ClusterMap,
                        add: tuple[str, ...] = (),
                        remove: tuple[str, ...] = ()) -> tuple[ClusterMap,
                                                               ResizePlan]:
    new = cluster
    for o in add:
        new = new.add_osds([o])
    for o in remove:
        new = new.remove_osd(o)
    delta = pg_delta(cluster, new)
    plan = ResizePlan(
        old_osds=cluster.osds, new_osds=new.osds,
        pgs_remapped=len(delta),
        movement_fraction=movement_fraction(cluster, new),
        epoch=new.epoch)
    return new, plan


def apply_storage_resize(store: ObjectStore,
                         add: tuple[str, ...] = (),
                         remove: tuple[str, ...] = ()) -> dict:
    """Resize + recover: after this every object is fully replicated on
    the new map and removed OSDs hold nothing the cluster needs."""
    old = store.cluster
    new, plan = plan_storage_resize(old, add, remove)
    for o in add:
        if o not in store.osds:
            store.osds[o] = type(store.osds[next(iter(store.osds))])(o)
    store.cluster = new
    stats = store.recover(old)
    return {"plan": dataclasses.asdict(plan), **stats}


def replan_loader(n_rows: int, global_batch: int,
                  old_dp: int, new_dp: int) -> dict:
    """Check a dp resize keeps the global order intact: the union of all
    ranks' row slices for a step is the same batch before and after."""
    if global_batch % old_dp or global_batch % new_dp:
        raise ValueError("global_batch must divide both dp sizes")
    idx = np.arange(global_batch)
    old_slices = [idx[r::old_dp] for r in range(old_dp)]
    new_slices = [idx[r::new_dp] for r in range(new_dp)]
    same = (np.sort(np.concatenate(old_slices)) ==
            np.sort(np.concatenate(new_slices))).all()
    return {"coverage_preserved": bool(same),
            "old_local_batch": global_batch // old_dp,
            "new_local_batch": global_batch // new_dp}
