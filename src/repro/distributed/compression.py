"""Int8 gradient compression with error feedback (cross-pod axis).

The pod-to-pod ICI hop is the slowest link in the (pod, data, model)
mesh; compressing only that hop's all-reduce cuts its bytes 4x while the
error-feedback buffer keeps the optimizer trajectory unbiased in the
long run (residuals are re-added next step).

The transform is per-tensor symmetric int8: q = round(g / s), s =
max|g| / 127.  ``compressed_psum_pod`` is a shard_map region over the
pod axis: quantize -> all-to-all-free psum of int8 (accumulated in int32)
-> dequantize.  Scales psum too (one fp32 scalar per tensor).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_residual(g: jax.Array, err: jax.Array):
    """Error feedback: compress (g + err); new err = input - decoded."""
    x = g.astype(jnp.float32) + err
    q, s = quantize_int8(x)
    dec = dequantize_int8(q, s)
    return q, s, x - dec


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_pod_allreduce(grads: Any, err_state: Any) -> tuple[Any, Any]:
    """All-reduce gradients over the 'pod' axis in int8 (+fp32 scale per
    tensor), with error feedback.  No-op (identity) without a pod axis.

    Call INSIDE a shard_map/jit where the pod axis exists; for the
    plain-jit training path use ``make_compressed_grad_sync`` below which
    wraps the shard_map plumbing.
    """
    def one(g, e):
        q, s, e_new = compress_residual(g, e)
        # int8 psum accumulates exactly in int32 for <= 2**24 pods
        tot = jax.lax.psum(q.astype(jnp.int32), "pod")
        s_tot = jax.lax.psum(s, "pod")  # sum of per-pod scales
        # decode with the mean scale x pod count: q_i*s_i summed exactly
        # would need per-pod scales; the standard trick keeps s_i close
        # via error feedback, so mean-scale decode is what EF corrects.
        n = jax.lax.psum(1, "pod")
        g_out = (tot.astype(jnp.float32) * (s_tot / n)) / n
        return g_out.astype(g.dtype), e_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def make_compressed_train_step(model, opt_cfg, rules: "shd.MeshRules"):
    """Train step with the cross-pod gradient hop in int8 + error feedback.

    Partial-manual shard_map (jax>=0.8 ``axis_names={'pod'}``): the body
    is manual over 'pod' only — inside it, GSPMD still auto-shards over
    (data, model) exactly as the baseline step, so each pod computes its
    pod-local gradient (data-axis reduction stays fp32 *within* the pod),
    and the pod-to-pod hop — the slow link — moves int8 + one fp32 scale
    per tensor: 4x fewer bytes on the dominant collective.

    Error-feedback residuals are *per-pod* state: stored with a leading
    pod axis, shape (n_pods, *param.shape), sharded P('pod') — use
    ``init_compressed_state`` to add them to a base train state.
    """
    from repro.train.optimizer import adamw_update

    import dataclasses as _dc

    inner_rules = _dc.replace(rules, manual_axes=("pod",))

    def train_step(state, batch):
        def body(bstate, bbatch):
            def loss_fn(params):
                with shd.use_rules(inner_rules):  # pod is manual here
                    return model.loss(params, bbatch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(bstate["params"])
            err = jax.tree.map(lambda e: e[0], bstate["err"])
            grads, new_err = compressed_pod_allreduce(grads, err)
            new_params, new_opt, gnorm = adamw_update(
                opt_cfg, grads, bstate["params"], bstate["opt"])
            metrics = dict(metrics)
            metrics = {k: jax.lax.pmean(v, "pod") for k, v in
                       metrics.items()}
            metrics.update({"loss": jax.lax.pmean(loss, "pod"),
                            "grad_norm": gnorm, "step": new_opt["step"]})
            return ({"params": new_params, "opt": new_opt,
                     "err": jax.tree.map(lambda e: e[None], new_err)},
                    metrics)

        state_specs = {"params": P(), "opt": P(), "err": P("pod")}
        return jax.shard_map(
            body, mesh=rules.mesh, axis_names={"pod"},
            in_specs=(state_specs, P("pod")),
            out_specs=(state_specs, P()),
            check_vma=False,
        )(state, batch)

    return train_step


def init_compressed_state(state, n_pods: int):
    err = jax.tree.map(
        lambda p: jnp.zeros((n_pods, *p.shape), jnp.float32),
        state["params"])
    return dict(state, err=err)


def abstract_compressed_state(state_shapes, state_specs, n_pods: int):
    """ShapeDtypeStructs + specs for the err-augmented state (dry-run)."""
    err_shapes = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_pods, *p.shape), jnp.float32),
        state_shapes["params"])

    def _depod(entry):
        # specs here are LOGICAL ("fsdp"/"tp"/...); mark them so the
        # resolver drops 'pod' (the err array has an explicit pod dim 0)
        if isinstance(entry, str) and not entry.endswith("_nopod"):
            return entry + "_nopod"
        return entry

    err_specs = jax.tree.map(
        lambda s: P("pod", *[_depod(e) for e in tuple(s)]),
        state_specs["params"],
        is_leaf=lambda s: isinstance(s, P))
    return (dict(state_shapes, err=err_shapes),
            dict(state_specs, err=err_specs))


def make_compressed_grad_sync(rules: "shd.MeshRules", logical_specs):
    """Returns sync(grads, err) -> (grads, err): int8 all-reduce over the
    pod axis under shard_map; identity when the mesh has no pod axis.

    ``logical_specs`` is the params' logical-axis spec tree ("fsdp"/"tp");
    it is resolved against ``rules.mesh`` so each leaf enters the region
    as its local (data, model) block and only 'pod' is reduced.
    """
    mesh = rules.mesh
    if "pod" not in mesh.axis_names:
        return lambda g, e: (g, e)

    resolved = jax.tree.map(lambda s: rules.spec(*tuple(s)), logical_specs,
                            is_leaf=lambda s: isinstance(s, P))

    def sync(grads, err):
        return shard_map(
            compressed_pod_allreduce, mesh=mesh,
            in_specs=(resolved, resolved),
            out_specs=(resolved, resolved),
            check_rep=False,
        )(grads, err)

    return sync
