"""Shared plumbing for the verification plane: findings + suppressions.

A :class:`Finding` is one contract violation with a stable *suppression
key* — ``rule file.py:qualname`` — that names the violating *function*
(or registry op), never a line number, so an intentional finding stays
suppressed across unrelated edits to the file.  Suppressions live in a
committed text file and each line MUST carry a justification after
``--``; a suppression that no longer matches anything is itself
reported (stale suppressions hide future regressions).
"""

from __future__ import annotations

import dataclasses
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation.

    ``file``/``line`` point at the most useful source location (for
    call-graph rules that is the *root*, with the mutation site named
    in the message); ``qualname`` is the dotted function path used in
    the suppression key.
    """

    rule: str       # "accounting" | "lock-guard" | "lock-blocking" |
    #                 "write-path" | "registry" | ...
    file: str       # repo-relative path
    line: int
    qualname: str   # e.g. "ObjectStore.put", "SkyhookDriver.run.pump"
    message: str

    @property
    def key(self) -> str:
        """Suppression key: rule + basename + qualname (line-free)."""
        return f"{self.rule} {Path(self.file).name}:{self.qualname}"

    def render(self) -> str:
        return (f"{self.file}:{self.line}: [{self.rule}] "
                f"{self.qualname}: {self.message}")


@dataclasses.dataclass
class Suppression:
    key: str            # "rule file.py:qualname"
    justification: str
    lineno: int         # line in the suppression file (for reporting)
    used: bool = False


class SuppressionError(ValueError):
    """A malformed suppression line (missing justification, bad shape)."""


def load_suppressions(path: Path) -> list[Suppression]:
    """Parse the suppression file.

    Format, one per line (blank lines and ``#`` comments ignored)::

        <rule> <file.py>:<qualname> -- <why this is intentional>

    The justification is REQUIRED — an unexplained suppression is a
    parse error, not a working suppression.
    """
    out: list[Suppression] = []
    if not path.exists():
        return out
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "--" not in line:
            raise SuppressionError(
                f"{path.name}:{i}: suppression needs a justification "
                f"after '--': {line!r}")
        head, _, why = line.partition("--")
        why = why.strip()
        if not why:
            raise SuppressionError(
                f"{path.name}:{i}: empty justification: {line!r}")
        parts = head.split()
        if len(parts) != 2 or ":" not in parts[1]:
            raise SuppressionError(
                f"{path.name}:{i}: expected '<rule> <file>:<qualname>"
                f" -- <why>', got: {line!r}")
        out.append(Suppression(" ".join(parts), why, i))
    return out


def apply_suppressions(
    findings: list[Finding], supps: list[Suppression],
) -> tuple[list[Finding], list[Finding], list[Suppression]]:
    """Split findings into (active, suppressed); also return the
    suppressions that matched nothing (stale — report those too)."""
    by_key: dict[str, Suppression] = {s.key: s for s in supps}
    active, quiet = [], []
    for f in findings:
        s = by_key.get(f.key)
        if s is not None:
            s.used = True
            quiet.append(f)
        else:
            active.append(f)
    unused = [s for s in supps if not s.used]
    return active, quiet, unused
