"""Verification plane: static invariant linter + dynamic lock checker.

``python -m repro.analysis`` runs the AST passes (accounting, lock
discipline, blocking-while-locked, write-path completeness) and the
registry completeness pass; ``repro.analysis.lockcheck`` is the runtime
half — an instrumented-lock harness the test suite can switch on with
``pytest --lockcheck``.  See this package's README.md for the full
contract list and where each one came from.
"""

from repro.analysis.base import Finding  # noqa: F401
