"""Dynamic lock-order / lock-ownership checker — the runtime half of
the verification plane.

:func:`install` monkey-patches the ``__init__`` of every class in the
core concurrency modules so that, after construction:

* every ``threading.Lock`` attribute is replaced by an
  :class:`InstrumentedLock` that records, per thread, the stack of
  held locks and feeds a global **lock-order graph** (edge
  ``A -> B`` = some thread acquired B while holding A, keyed by
  ``Class.attr`` so instances aggregate);
* every *container* attribute registered in the class's
  ``_GUARDED_BY`` dict is wrapped in a guarded proxy whose **mutator**
  operations record a violation when the owning lock is not held by
  the calling thread (reads by quiescent observers — tests peeking at
  counters — are deliberately not flagged; the static pass covers
  read discipline lexically).

A cycle in the order graph (including a ``Class.attr`` self-edge:
two *instances* of the same lock held at once) is a deadlock hazard
even if no deadlock happened in this run — that is the point: the
harness turns "it didn't hang today" into "no inconsistent order was
ever exhibited".  The pytest ``--lockcheck`` flag (tests/conftest.py)
installs this over the whole suite and fails the run on any cycle or
ownership violation.
"""

from __future__ import annotations

import threading
import traceback
from collections import OrderedDict
from typing import Any

_LOCK_TYPE = type(threading.Lock())


class LockCheckState:
    """Global recording state shared by every instrumented lock."""

    def __init__(self) -> None:
        self._mx = threading.Lock()        # guards the state itself
        self._held = threading.local()     # per-thread list of locks
        self.edges: dict[str, set[str]] = {}
        self.edge_sites: dict[tuple[str, str], str] = {}
        self.violations: OrderedDict[tuple[str, str], str] = \
            OrderedDict()
        self.acquisitions = 0
        self.wrapped_locks = 0
        self.wrapped_containers = 0

    # ------------------------------------------------------------ held
    def _stack(self) -> list:
        st = getattr(self._held, "locks", None)
        if st is None:
            st = self._held.locks = []
        return st

    def holds(self, lock: "InstrumentedLock") -> bool:
        return any(h is lock for h in self._stack())

    # ------------------------------------------------------------ events
    def note_acquire(self, lock: "InstrumentedLock") -> None:
        st = self._stack()
        if st:
            site = _caller()
            with self._mx:
                for held in st:
                    # A -> A on the SAME instance would be a
                    # self-deadlock and cannot reach here (acquire
                    # would block); same NAME on another instance is
                    # a real ordering hazard and is recorded.
                    if held is lock:
                        continue
                    e = (held.name, lock.name)
                    self.edges.setdefault(e[0], set()).add(e[1])
                    self.edge_sites.setdefault(e, site)
        with self._mx:
            self.acquisitions += 1
        st.append(lock)

    def note_release(self, lock: "InstrumentedLock") -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] is lock:
                del st[i]
                return

    def note_violation(self, what: str, op: str) -> None:
        site = _caller()
        with self._mx:
            self.violations.setdefault(
                (what, site), f"{what}.{op} without owning lock "
                              f"at {site}")

    # ------------------------------------------------------------ verdict
    def cycles(self) -> list[list[str]]:
        """Elementary ordering cycles in the lock-order graph (Tarjan
        SCCs; a single-node SCC counts when it has a self-edge)."""
        index: dict[str, int] = {}
        low: dict[str, int] = {}
        on: set[str] = set()
        stack: list[str] = []
        out: list[list[str]] = []
        counter = [0]

        def strong(v: str) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on.add(v)
            for w in self.edges.get(v, ()):
                if w not in index:
                    strong(w)
                    low[v] = min(low[v], low[w])
                elif w in on:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on.discard(w)
                    scc.append(w)
                    if w == v:
                        break
                if len(scc) > 1 or v in self.edges.get(v, ()):
                    out.append(sorted(scc))

        for v in list(self.edges):
            if v not in index:
                strong(v)
        return out

    def report(self) -> dict:
        cyc = self.cycles()
        return {
            "acquisitions": self.acquisitions,
            "locks_instrumented": self.wrapped_locks,
            "containers_instrumented": self.wrapped_containers,
            "order_edges": {a: sorted(bs)
                            for a, bs in sorted(self.edges.items())},
            "cycles": cyc,
            "violations": list(self.violations.values()),
            "ok": not cyc and not self.violations,
        }


def _caller() -> str:
    """First stack frame outside this module (the code under test)."""
    for frame in reversed(traceback.extract_stack()[:-2]):
        if "analysis/lockcheck" not in frame.filename.replace(
                "\\", "/"):
            return f"{frame.filename}:{frame.lineno}"
    return "<unknown>"


class InstrumentedLock:
    """Drop-in ``threading.Lock`` recording order + ownership."""

    __slots__ = ("name", "_lk", "_state")

    def __init__(self, name: str, state: LockCheckState):
        self.name = name
        self._lk = threading.Lock()
        self._state = state

    def acquire(self, blocking: bool = True,
                timeout: float = -1) -> bool:
        got = self._lk.acquire(blocking, timeout)
        if got:
            self._state.note_acquire(self)
        return got

    def release(self) -> None:
        self._state.note_release(self)
        self._lk.release()

    def locked(self) -> bool:
        return self._lk.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<InstrumentedLock {self.name}>"


# --------------------------------------------------------------------------
# guarded-container proxies: mutators must hold the owning lock
# --------------------------------------------------------------------------


def _mutator(name: str):
    def op(self, *a, **k):
        if not self._lc_state.holds(self._lc_owner):
            self._lc_state.note_violation(self._lc_name, name)
        return getattr(self._lc_base, name)(self, *a, **k)
    op.__name__ = name
    return op


def _make_guarded(base: type) -> type:
    muts = {
        dict: ("__setitem__", "__delitem__", "pop", "popitem",
               "setdefault", "update", "clear"),
        OrderedDict: ("__setitem__", "__delitem__", "pop", "popitem",
                      "setdefault", "update", "clear", "move_to_end"),
        set: ("add", "discard", "remove", "pop", "clear", "update",
              "difference_update", "intersection_update"),
    }[base]
    ns: dict[str, Any] = {"_lc_base": base,
                          "__slots__": ("_lc_owner", "_lc_state",
                                        "_lc_name")}
    for m in muts:
        ns[m] = _mutator(m)
    return type(f"Guarded{base.__name__}", (base,), ns)


GuardedDict = _make_guarded(dict)
GuardedOrderedDict = _make_guarded(OrderedDict)
GuardedSet = _make_guarded(set)

_PROXIES: dict[type, type] = {dict: GuardedDict,
                              OrderedDict: GuardedOrderedDict,
                              set: GuardedSet}


def _wrap_container(value, name: str, owner: InstrumentedLock,
                    state: LockCheckState):
    proxy = _PROXIES.get(type(value))
    if proxy is None:
        return None
    if isinstance(value, OrderedDict) or isinstance(value, dict):
        wrapped = proxy(value)
    else:
        wrapped = proxy(value)
    wrapped._lc_owner = owner
    wrapped._lc_state = state
    wrapped._lc_name = name
    return wrapped


# --------------------------------------------------------------------------
# install / uninstall
# --------------------------------------------------------------------------

_CORE_MODULES = ("repro.core.store", "repro.core.cache",
                 "repro.core.session", "repro.core.maintenance",
                 "repro.core.faults", "repro.core.skyhook")


def _instrument_instance(self, state: LockCheckState) -> None:
    cls = type(self)
    try:
        attrs = vars(self)
    except TypeError:       # __slots__-only instances hold no locks
        return
    locks: dict[str, InstrumentedLock] = {}
    for attr, value in list(attrs.items()):
        if isinstance(value, _LOCK_TYPE):
            il = InstrumentedLock(f"{cls.__name__}.{attr}", state)
            setattr(self, attr, il)
            locks[attr] = il
            state.wrapped_locks += 1
    guarded = getattr(cls, "_GUARDED_BY", None)
    if not guarded:
        return
    for attr, lock_attr in guarded.items():
        owner = locks.get(lock_attr)
        value = attrs.get(attr)
        if owner is None or value is None:
            continue
        wrapped = _wrap_container(value, f"{cls.__name__}.{attr}",
                                  owner, state)
        if wrapped is not None:
            setattr(self, attr, wrapped)
            state.wrapped_containers += 1


def install() -> LockCheckState:
    """Patch the core classes; returns the recording state.  Call
    :func:`uninstall` to undo (idempotent per install)."""
    import importlib

    state = LockCheckState()
    patched: list[tuple[type, Any]] = []
    for modname in _CORE_MODULES:
        mod = importlib.import_module(modname)
        for obj in list(vars(mod).values()):
            if not isinstance(obj, type) \
                    or obj.__module__ != modname:
                continue
            orig = obj.__init__

            def make(orig_init):
                def patched_init(self, *a, **k):
                    orig_init(self, *a, **k)
                    _instrument_instance(self, state)
                patched_init.__wrapped__ = orig_init
                return patched_init

            obj.__init__ = make(orig)
            patched.append((obj, orig))
    state._patched = patched        # type: ignore[attr-defined]
    return state


def uninstall(state: LockCheckState) -> None:
    for cls, orig in getattr(state, "_patched", ()):
        cls.__init__ = orig
    state._patched = []             # type: ignore[attr-defined]
