"""Pass (e): objclass registry completeness.

Runtime (not AST) checks over ``repro.core.objclass._REGISTRY`` — the
registry is data, so the honest check is to interrogate the real one:

* every registered op has **representative params** declared here and
  survives a wire round trip (``to_json -> json -> from_json``) with an
  identical pipeline digest — an op that can't cross the wire can't be
  pushed down;
* every op either rides a server-side merge plane (``exec_combine``:
  decomposable + combine + merge, partial-out) or the concat plane
  (table-out), **or** is explicitly declared not mergeable;
* every op's column needs are either analyzable by
  ``required_columns`` (single-col / col-free / project-filter shapes)
  **or** explicitly declared conservative (full-decode / blob-level).

The declaration sets make silence impossible: registering a new op
without updating them is a finding, and a declaration that a later
change makes stale (the op *became* mergeable) is a finding too.
All tables are injectable for the linter's own tests.
"""

from __future__ import annotations

import json

from repro.analysis.base import Finding

_FILE = "src/repro/core/objclass.py"

# representative params per op: minimal, JSON-able, shaped like real
# call sites (scan planner / hyperslab resolver / compaction)
REP_PARAMS: dict[str, dict] = {
    "select": {"rows": (0, 4)},
    "project": {"cols": ["x"]},
    "filter": {"col": "x", "cmp": ">", "value": 0.0},
    "agg": {"col": "x", "fn": "sum"},
    "multi_agg": {"specs": [["sum", "x"], ["min", "y"]]},
    "median": {"col": "x"},
    "quantile_sketch": {"col": "x", "q": 0.5},
    "recompress": {"codecs": {"x": "raw"}},
    "select_packed": {"rows": (0, 4), "col": "x"},
    "row_slice": {"rows": (0, 4)},
    "hyperslab_slice": {"space": {"shape": [8, 8], "chunk": [4, 4],
                                  "dtype": "float32"},
                        "sel": {"start": [0, 0], "count": [2, 2]}},
    "hyperslab_local": {"space": {"shape": [8, 8], "chunk": [4, 4],
                                  "dtype": "float32"},
                        "sel": {"start": [0, 0], "count": [2, 2]},
                        "chunk_start": 0, "cids": [0]},
    "compact_merge": {"out_layout": "col"},
}

# holistic / blob-level / placeholder-local ops that ride neither the
# combine plane nor the concat plane — each needs a reason to stay here
KNOWN_NOT_MERGEABLE: frozenset[str] = frozenset({
    "median",          # holistic: exact median has no associative partial
    "select_packed",   # partial-out blob slice; client-side unpack only
    "compact_merge",   # consumes N source blobs, not a table stream
})

# ops whose column needs required_columns() cannot narrow — declared
# conservative (full decode / blob-level), so a pipeline containing one
# correctly falls back to fetching every column
KNOWN_COL_CONSERVATIVE: frozenset[str] = frozenset({
    "recompress",        # rewrites every column's codec
    "select_packed",     # blob-level; bypasses the decoded table
    "hyperslab_slice",   # N-d cell selection over the stacked block
    "hyperslab_local",
    "compact_merge",     # whole-object rewrite
})


def check_registry(*, reps: dict | None = None,
                   not_mergeable: frozenset | None = None,
                   col_conservative: frozenset | None = None,
                   ops: tuple[str, ...] | None = None) -> list[Finding]:
    from repro.core import objclass as oc

    reps = REP_PARAMS if reps is None else reps
    not_mergeable = KNOWN_NOT_MERGEABLE if not_mergeable is None \
        else not_mergeable
    col_conservative = KNOWN_COL_CONSERVATIVE \
        if col_conservative is None else col_conservative
    ops = oc.registered_ops() if ops is None else ops

    analyzable = (set(oc._SINGLE_COL_OPS) | set(oc._COL_FREE_OPS)
                  | {"project", "filter", "multi_agg"})

    findings: list[Finding] = []

    def flag(name: str, msg: str) -> None:
        findings.append(Finding("registry", _FILE, 1,
                                f"op:{name}", msg))

    for name in ops:
        impl = oc.get_impl(name)

        # -- wire round trip over representative params
        rep = reps.get(name)
        if rep is None:
            flag(name, "no representative params declared "
                       "(REP_PARAMS) — wire round trip unchecked")
        else:
            o = oc.ObjOp(name, rep)
            try:
                wire = json.loads(json.dumps(o.to_json()))
                back = oc.ObjOp.from_json(wire)
                ok = (back.name == o.name
                      and oc.pipeline_digest([back])
                      == oc.pipeline_digest([o]))
            except Exception as e:        # noqa: BLE001 - report, don't die
                ok = False
                flag(name, f"wire round trip raised {e!r}")
            else:
                if not ok:
                    flag(name, "wire round trip changed the op "
                               "(digest mismatch after "
                               "to_json -> json -> from_json)")

        # -- merge-plane coverage
        combinable = (impl.decomposable and not impl.table_out
                      and impl.combine is not None
                      and impl.merge is not None)
        concatable = impl.table_out
        if not (combinable or concatable) \
                and name not in not_mergeable:
            flag(name, "neither combine-plane capable (decomposable + "
                       "combine + merge, partial-out) nor table-out, "
                       "and not declared in KNOWN_NOT_MERGEABLE")
        if (combinable or concatable) and name in not_mergeable:
            flag(name, "declared KNOWN_NOT_MERGEABLE but actually "
                       "rides a merge/concat plane — stale "
                       "declaration")

        # -- required_columns coverage
        if name not in analyzable \
                and name not in col_conservative:
            flag(name, "required_columns() cannot analyze this op and "
                       "it is not declared in KNOWN_COL_CONSERVATIVE")
        if name in analyzable and name in col_conservative:
            flag(name, "declared KNOWN_COL_CONSERVATIVE but "
                       "required_columns() analyzes it — stale "
                       "declaration")

    return findings
