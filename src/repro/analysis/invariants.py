"""AST invariant linter for the storage planes (``repro.core`` +
``repro.serve``).

The store's correctness arguments are concurrency contracts that unit
tests exercise but cannot *enforce* — a regression only shows up as a
rare lost update or a deadlock under load.  This module walks the ASTs
and checks the contracts structurally:

**(a) accounting** — :class:`Fabric` counters are caller-thread-owned:
no function reachable from an executor-``submit`` root may mutate one,
and thread roots (daemon loops) may only touch the counters a single
daemon owns (``DAEMON_OWNED_COUNTERS``).

**(b) lock-guard** — attributes a class registers in its
``_GUARDED_BY`` dict may only be read or written inside a lexical
``with <base>.<lock>:`` over the registered lock.

**(c) lock-blocking** — no ``time.sleep``, fabric transfer
(``_client_xfer``), replication hop (``_hop_put``), retry loop, or OSD
RPC inside a body holding any discovered ``threading.Lock``.

**(d) write-path** — every function that rewrites OSD blob/xattr state
must reach cache invalidation in its call closure, and every user of
``_next_version`` must reach both ``content_digest`` stamping and
invalidation (the version/digest/cache triple moves together).

The call graph is intentionally an under-approximation: calls on
receivers whose type cannot be resolved from ``VAR_TYPES``/``self``
are ignored rather than guessed, and only one level of
callable-parameter passthrough is followed (``f(cb)`` where ``f``
submits its parameter).  That keeps findings precise — each one names
a concrete root-to-mutation path — at the cost of not *proving*
absence; the dynamic half (``repro.analysis.lockcheck``) covers the
runtime side.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from repro.analysis.base import Finding

# --------------------------------------------------------------------------
# resolution tables (the repo's naming conventions, made explicit)
# --------------------------------------------------------------------------

# variable/attribute name -> class, for receiver typing.  These are the
# repo's stable idioms; an unresolved receiver is *skipped*, so a wrong
# entry here produces false findings, not silence — keep it short.
VAR_TYPES: dict[str, str] = {
    "osd": "OSD",
    "entry": "OSD",
    "store": "ObjectStore",
    "cache": "ResultCache",
    "session": "ScanSession",
    "maintenance": "MaintenancePlane",
    "w": "SkyhookWorker",
}

# attribute names whose subscript yields an OSD (``self.osds[osd_id]``)
OSD_MAPS = frozenset({"osds"})
# method names returning an OSD (``self._osd(osd_id)``)
OSD_GETTERS = frozenset({"_osd"})

# Fabric counters a maintenance daemon owns exclusively (exactly one
# writer thread each) — the only counters a thread root may reach.
DAEMON_OWNED_COUNTERS = frozenset({
    "scrub_bytes", "corruptions_detected", "heals", "recovery_bytes",
    "compactions", "compaction_bytes", "rebalance_bytes",
    "gc_objects", "gc_bytes",
})

# pass (c): calls that block, by shape
BLOCKING_ATTRS = frozenset({"_client_xfer", "_hop_put", "_replicate",
                            "_osd_call", "_osd_call_quiet"})
OSD_RPCS = frozenset({"get", "put", "put_batch", "exec_cls",
                      "exec_cls_batch", "compact_merge", "stat",
                      "get_xattrs", "list_xattrs"})

# pass (d): blob/xattr stores and the invalidation/stamping calls
OSD_STATE_ATTRS = frozenset({"data", "xattrs"})
INVALIDATORS = frozenset({"invalidate", "invalidate_cached"})
DIGEST_FNS = frozenset({"content_digest"})

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


# --------------------------------------------------------------------------
# index: functions, classes, locks, guards
# --------------------------------------------------------------------------


class FuncInfo:
    """One function/method/nested-def/lambda and its analysis scope."""

    def __init__(self, node, qualname: str, file: str, module: str,
                 cls_name: str | None, parent: "FuncInfo | None"):
        self.node = node
        self.qualname = qualname
        self.file = file
        self.module = module
        self.cls_name = cls_name      # owning class for methods, else the
        #                               enclosing method's class for nested
        self.parent = parent
        self.children: dict[str, FuncInfo] = {}
        self.lambdas: dict[int, FuncInfo] = {}   # id(node) -> info

    @property
    def line(self) -> int:
        return self.node.lineno

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    def scope(self) -> Iterator[ast.AST]:
        """All descendant nodes, not descending into nested defs (their
        bodies are separate :class:`FuncInfo` scopes)."""
        todo = list(ast.iter_child_nodes(self.node))
        while todo:
            n = todo.pop()
            yield n
            if not isinstance(n, _SCOPE_NODES):
                todo.extend(ast.iter_child_nodes(n))

    def __repr__(self):
        return f"<func {self.qualname}>"


class ClassInfo:
    def __init__(self, name: str, file: str):
        self.name = name
        self.file = file
        self.methods: dict[str, FuncInfo] = {}
        self.guarded: dict[str, str] = {}   # attr -> lock attr
        self.locks: set[str] = set()        # threading.Lock() attrs


class Codebase:
    """Parsed view of the checked packages, plus the call graph."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self.functions: list[FuncInfo] = []
        self.classes: dict[str, ClassInfo] = {}
        self.module_funcs: dict[str, dict[str, FuncInfo]] = {}
        self.fabric_counters: set[str] = set()
        for rel in ("src/repro/core", "src/repro/serve"):
            d = self.root / rel
            for path in sorted(d.glob("*.py")):
                self._index_module(path)
        self._edges: dict[int, set[FuncInfo]] = {}   # id(F) -> targets
        # (func, param name) pairs whose value gets pool-submitted
        self.submit_params: set[tuple[FuncInfo, str]] = set()

    # ------------------------------------------------------------ indexing
    def _index_module(self, path: Path) -> None:
        rel = str(path.relative_to(self.root))
        module = path.stem
        tree = ast.parse(path.read_text(), filename=rel)
        self.module_funcs.setdefault(module, {})
        for stmt in tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                f = self._add_func(stmt, stmt.name, rel, module,
                                   None, None)
                self.module_funcs[module][stmt.name] = f
            elif isinstance(stmt, ast.ClassDef):
                self._index_class(stmt, rel, module)

    def _index_class(self, node: ast.ClassDef, rel: str,
                     module: str) -> None:
        ci = self.classes.setdefault(node.name, ClassInfo(node.name, rel))
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                f = self._add_func(stmt, f"{node.name}.{stmt.name}",
                                   rel, module, node.name, None)
                ci.methods[stmt.name] = f
                if stmt.name == "__init__":
                    self._scan_init_locks(ci, f)
            elif isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if (isinstance(tgt, ast.Name)
                            and tgt.id == "_GUARDED_BY"
                            and isinstance(stmt.value, ast.Dict)):
                        for k, v in zip(stmt.value.keys,
                                        stmt.value.values):
                            if (isinstance(k, ast.Constant)
                                    and isinstance(v, ast.Constant)):
                                ci.guarded[k.value] = v.value
        if node.name == "Fabric":
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    self.fabric_counters.add(stmt.target.id)

    def _scan_init_locks(self, ci: ClassInfo, init: FuncInfo) -> None:
        """``self.X = threading.Lock()`` in ``__init__`` registers X as
        a lock attribute of the class (pass-c discovery)."""
        for n in init.scope():
            if not isinstance(n, ast.Assign):
                continue
            v = n.value
            is_lock = (isinstance(v, ast.Call)
                       and isinstance(v.func, ast.Attribute)
                       and v.func.attr in ("Lock", "RLock")
                       and isinstance(v.func.value, ast.Name)
                       and v.func.value.id == "threading")
            if not is_lock:
                continue
            for tgt in n.targets:
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    ci.locks.add(tgt.attr)

    def _add_func(self, node, qualname: str, rel: str, module: str,
                  cls_name: str | None,
                  parent: FuncInfo | None) -> FuncInfo:
        f = FuncInfo(node, qualname, rel, module, cls_name, parent)
        self.functions.append(f)
        # register nested defs and lambdas as child scopes
        for n in f.scope():
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child = self._add_func(n, f"{qualname}.{n.name}", rel,
                                       module, cls_name, f)
                f.children[n.name] = child
            elif isinstance(n, ast.Lambda):
                child = self._add_func(n, f"{qualname}.<lambda>", rel,
                                       module, cls_name, f)
                f.lambdas[id(n)] = child
        return f

    # ------------------------------------------------------------ typing
    def type_of(self, node: ast.AST, func: FuncInfo) -> str | None:
        """The class name of an expression's value, or None.  Resolves
        the repo's idioms only — anything else is *unknown*, never
        guessed."""
        if isinstance(node, ast.Name):
            if node.id == "self" and func.cls_name:
                return func.cls_name
            return VAR_TYPES.get(node.id)
        if isinstance(node, ast.Attribute):
            return VAR_TYPES.get(node.attr)
        if isinstance(node, ast.Subscript):
            base = node.value
            if isinstance(base, ast.Attribute) and base.attr in OSD_MAPS:
                return "OSD"
            if isinstance(base, ast.Name) and base.id in OSD_MAPS:
                return "OSD"
            return None
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in OSD_GETTERS:
                return "OSD"
            if isinstance(fn, ast.Name) and fn.id in self.classes:
                return fn.id       # constructor call
            return None
        return None

    def resolve(self, node: ast.AST,
                func: FuncInfo) -> FuncInfo | None:
        """The FuncInfo a callable expression refers to, or None."""
        if isinstance(node, ast.Lambda):
            g: FuncInfo | None = func
            while g is not None:
                if id(node) in g.lambdas:
                    return g.lambdas[id(node)]
                g = g.parent
            return None
        if isinstance(node, ast.Name):
            g = func
            while g is not None:
                if node.id in g.children:
                    return g.children[node.id]
                g = g.parent
            return self.module_funcs.get(func.module, {}).get(node.id)
        if isinstance(node, ast.Attribute):
            t = self.type_of(node.value, func)
            if t in self.classes:
                return self.classes[t].methods.get(node.attr)
        return None

    # ------------------------------------------------------------ call graph
    def edges(self, func: FuncInfo) -> set[FuncInfo]:
        """Direct callees of ``func``: call targets plus any resolvable
        function reference passed as a call argument (callback
        capture — a captured callable is assumed to run on the
        capturing side's thread)."""
        cached = self._edges.get(id(func))
        if cached is not None:
            return cached
        out: set[FuncInfo] = set()
        for n in func.scope():
            if not isinstance(n, ast.Call):
                continue
            tgt = self.resolve(n.func, func)
            if tgt is not None:
                out.add(tgt)
            for a in list(n.args) + [k.value for k in n.keywords]:
                cb = self.resolve(a, func)
                if cb is not None:
                    out.add(cb)
        self._edges[id(func)] = out
        return out

    def closure(self, root: FuncInfo) -> set[FuncInfo]:
        seen = {root}
        todo = [root]
        while todo:
            f = todo.pop()
            for g in self.edges(f):
                if g not in seen:
                    seen.add(g)
                    todo.append(g)
        return seen

    # ------------------------------------------------------------ guards
    def guard_for(self, cls: str | None,
                  attr: str) -> str | None:
        if cls is None:
            return None
        ci = self.classes.get(cls)
        return ci.guarded.get(attr) if ci else None

    def all_lock_attrs(self) -> set[str]:
        out: set[str] = set()
        for ci in self.classes.values():
            out |= ci.locks
        return out


# --------------------------------------------------------------------------
# pass (a): accounting discipline
# --------------------------------------------------------------------------


def _fabric_mutations(cb: Codebase,
                      f: FuncInfo) -> list[tuple[str, int]]:
    """``(counter, line)`` for each Fabric-counter mutation in ``f``.

    A mutation is an (Aug)Assign whose target is ``<fabric>.<counter>``
    where ``<fabric>`` is an attribute named ``fabric``, a local alias
    assigned from one, or ``self`` inside the Fabric class itself.
    """
    aliases: set[str] = set()
    for n in f.scope():
        if (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and isinstance(n.value, ast.Attribute)
                and n.value.attr == "fabric"):
            aliases.add(n.targets[0].id)

    def is_fabric(base: ast.AST) -> bool:
        if isinstance(base, ast.Attribute) and base.attr == "fabric":
            return True
        if isinstance(base, ast.Name):
            if base.id in aliases:
                return True
            if base.id == "self" and f.cls_name == "Fabric":
                return True
        return False

    out: list[tuple[str, int]] = []
    for n in f.scope():
        targets: list[ast.AST] = []
        if isinstance(n, ast.AugAssign):
            targets = [n.target]
        elif isinstance(n, ast.Assign):
            targets = list(n.targets)
        for t in targets:
            if (isinstance(t, ast.Attribute)
                    and t.attr in cb.fabric_counters
                    and is_fabric(t.value)):
                out.append((t.attr, t.lineno))
    return out


def _collect_roots(cb: Codebase) -> dict[FuncInfo, set[str]]:
    """Off-caller-thread entry points: functions handed to an executor
    (``kind="submit"``) or to ``threading.Thread`` (``kind="thread"``).

    Thread-creating functions also contribute every ``self.<method>``
    reference they make (daemon loops receive their step functions via
    data structures — ``steps = {"scrub": self.scrub_step, ...}`` —
    which a pure call-walk would miss).
    """
    roots: dict[FuncInfo, set[str]] = {}

    def add(f: FuncInfo | None, kind: str) -> None:
        if f is not None:
            roots.setdefault(f, set()).add(kind)

    for f in cb.functions:
        makes_thread = False
        for n in f.scope():
            if not isinstance(n, ast.Call):
                continue
            fn = n.func
            if isinstance(fn, ast.Attribute) and fn.attr == "submit":
                if n.args:
                    a0 = n.args[0]
                    add(cb.resolve(a0, f), "submit")
                    if (isinstance(a0, ast.Name)
                            and cb.resolve(a0, f) is None):
                        cb.submit_params.add((f, a0.id))
            is_thread_ctor = (
                (isinstance(fn, ast.Attribute) and fn.attr == "Thread")
                or (isinstance(fn, ast.Name) and fn.id == "Thread"))
            if is_thread_ctor:
                makes_thread = True
                for k in n.keywords:
                    if k.arg == "target":
                        add(cb.resolve(k.value, f), "thread")
        if makes_thread and f.cls_name:
            ci = cb.classes.get(f.cls_name)
            for n in f.scope():
                if (isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id == "self" and ci
                        and n.attr in ci.methods):
                    add(ci.methods[n.attr], "thread")

    # one level of callable-parameter passthrough: if g submits its
    # parameter p, every resolvable argument bound to p at a call site
    # of g is itself a submit root
    if cb.submit_params:
        by_func: dict[int, tuple[FuncInfo, set[str]]] = {}
        for g, pname in cb.submit_params:
            by_func.setdefault(id(g), (g, set()))[1].add(pname)
        for f in cb.functions:
            for n in f.scope():
                if not isinstance(n, ast.Call):
                    continue
                g = cb.resolve(n.func, f)
                if g is None or id(g) not in by_func:
                    continue
                _, pnames = by_func[id(g)]
                params = [a.arg for a in g.node.args.args]
                offset = 1 if (params and params[0] == "self"
                               and isinstance(n.func, ast.Attribute)) \
                    else 0
                for i, a in enumerate(n.args):
                    if i + offset < len(params) \
                            and params[i + offset] in pnames:
                        add(cb.resolve(a, f), "submit")
                for k in n.keywords:
                    if k.arg in pnames:
                        add(cb.resolve(k.value, f), "submit")
    return roots


def check_accounting(cb: Codebase) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[tuple[str, str, str, str]] = set()
    for root, kinds in _collect_roots(cb).items():
        cl = cb.closure(root)
        for kind in sorted(kinds):
            for f in cl:
                for counter, line in _fabric_mutations(cb, f):
                    if kind == "thread" \
                            and counter in DAEMON_OWNED_COUNTERS:
                        continue
                    k = (root.qualname, f.qualname, counter, kind)
                    if k in seen:
                        continue
                    seen.add(k)
                    findings.append(Finding(
                        "accounting", root.file, root.line,
                        root.qualname,
                        f"Fabric.{counter} mutated at {f.file}:{line} "
                        f"({f.qualname}), reachable from this "
                        f"{kind} root — counters are caller-thread-"
                        f"owned"))
    return findings


# --------------------------------------------------------------------------
# passes (b) + (c): lock discipline / blocking while locked
# --------------------------------------------------------------------------


def _walk_with_locks(cb: Codebase, f: FuncInfo):
    """Yield ``(node, held)`` for every node in ``f``'s scope, where
    ``held`` is the frozenset of lock expressions (unparsed, e.g.
    ``"osd.lock"``) lexically held at that node."""
    lock_attrs = cb.all_lock_attrs()

    def rec(children, held: frozenset[str]):
        for child in children:
            if isinstance(child, _SCOPE_NODES):
                continue
            yield child, held
            if isinstance(child, ast.With):
                inner = set(held)
                for item in child.items:
                    e = item.context_expr
                    if (isinstance(e, ast.Attribute)
                            and e.attr in lock_attrs):
                        inner.add(ast.unparse(e))
                    # the with-items themselves evaluate unlocked
                    yield from rec(ast.iter_child_nodes(item), held)
                yield from rec(child.body, frozenset(inner))
            else:
                yield from rec(ast.iter_child_nodes(child), held)

    yield from rec(ast.iter_child_nodes(f.node), frozenset())


def check_lock_guard(cb: Codebase) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[tuple[str, str]] = set()
    for f in cb.functions:
        for node, held in _walk_with_locks(cb, f):
            if not isinstance(node, ast.Attribute):
                continue
            t = cb.type_of(node.value, f)
            lock = cb.guard_for(t, node.attr)
            if lock is None:
                continue
            if (f.name == "__init__" and f.cls_name == t
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                continue    # construction happens-before sharing
            needed = f"{ast.unparse(node.value)}.{lock}"
            if needed in held:
                continue
            k = (f.qualname, node.attr)
            if k in seen:
                continue
            seen.add(k)
            findings.append(Finding(
                "lock-guard", f.file, node.lineno, f.qualname,
                f"{t}.{node.attr} accessed without holding "
                f"{needed} (declared in {t}._GUARDED_BY)"))
    return findings


def check_lock_blocking(cb: Codebase) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[tuple[str, str]] = set()
    for f in cb.functions:
        for node, held in _walk_with_locks(cb, f):
            if not held or not isinstance(node, ast.Call):
                continue
            fn = node.func
            what = None
            if isinstance(fn, ast.Attribute):
                if (fn.attr == "sleep"
                        and isinstance(fn.value, ast.Name)
                        and fn.value.id == "time"):
                    what = "time.sleep"
                elif fn.attr in BLOCKING_ATTRS:
                    what = fn.attr
                elif (fn.attr in OSD_RPCS
                      and cb.type_of(fn.value, f) == "OSD"):
                    what = f"OSD.{fn.attr} RPC"
            if what is None:
                continue
            k = (f.qualname, what)
            if k in seen:
                continue
            seen.add(k)
            findings.append(Finding(
                "lock-blocking", f.file, node.lineno, f.qualname,
                f"{what} called while holding "
                f"{', '.join(sorted(held))}"))
    return findings


# --------------------------------------------------------------------------
# pass (d): write-path completeness
# --------------------------------------------------------------------------


def _writes_osd_state(cb: Codebase, f: FuncInfo) -> int | None:
    """Line of the first blob/xattr rewrite in ``f``, or None."""

    def osd_state(node: ast.AST) -> bool:
        return (isinstance(node, ast.Attribute)
                and node.attr in OSD_STATE_ATTRS
                and cb.type_of(node.value, f) == "OSD")

    for n in f.scope():
        targets: list[ast.AST] = []
        if isinstance(n, (ast.Assign, ast.AugAssign)):
            targets = n.targets if isinstance(n, ast.Assign) \
                else [n.target]
        elif isinstance(n, ast.Delete):
            targets = list(n.targets)
        for t in targets:
            if isinstance(t, ast.Subscript) and osd_state(t.value):
                return t.lineno
        if (isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr in ("pop", "clear", "update")
                and osd_state(n.func.value)):
            return n.lineno
    return None


def _closure_calls(cb: Codebase, root: FuncInfo,
                   names: frozenset[str]) -> bool:
    """Does any function in ``root``'s call closure call one of
    ``names`` (matched by bare name or attribute name)?"""
    for f in cb.closure(root):
        for n in f.scope():
            if not isinstance(n, ast.Call):
                continue
            fn = n.func
            if isinstance(fn, ast.Name) and fn.id in names:
                return True
            if isinstance(fn, ast.Attribute) and fn.attr in names:
                return True
    return False


def check_write_path(cb: Codebase) -> list[Finding]:
    findings: list[Finding] = []
    for f in cb.functions:
        if f.name == "__init__":
            continue
        # (d1) raw blob/xattr rewrite must reach invalidation
        line = _writes_osd_state(cb, f)
        if line is not None \
                and not _closure_calls(cb, f, INVALIDATORS):
            findings.append(Finding(
                "write-path", f.file, line, f.qualname,
                "rewrites OSD blob/xattr state but never reaches "
                "cache invalidation (invalidate/invalidate_cached) "
                "in its call closure"))
        # (d2) version allocation must reach digest stamping AND
        # invalidation — the version/digest/cache triple is atomic
        calls_next_version = any(
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr == "_next_version"
            for n in f.scope())
        if not calls_next_version or f.name == "_next_version":
            continue
        missing = []
        if not _closure_calls(cb, f, DIGEST_FNS):
            missing.append("content_digest stamping")
        if not _closure_calls(cb, f, INVALIDATORS):
            missing.append("cache invalidation")
        if missing:
            findings.append(Finding(
                "write-path", f.file, f.line, f.qualname,
                f"allocates a version (_next_version) but its call "
                f"closure never reaches {' or '.join(missing)}"))
    return findings


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def analyze(root: Path) -> list[Finding]:
    """Run all AST passes over the repo rooted at ``root``."""
    cb = Codebase(root)
    findings: list[Finding] = []
    findings += check_accounting(cb)
    findings += check_lock_guard(cb)
    findings += check_lock_blocking(cb)
    findings += check_write_path(cb)
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings
