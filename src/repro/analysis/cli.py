"""``python -m repro.analysis`` — run every invariant pass and report.

Exit status 0 means: zero unsuppressed findings AND zero stale
suppressions.  The committed suppression file
(``src/repro/analysis/suppressions.txt``) is the complete, justified
list of intentional contract exceptions — anything else fails CI.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import invariants, registry
from repro.analysis.base import (SuppressionError, apply_suppressions,
                                 load_suppressions)

DEFAULT_SUPPRESSIONS = Path(__file__).with_name("suppressions.txt")


def _find_root(start: Path) -> Path:
    """The repo root: the nearest ancestor containing src/repro."""
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(f"no src/repro found above {start}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant linter for the storage planes")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: walk up from this file)")
    ap.add_argument("--suppressions", type=Path,
                    default=DEFAULT_SUPPRESSIONS,
                    help="suppression file (default: the committed one)")
    ap.add_argument("--list-suppressed", action="store_true",
                    help="also print the suppressed findings")
    args = ap.parse_args(argv)

    root = args.root or _find_root(Path(__file__).parent)
    findings = invariants.analyze(root) + registry.check_registry()
    try:
        supps = load_suppressions(args.suppressions)
    except SuppressionError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    active, quiet, unused = apply_suppressions(findings, supps)

    for f in active:
        print(f.render())
    if args.list_suppressed:
        for f in quiet:
            print(f"(suppressed) {f.render()}")
    for s in unused:
        print(f"{args.suppressions.name}:{s.lineno}: stale suppression "
              f"(matched nothing): {s.key}")
    print(f"repro.analysis: {len(active)} finding(s), "
          f"{len(quiet)} suppressed, {len(unused)} stale "
          f"suppression(s)")
    return 1 if (active or unused) else 0
