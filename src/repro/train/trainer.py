"""Training loop: object-store data path, checkpoint/restart, straggler
detection, failure injection — the fault-tolerance layer of the system.

Everything stateful lives in the object store (checkpoints AND the data
order, which is a pure function of (seed, step)), so a restart from any
committed step is bit-deterministic: same params, same optimizer moments,
same next batch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step, restore
from repro.core.store import ObjectStore
from repro.data.fused_ingest import fused_batch
from repro.data.pipeline import ObjectDataLoader
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ``factor`` x EWMA.

    On a real pod the flag triggers hedged reads / slot replacement; here
    it feeds the loader's hedging and the trainer's log.
    """

    alpha: float = 0.1
    factor: float = 2.0
    ewma_s: float | None = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma_s is None:
            self.ewma_s = dt
            return False
        slow = dt > self.factor * self.ewma_s
        self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * dt
        self.flagged += int(slow)
        return slow


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_keep: int = 2
    ckpt_tag: str = "train"
    log_every: int = 10
    packed_ingest: bool = False


class Trainer:
    def __init__(self, model, loader: ObjectDataLoader,
                 store: ObjectStore, *,
                 opt: OptConfig = OptConfig(),
                 cfg: TrainerConfig = TrainerConfig(),
                 step_fn: Callable | None = None,
                 log: Callable[[str], None] = print):
        self.model = model
        self.loader = loader
        self.store = store
        self.cfg = cfg
        self.opt = opt
        self.log = log
        base = step_fn or make_train_step(model, opt)
        if cfg.packed_ingest:
            base_inner = base
            base = lambda s, b: base_inner(  # noqa: E731
                s, fused_batch(b["tokens_packed"]))
        self.train_step = jax.jit(base, donate_argnums=(0,))
        self.ckpts = CheckpointManager(
            store, tag=cfg.ckpt_tag, every_steps=cfg.ckpt_every,
            keep=cfg.ckpt_keep)
        self.straggler = StragglerMonitor()
        self.history: list[dict] = []

    # ------------------------------------------------------------ state
    def init_or_restore(self, seed: int = 0) -> tuple[Any, int]:
        """Fresh state, or the latest committed checkpoint if one exists."""
        state = init_train_state(self.model, jax.random.PRNGKey(seed),
                                 self.model.cfg.opt_dtype)
        step = latest_step(self.store, tag=self.cfg.ckpt_tag)
        if step is None:
            return state, 0
        like = jax.tree.map(np.asarray, state)
        restored, manifest = restore(self.store, like, step=step,
                                     tag=self.cfg.ckpt_tag)
        self.log(f"[trainer] restored step {step} "
                 f"(loader resumes at {manifest['extra'].get('loader_step')})")
        state = jax.tree.map(jax.numpy.asarray, restored)
        return state, step

    # ------------------------------------------------------------ loop
    def run(self, state=None, *, start_step: int | None = None,
            on_step: Callable[[int], None] | None = None) -> Any:
        if state is None:
            state, start = self.init_or_restore()
            start_step = start if start_step is None else start_step
        start_step = start_step or 0
        # exact reposition (data order is a pure function of step); the
        # consume below rides the loader's prefetch queue, so storage
        # fetches — windowed across steps when window_steps > 1 —
        # overlap step compute instead of serializing ahead of it
        self.loader.seek(start_step)

        for step in range(start_step, self.cfg.total_steps):
            t0 = time.perf_counter()
            batch = next(self.loader)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = self.train_step(state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.perf_counter() - t0
            slow = self.straggler.observe(dt)
            rec = dict(metrics, step=step + 1, wall_s=dt, straggler=slow)
            self.history.append(rec)
            if (step + 1) % self.cfg.log_every == 0 or slow:
                self.log(f"[trainer] step {step + 1} "
                         f"loss={metrics['loss']:.4f} "
                         f"{dt * 1000:.0f}ms" + (" STRAGGLER" if slow else ""))
            self.ckpts.maybe_save(state, step + 1,
                                  extra={"loader_step": step + 1})
            if on_step is not None:
                on_step(step + 1)
        self.ckpts.wait()
        return state
