"""Train / eval step builders."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import (
    OptConfig,
    abstract_opt_state,
    adamw_update,
    init_opt_state,
    opt_state_specs,
)


def make_train_step(model, opt_cfg: OptConfig, *, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split on dim 0 and scanned sequentially, so live activations shrink
    by the factor while the math stays identical (fp32 accumulators).
    Required for SSM/hybrid multi-pod cells where sequence scans keep
    activations batch-proportional (DESIGN.md §5).
    """

    def grad_fn(params, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state["params"], batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches,
                                    x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def body(acc, b):
                gsum, loss_sum, msum = acc
                (loss, metrics), g = grad_fn(state["params"], b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                msum = jax.tree.map(lambda a, x: a + x, msum, metrics)
                return (gsum, loss_sum + loss, msum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32),
                state["params"])
            m0 = jax.tree.map(lambda _: jnp.zeros((), jnp.float32),
                              jax.eval_shape(
                                  lambda: grad_fn(state["params"],
                                                  jax.tree.map(
                                                      lambda x: x[0], mb)
                                                  )[0][1]))
            (gsum, loss_sum, msum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32), m0), mb)
            k = float(microbatches)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = loss_sum / k
            metrics = jax.tree.map(lambda m: m / k, msum)

        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, grads, state["params"], state["opt"])
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm,
                        "step": new_opt["step"]})
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return dict(metrics, loss=loss)
    return eval_step


def init_train_state(model, key, opt_dtype=jnp.float32):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_dtype)}


def abstract_train_state(model, opt_dtype=jnp.float32):
    """(state ShapeDtypeStructs, state PartitionSpecs) — no allocation."""
    shapes, specs = model.abstract()
    state_shapes = {"params": shapes,
                    "opt": abstract_opt_state(shapes, opt_dtype)}
    state_specs = {"params": specs, "opt": opt_state_specs(specs)}
    return state_shapes, state_specs


def metric_specs(metrics_tree: Any):
    return jax.tree.map(lambda _: P(), metrics_tree)
