"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Optimizer moments are kept in ``cfg.opt_dtype`` (fp32 by default) with the
same sharding as their parameters (ZeRO: the optimizer state is fully
sharded because the params are).  Params may be bf16 (large archs): the
update is computed in fp32 and cast back — the stochastic-rounding caveat
is recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptConfig) -> Callable[[jax.Array], jax.Array]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return f


def init_opt_state(params, opt_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, opt_dtype)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(param_shapes, opt_dtype=jnp.float32):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, opt_dtype)  # noqa: E731
    return {"m": jax.tree.map(sds, param_shapes),
            "v": jax.tree.map(sds, param_shapes),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "step": P()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptConfig, grads, params, opt_state):
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg)(step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m1 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v1 / (1 - b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        # decay only matrices (norms/biases are 1-D)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + wd * pf)
        return pf.astype(p.dtype), m1.astype(m.dtype), v1.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(g, p, m, v) for g, p, m, v
           in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
