"""SkyhookDM-style driver/worker query engine (paper §4.2, Fig. 3/4).

Workflow (Fig. 4): client submits a Query -> the Driver generates object
names + sub-queries -> Workers (the Dask-worker stand-ins) forward
sub-queries to the storage extensions (``store.exec``), post-process
partials if needed, and return them -> the Driver aggregates and answers.

The Driver/Worker split matters beyond parallelism: workers can run
*non-pushdownable* post-processing near the storage tier (e.g. the final
combine of an approximate quantile), which is exactly the paper's
"Workers could further conduct some complicated computations against the
results returned by Skyhook-Extensions".
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any

import numpy as np

from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.logical import concat_tables
from repro.core.partition import ObjectMap
from repro.core.store import ObjectStore
from repro.core.vol import GlobalVOL


@dataclasses.dataclass(frozen=True)
class Query:
    """A declarative query against one mapped dataset."""

    dataset: str
    filter: tuple | None = None            # (col, cmp, value)
    projection: tuple[str, ...] | None = None
    aggregate: tuple | None = None         # (fn, col); fn may be "median"
    allow_approx: bool = False

    def pipeline(self) -> list[oc.ObjOp]:
        ops: list[oc.ObjOp] = []
        if self.filter:
            col, cmp, value = self.filter
            ops.append(oc.op("filter", col=col, cmp=cmp, value=value))
        if self.projection:
            ops.append(oc.op("project", cols=list(self.projection)))
        if self.aggregate:
            fn, col = self.aggregate
            if fn == "median":
                ops.append(oc.op("median", col=col))
            else:
                ops.append(oc.op("agg", col=col, fn=fn))
        return ops


@dataclasses.dataclass
class QueryStats:
    wall_s: float
    objects_touched: int
    objects_pruned: int
    client_rx_bytes: int
    storage_local_bytes: int
    pushdown: bool
    result_rows: int | None = None
    fabric_ops: int = 0        # client<->OSD round trips the query cost

    @property
    def selectivity_gain(self) -> float:
        """How many storage-side bytes were scanned per byte returned."""
        return self.storage_local_bytes / max(self.client_rx_bytes, 1)


class SkyhookWorker:
    """Executes sub-queries against a set of objects via the storage
    extensions; optionally post-processes before returning partials."""

    def __init__(self, store: ObjectStore, worker_id: int):
        self.store = store
        self.worker_id = worker_id

    def run(self, names: list[str], ops: list[oc.ObjOp],
            combine: bool = False) -> list[Any]:
        """Forward the shard as batched per-OSD objclass requests (one
        round trip per OSD this shard touches, not one per object).
        With ``combine`` the OSDs fold their partials server-side and
        the worker relays one partial per OSD request."""
        if combine:
            return self.store.exec_combine(names, ops)
        return self.store.exec_batch(names, ops)


class SkyhookDriver:
    """Schedules sub-queries over workers, combines partials."""

    def __init__(self, vol: GlobalVOL, n_workers: int = 4):
        self.vol = vol
        self.store = vol.store
        self.workers = [SkyhookWorker(self.store, i)
                        for i in range(n_workers)]
        # persistent dispatch pool (mirrors ObjectStore._pool): no
        # per-query executor churn on the hot path
        self._pool = ThreadPoolExecutor(max_workers=n_workers,
                                        thread_name_prefix="skyhook-drv")

    def close(self) -> None:
        self._pool.shutdown(wait=False)

    def __del__(self):
        try:
            self._pool.shutdown(wait=False)
        except Exception:
            pass

    # ------------------------------------------------------------ execute
    def execute(self, q: Query) -> tuple[Any, QueryStats]:
        omap = self.vol.open(q.dataset)
        ops = q.pipeline()
        t0 = time.perf_counter()
        before = self.store.fabric.snapshot()
        result, vstats = self._dispatch(omap, ops, q)
        after = self.store.fabric.snapshot()
        rows = None
        if isinstance(result, dict) and result:
            rows = len(next(iter(result.values())))
        stats = QueryStats(
            wall_s=time.perf_counter() - t0,
            objects_touched=vstats["objects_touched"],
            objects_pruned=vstats["objects_pruned"],
            client_rx_bytes=after["client_rx"] - before["client_rx"],
            storage_local_bytes=after["local_bytes"] - before["local_bytes"],
            pushdown=vstats["pushdown"],
            result_rows=rows,
            fabric_ops=after["ops"] - before["ops"],
        )
        return result, stats

    def _dispatch(self, omap: ObjectMap, ops: list[oc.ObjOp],
                  q: Query) -> tuple[Any, dict]:
        """Shard object list over workers (Fig. 4's scheduler role), then
        combine exactly as GlobalVOL.query would."""
        plan = self.vol.plan(omap, ops)
        names = [n for n, _ in plan.sub_requests]
        # shard by primary OSD (not round-robin) so each OSD's objects
        # stay in ONE worker's batch: the whole query costs <= K
        # batched requests for K OSDs regardless of worker count
        by_osd: dict[str, list[str]] = {}
        for n in names:
            by_osd.setdefault(self.store.cluster.primary(n), []).append(n)
        shards: list[list[str]] = [[] for _ in self.workers]
        for j, (_, group) in enumerate(sorted(by_osd.items())):
            shards[j % len(self.workers)].extend(group)

        rewritten = False
        if ops and ops[-1].name == "median" and q.allow_approx:
            col = ops[-1].params["col"]
            lo, hi = self.vol._column_bounds(omap, col)
            ops = ops[:-1] + [oc.op("quantile_sketch", col=col,
                                    lo=lo, hi=hi)]
            rewritten = True

        tail = oc.get_impl(ops[-1].name) if ops else None
        holistic = ops and not tail.table_out and tail.combine is None

        if holistic:  # gather projected inputs through workers
            col = ops[-1].params["col"]
            sub_ops = [o for o in ops[:-1]] + [oc.op("project", cols=[col])]
        else:
            sub_ops = ops
        # decomposable aggregate tails combine per OSD: each worker's
        # shard returns one partial per OSD it touches, O(K) client_rx
        combine = bool(sub_ops) and oc.pipeline_mergeable(sub_ops)

        if self.store.io_simulated():  # workers overlap simulated I/O
            parts_nested = list(self._pool.map(
                lambda wn: wn[0].run(wn[1], sub_ops, combine),
                zip(self.workers, shards)))
        else:  # compute-bound: threads only add GIL contention
            parts_nested = [w.run(s, sub_ops, combine)
                            for w, s in zip(self.workers, shards)]
        partials = [p for ps in parts_nested for p in ps]

        if not ops or tail.table_out:
            result = concat_tables([fmt.decode_block(b) for b in partials])
        elif holistic:
            col = ops[-1].params["col"]
            tabs = [fmt.decode_block(b) for b in partials]
            result = oc.median_exact(
                [{col: t[col].ravel()} for t in tabs], col)
        else:
            result = oc.combine_partials(ops, partials)

        return result, {"objects_touched": len(names),
                        "objects_pruned": len(plan.pruned),
                        "pushdown": plan.pushdown and not holistic,
                        "approx_rewrite": rewritten}

    # ------------------------------------------------------------ baseline
    def execute_client_side(self, q: Query) -> tuple[Any, QueryStats]:
        """The no-pushdown baseline: fetch every (non-pruned) object's full
        bytes to the client and evaluate the pipeline locally."""
        omap = self.vol.open(q.dataset)
        ops = q.pipeline()
        t0 = time.perf_counter()
        before = self.store.fabric.snapshot()
        tables = []
        for extent in omap:
            blob = self.store.get(extent.name)
            tables.append(fmt.decode_block(blob))
        table = concat_tables(tables)
        result: Any = table
        for o in ops:
            impl = oc.get_impl(o.name)
            if o.name == "median":
                result = float(np.median(np.asarray(
                    result[o.params["col"]]).ravel()))
            elif not impl.table_out:
                result = impl.combine([impl.local(result, **o.params)],
                                      **o.params)
            else:
                result = impl.local(result, **o.params)
        after = self.store.fabric.snapshot()
        rows = None
        if isinstance(result, dict) and result:
            rows = len(next(iter(result.values())))
        stats = QueryStats(
            wall_s=time.perf_counter() - t0,
            objects_touched=omap.n_objects, objects_pruned=0,
            client_rx_bytes=after["client_rx"] - before["client_rx"],
            storage_local_bytes=after["local_bytes"] - before["local_bytes"],
            pushdown=False, result_rows=rows,
            fabric_ops=after["ops"] - before["ops"])
        return result, stats
