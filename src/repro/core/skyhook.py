"""SkyhookDM-style driver/worker scheduling over the scan engine
(paper §4.2, Fig. 3/4).

Workflow (Fig. 4): a client submits a :class:`Query` (the declarative
shim) or a :class:`~repro.core.scan.Scan` (the composable builder) ->
the Driver compiles it to ONE :class:`~repro.core.scan.PhysicalPlan`
through the shared ``ScanEngine`` -> the plan's per-OSD request shards
are scheduled over Workers, which forward them to the storage
extensions (``exec_combine`` / ``exec_concat`` / ``exec_batch``) and
relay the per-OSD partials or framed tables back -> the engine combines
and emits the unified stats.

The Driver adds SCHEDULING only.  What to push down, how to prune
(OSD-side by default — the predicates ride inside the workers' batched
requests), and how to combine are all decided by the engine at compile
time; the driver/worker layer is a transport that must preserve the
store-call semantics.  This is exactly the paper's split: "Workers
could further conduct some complicated computations against the results
returned by Skyhook-Extensions", while the planning stays global.

``execute_client_side`` is the no-pushdown baseline (full objects to
the client, pipeline evaluated locally) — also compiled and executed by
the engine, as the ``client-gather`` execution class.
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any

from repro.core import objclass as oc
from repro.core.scan import Scan
from repro.core.store import ObjectStore
from repro.core.vol import GlobalVOL


@dataclasses.dataclass(frozen=True)
class Query:
    """A declarative query against one mapped dataset — now a thin shim
    that compiles to a :class:`~repro.core.scan.Scan`.

    ``filter`` accepts one ``(col, cmp, value)`` triple or a sequence
    of them; ``filters`` is the explicit N-ary spelling.  All filters
    AND together.  ``aggregate`` accepts one ``(fn, col)`` pair or a
    sequence of pairs (compiled to one mergeable ``multi_agg`` tail);
    ``fn`` may be ``"median"`` (holistic unless ``allow_approx``).
    """

    dataset: str
    filter: tuple | None = None            # (col, cmp, value) | sequence
    projection: tuple[str, ...] | None = None
    aggregate: tuple | None = None         # (fn, col) | sequence of them
    allow_approx: bool = False
    filters: tuple = ()                    # ((col, cmp, value), ...)

    def to_scan(self) -> Scan:
        s = Scan(dataset=self.dataset)
        flts = list(_nested(self.filter)) + list(self.filters)
        for col, cmp, value in flts:
            s = s.filter(col, cmp, value)
        if self.projection:
            s = s.project(*self.projection)
        for fn, col in _nested(self.aggregate):
            s = s.median(col, approx=self.allow_approx) \
                if fn == "median" else s.agg(fn, col)
        return s

    def pipeline(self) -> list[oc.ObjOp]:
        return self.to_scan().pipeline()


def _nested(spec) -> tuple:
    """Normalize None | one tuple | sequence-of-tuples to a tuple of
    tuples (how ``Query.filter``/``aggregate`` accept one or many)."""
    if not spec:
        return ()
    if isinstance(spec[0], (tuple, list)):
        return tuple(tuple(x) for x in spec)
    return (tuple(spec),)


@dataclasses.dataclass
class QueryStats:
    """Uniform per-query stats — emitted by the ONE engine, so every
    path (vol.query, driver, client-side baseline) reports pushdown,
    pruning, and cardinality identically.  ``result_rows`` is the
    result's cardinality: table rows for table-out scans, 1 for
    scalar/aggregate results (never None for a completed query)."""

    wall_s: float
    objects_touched: int
    objects_pruned: int
    client_rx_bytes: int
    storage_local_bytes: int
    pushdown: bool
    result_rows: int | None = None
    fabric_ops: int = 0        # client<->OSD round trips the query cost
    rx_frames: int = 0         # framed responses the client parsed
    exec_class: str = ""       # scan.EXEC_* the plan compiled to
    prune: str = ""            # prune strategy the plan compiled to

    @property
    def selectivity_gain(self) -> float:
        """How many storage-side bytes were scanned per byte returned."""
        return self.storage_local_bytes / max(self.client_rx_bytes, 1)


class SkyhookWorker:
    """Executes sub-requests against a set of objects via the storage
    extensions, relaying per-OSD partials / framed tables back."""

    def __init__(self, store: ObjectStore, worker_id: int):
        self.store = store
        self.worker_id = worker_id

    def run(self, names: list[str], ops, mode: str = "batch",
            predicates=None) -> Any:
        """Forward the shard as batched per-OSD objclass requests (one
        round trip per OSD this shard touches, not one per object).
        ``mode`` follows the engine's runner protocol: "combine" folds
        partials server-side, "concat" returns one framed table per
        OSD, "batch" returns per-object results.  ``predicates`` is the
        plan's filter-expression tree (or None), riding down serialized
        for OSD-side pruning."""
        if mode == "combine":
            got = self.store.exec_combine(names, ops, prune=predicates)
            return got if isinstance(got, tuple) else (got, [])
        if mode == "concat":
            return self.store.exec_concat(names, ops, prune=predicates)
        return self.store.exec_batch(names, ops)

    def run_stream(self, names: list[str], ops, predicates=None,
                   pruned_out: list | None = None):
        """Frame-streaming concat shard: an iterator of per-OSD framed
        responses, each yielded the MOMENT its OSD answers
        (``exec_concat_iter``) instead of after the whole shard — so
        the driver forwards frames at OSD granularity and one slow OSD
        in a shard no longer gates that shard's fast frames.
        ``pruned_out`` accumulates OSD-pruned names, complete once the
        iterator is exhausted."""
        return self.store.exec_concat_iter(names, ops, prune=predicates,
                                           pruned_out=pruned_out)


class SkyhookDriver:
    """Schedules a compiled plan's shards over workers; the engine does
    the planning and the combining."""

    def __init__(self, vol: GlobalVOL, n_workers: int = 4):
        self.vol = vol
        self.store = vol.store
        self.workers = [SkyhookWorker(self.store, i)
                        for i in range(n_workers)]
        # persistent dispatch pool (mirrors ObjectStore._pool): no
        # per-query executor churn on the hot path
        self._pool = ThreadPoolExecutor(max_workers=n_workers,
                                        thread_name_prefix="skyhook-drv")

    def close(self) -> None:
        self._pool.shutdown(wait=False)

    def __del__(self):
        try:
            self._pool.shutdown(wait=False)
        except Exception:
            pass

    # ------------------------------------------------------------ execute
    def scan(self, dataset: str) -> Scan:
        """A fluent scan whose ``execute`` is scheduled by this driver
        (the plan executes through ``_runner``, i.e. the workers)."""
        return Scan(dataset=dataset).bind(self.vol, runner=self._runner)

    def execute(self, q: Query | Scan) -> tuple[Any, QueryStats]:
        s = q.to_scan() if isinstance(q, Query) else q
        omap = self.vol.open(s.dataset)
        t0 = time.perf_counter()
        before = self.store.fabric.snapshot()  # include compile traffic
        plan = self.vol.engine.compile(omap, s)
        result, vstats = self.vol.engine.execute(
            plan, runner=self._runner, before=before, omap=omap)
        return result, self._stats(vstats, t0)

    # ------------------------------------------------------------ baseline
    def execute_client_side(self, q: Query | Scan) -> tuple[Any, QueryStats]:
        """The no-pushdown baseline: fetch every object's full bytes to
        the client and evaluate the pipeline locally (the engine's
        ``client-gather`` execution class)."""
        s = q.to_scan() if isinstance(q, Query) else q
        omap = self.vol.open(s.dataset)
        t0 = time.perf_counter()
        before = self.store.fabric.snapshot()
        plan = self.vol.engine.compile_ops(omap, s.pipeline(),
                                           baseline=True)
        result, vstats = self.vol.engine.execute(plan, before=before)
        return result, self._stats(vstats, t0)

    # ------------------------------------------------------------ internals
    def _stats(self, vstats: dict, t0: float) -> QueryStats:
        return QueryStats(
            wall_s=time.perf_counter() - t0,
            objects_touched=vstats["objects_touched"],
            objects_pruned=vstats["objects_pruned"],
            client_rx_bytes=vstats["client_rx"],
            storage_local_bytes=vstats["local_bytes"],
            pushdown=vstats["pushdown"],
            result_rows=vstats["result_rows"],
            fabric_ops=vstats["ops"],
            rx_frames=vstats["rx_frames"],
            exec_class=vstats["exec_class"],
            prune=vstats["prune"],
        )

    def _runner(self, mode: str, names: list[str], pipelines,
                predicates=None, plan_shards: tuple = ()) -> Any:
        """The engine's runner, scheduled over workers: the plan's
        per-OSD shards (each OSD's objects stay in ONE worker's batch,
        so the whole query still costs <= K batched requests for K OSDs
        regardless of worker count) round-robin across workers, then
        shard-local results translate back to global positions."""
        shared = not pipelines or isinstance(pipelines[0], oc.ObjOp)
        if not plan_shards:  # derive placement if the plan carries none
            by_osd: dict[str, list[int]] = {}
            for i, n in enumerate(names):
                by_osd.setdefault(
                    self.store.cluster.primary(n), []).append(i)
            plan_shards = tuple(sorted(by_osd.items()))
        shards: list[list[int]] = [[] for _ in self.workers]
        for j, (_, idxs) in enumerate(plan_shards):
            shards[j % len(self.workers)].extend(idxs)

        def run_shard(pair):
            w, idxs = pair
            if not idxs:
                return idxs, ([] if mode == "batch" else ([], []))
            sub_names = [names[i] for i in idxs]
            sub_pipes = pipelines if shared \
                else [pipelines[i] for i in idxs]
            return idxs, w.run(sub_names, sub_pipes, mode=mode,
                               predicates=predicates)

        io = self.store.io_simulated()
        if mode == "batch":
            if io:  # workers overlap simulated I/O
                outs = list(self._pool.map(run_shard,
                                           zip(self.workers, shards)))
            else:  # compute-bound: threads only add GIL contention
                outs = [run_shard(p) for p in zip(self.workers, shards)]
            results: list[Any] = [None] * len(names)
            for idxs, rs in outs:
                for i, r in zip(idxs, rs):
                    results[i] = r
            return results

        # combine/concat follow the engine's LAZY runner protocol: the
        # partial/frame half streams as results land (the engine
        # decodes early results while slower OSDs are still scanning);
        # ``pruned`` fills during consumption and is complete once the
        # stream is exhausted
        pruned: list[str] = []

        if mode == "concat":
            return self._concat_stream(names, pipelines, shared,
                                       predicates, shards, io,
                                       pruned), pruned

        # combine partials feed an order-sensitive float fold and keep
        # submission order (deterministic); they are scalar-sized, so
        # there is no decode to overlap anyway
        def stream():
            if io:
                futs = [self._pool.submit(run_shard, p)
                        for p in zip(self.workers, shards)]
                for f in futs:
                    idxs, (items, pr) = f.result()
                    pruned.extend(pr)
                    yield from items
            else:
                for p in zip(self.workers, shards):
                    idxs, (items, pr) = run_shard(p)
                    pruned.extend(pr)
                    yield from items

        return stream(), pruned

    def _concat_stream(self, names, pipelines, shared, predicates,
                       shards, io, pruned):
        """Worker-level frame streaming: every per-OSD framed response
        forwards the moment it lands, translated to global positions —
        frames interleave ACROSS workers in arrival order (matching the
        store-direct ``exec_concat_iter`` overlap), not in
        shard-completion order, so one slow OSD anywhere delays only
        its own frame."""
        work = []  # (worker, global idxs) pairs with actual items
        for w, idxs in zip(self.workers, shards):
            if idxs:
                sub_pipes = pipelines if shared \
                    else [pipelines[i] for i in idxs]
                work.append((w, idxs, [names[i] for i in idxs],
                             sub_pipes))

        if not io:  # compute-bound: sequential, still frame-granular
            def stream_seq():
                for w, idxs, sub_names, sub_pipes in work:
                    local_pruned: list[str] = []
                    for local, blob, counts in w.run_stream(
                            sub_names, sub_pipes, predicates,
                            local_pruned):
                        yield (tuple(idxs[k] for k in local), blob,
                               counts)
                    pruned.extend(local_pruned)
            return stream_seq()

        # one pump per worker shard feeds a shared arrival queue; the
        # consumer (the engine, decoding frames) runs on the caller's
        # thread and drains until every pump posts its done sentinel
        q: _queue.Queue = _queue.Queue()

        def pump(w, idxs, sub_names, sub_pipes):
            local_pruned: list[str] = []
            try:
                for local, blob, counts in w.run_stream(
                        sub_names, sub_pipes, predicates, local_pruned):
                    q.put(("frame",
                           (tuple(idxs[k] for k in local), blob,
                            counts)))
            except BaseException as e:
                q.put(("error", e))
                return
            q.put(("done", local_pruned))

        futs = [self._pool.submit(pump, *item) for item in work]

        def stream_live():
            live = len(futs)
            while live:
                kind, payload = q.get()
                if kind == "error":
                    raise payload
                if kind == "done":
                    pruned.extend(payload)
                    live -= 1
                    continue
                yield payload

        return stream_live()
