"""Byte-bounded LRU result cache — the OSD half of the hot-data serve
plane.

Each :class:`~repro.core.store.OSD` owns one :class:`ResultCache`
holding decoded column tables and per-object pipeline results keyed by
``(object name, xattr version, pipeline/columns digest)``.  The
monotonic ``version`` stamped by every write path gives exact
invalidation for free: a write, heal, or compaction bumps the version,
so stale entries simply never match a current lookup — eviction is a
memory concern, never a correctness one.  Entries are derived from
digest-verified blobs at insert time and are dropped eagerly on
anything that pulls the source copy out of service (rewrite,
quarantine, delete), so a cached result is never served across a
version bump.

Thread-safety: all mutators run under one internal lock (OSD serve
paths run concurrently on the store's pool workers).  The cache never
touches ``Fabric`` counters itself — per-request hit/miss/eviction
deltas ride back in the batched response and are accumulated by the
client thread that issued the call, preserving the store's
single-accounting-thread counter contract.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable

_MISS = object()


class Negative:
    """A cached *negative* result: the object provably served NOTHING
    for this key — missing from the store, disjoint from a resolved
    row/hyperslab range, or zone-map pruned.  Cached under the same
    ``(name, version, ...)`` keyed scheme as positive entries (so the
    version-bump and eager-invalidation paths retire them identically),
    it lets a repeat scan skip digest verification, op resolution, and
    the service queue for objects that still have nothing to say.
    ``reason`` is the disposition the original miss reported
    ("missing" / "skipped" / "pruned") so the replay answers with the
    same shape."""

    __slots__ = ("reason",)
    NBYTES = 64  # accounting charge per negative entry (tiny, not free)

    def __init__(self, reason: str):
        self.reason = reason

    def __repr__(self):
        return f"Negative({self.reason!r})"


class ResultCache:
    """LRU mapping ``key -> value`` bounded by total payload bytes.

    Keys are tuples whose FIRST element is the object name — the
    per-name index built from it makes ``invalidate(name)`` O(entries
    for that name), which is what the write/quarantine paths call on
    every version bump.  ``capacity <= 0`` disables the cache entirely
    (every ``get`` misses, every ``put`` is a no-op) so a cold store
    pays nothing for the feature.
    """

    # lock-discipline contract (see ``repro.analysis``): the entry map,
    # the per-name index, and the byte gauge move together — partial
    # views are never visible outside ``_lock``
    _GUARDED_BY = {"_entries": "_lock", "_by_name": "_lock",
                   "_bytes": "_lock"}

    def __init__(self, capacity_bytes: int = 0):
        self.capacity = int(capacity_bytes or 0)
        self._lock = threading.Lock()
        self._entries: OrderedDict[Hashable, tuple[Any, int]] = \
            OrderedDict()
        self._by_name: dict[str, set[Hashable]] = {}
        self._bytes = 0

    # ------------------------------------------------------------ lookup
    def get(self, key: Hashable) -> Any:
        """The cached value (refreshed to MRU) or the module-level
        ``_MISS`` sentinel — values themselves may be any object."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return _MISS
            self._entries.move_to_end(key)
            return ent[0]

    # ------------------------------------------------------------ insert
    def put(self, key: Hashable, value: Any,
            nbytes: int) -> tuple[int, int]:
        """Insert (or refresh) one entry, evicting LRU entries until the
        byte bound holds again.  Returns ``(evicted_entries,
        inserted_bytes)`` for the caller's per-request meters — an
        over-capacity value is refused (0 inserted) rather than allowed
        to flush the whole cache for one unreusable result."""
        nbytes = int(nbytes)
        if self.capacity <= 0 or nbytes > self.capacity:
            return 0, 0
        evicted = 0
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
                self._by_name[key[0]].discard(key)
            while self._bytes + nbytes > self.capacity and self._entries:
                self._evict_lru()
                evicted += 1
            self._entries[key] = (value, nbytes)
            self._by_name.setdefault(key[0], set()).add(key)
            self._bytes += nbytes
        return evicted, nbytes

    def _evict_lru(self) -> None:
        key, (_, nb) = self._entries.popitem(last=False)
        self._bytes -= nb
        keys = self._by_name.get(key[0])
        if keys is not None:
            keys.discard(key)
            if not keys:
                del self._by_name[key[0]]

    def put_negative(self, key: Hashable, reason: str) -> tuple[int, int]:
        """Cache a nothing-to-serve disposition (see :class:`Negative`)."""
        return self.put(key, Negative(reason), Negative.NBYTES)

    # ------------------------------------------------------------ drop
    def invalidate(self, name: str) -> int:
        """Drop every entry for one object name (called on rewrite,
        quarantine, and delete).  Returns the entry count dropped."""
        with self._lock:
            keys = self._by_name.pop(name, None)
            if not keys:
                return 0
            for key in keys:
                _, nb = self._entries.pop(key)
                self._bytes -= nb
            return len(keys)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_name.clear()
            self._bytes = 0

    # ------------------------------------------------------------ observe
    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def entries_for(self, name: str) -> int:
        with self._lock:
            return len(self._by_name.get(name, ()))
