"""Predicate-expression algebra — ONE filter language for every layer
(paper §3.2; Skyhook-style rich pushdown filters).

A predicate is an immutable tree of :class:`Expr` nodes::

    Or((Cmp("run", "<", 10), Cmp("run", ">", 90))) & Cmp("hits", ">=", 3)

and the SAME tree serves three roles:

  * **evaluation** — ``expr.mask(table)`` walks the tree producing one
    vectorized numpy row mask per leaf and combining them with mask
    algebra per node; the OSD's ``filter`` objclass op is exactly this
    walk;
  * **pruning** — ``expr.prunes(zone_map)`` decides, by interval
    arithmetic over the object's per-column [lo, hi] zone map, whether
    the object PROVABLY matches no row.  The rule is conservative by
    construction: a leaf prunes only when its interval is disjoint from
    the matching set, ``And`` prunes if ANY child prunes, ``Or`` only
    if ALL children prune, and ``Not`` / unknown leaves never prune.
    The one rule is shared verbatim by the client planner
    (``GlobalVOL.plan``) and the OSDs (``OSD.exec_cls_batch``), so
    ``prune="client"`` and ``prune="pushdown"`` agree bit-exactly on
    identical metadata;
  * **transport** — ``to_json()``/``from_json()`` give the wire form
    that rides inside ``ObjOp`` params and the batched request's
    ``prune`` field, so a rich filter costs the same K round trips as a
    flat one.

Every comparison operator is defined ONCE, in :data:`CMP_TABLE`: a
:class:`Comparator` carries BOTH its vectorized evaluator and its
interval prune rule as required fields, so adding an operator without
teaching every layer is a construction-time ``TypeError`` — not a
silent never-prune on the client or a ``KeyError`` on the OSD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping

import numpy as np


# --------------------------------------------------------------------------
# the ONE comparator table
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Comparator:
    """One comparison operator for every layer that needs it: ``fn`` is
    the vectorized evaluator (``mask = fn(column, value)``), ``prunes``
    the interval rule (does a [lo, hi] zone PROVE no value matches?).
    Both are required fields on purpose — a half-defined operator
    cannot be registered."""

    fn: Callable[..., np.ndarray]
    prunes: Callable[[Any, Any, Any], bool]


CMP_TABLE: dict[str, Comparator] = {
    "<":  Comparator(np.less,          lambda lo, hi, v: lo >= v),
    "<=": Comparator(np.less_equal,    lambda lo, hi, v: lo > v),
    ">":  Comparator(np.greater,       lambda lo, hi, v: hi <= v),
    ">=": Comparator(np.greater_equal, lambda lo, hi, v: hi < v),
    "==": Comparator(np.equal,         lambda lo, hi, v: v < lo or v > hi),
    # a zone can prove != empty only when EVERY row equals the value
    "!=": Comparator(np.not_equal,     lambda lo, hi, v: lo == v == hi),
}

COMPARATORS = tuple(CMP_TABLE)


def _rows(mask) -> np.ndarray:
    """Reduce a leaf's elementwise mask to a 1-D row mask: a row of a
    multi-dim column matches when ANY of its elements does (each leaf
    reduces independently, so leaves over different-shaped columns
    still combine)."""
    mask = np.asarray(mask)
    if mask.ndim > 1:
        mask = mask.any(axis=tuple(range(1, mask.ndim)))
    return mask


def _sound(prune_fn, rng, *args) -> bool:
    """A leaf prunes only when its zone interval PROVES emptiness; a
    missing, malformed, or type-mismatched interval proves nothing."""
    if not rng:
        return False
    try:
        lo, hi = rng
        return bool(prune_fn(lo, hi, *args))
    except TypeError:  # e.g. string zone vs numeric value
        return False


def _py(v):
    """JSON-able scalar (numpy scalars -> python)."""
    return v.item() if isinstance(v, np.generic) else v


# --------------------------------------------------------------------------
# the expression tree
# --------------------------------------------------------------------------


class Expr:
    """Base of the immutable predicate tree.  Subclasses implement
    ``mask`` (vectorized evaluation -> 1-D row mask), ``prunes``
    (conservative zone-map interval proof), ``columns`` and
    ``to_json``.  ``&``/``|``/``~`` compose trees fluently."""

    def mask(self, table: Mapping[str, np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def prunes(self, zone_map: Mapping) -> bool:
        return False  # conservative default (Not, unknown leaves)

    def columns(self) -> frozenset:
        raise NotImplementedError

    def to_json(self) -> dict:
        raise NotImplementedError

    def __and__(self, other) -> "Expr":
        return conj(self, ensure(other))

    def __or__(self, other) -> "Expr":
        return Or((self, ensure(other)))

    def __invert__(self) -> "Expr":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    """A constant predicate — the residue of constant folding.  True
    matches every row (and never prunes); False matches none (and
    soundly prunes ANY object, zone map or not)."""

    value: bool

    def mask(self, table):
        n = 0
        for v in table.values():
            n = int(np.asarray(v).shape[0])
            break
        return np.full(n, bool(self.value), dtype=bool)

    def prunes(self, zone_map):
        return not self.value

    def columns(self):
        return frozenset()

    def to_json(self):
        return {"t": "const", "value": bool(self.value)}


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    """``col <cmp> value`` — one :data:`CMP_TABLE` comparison."""

    col: str
    cmp: str
    value: Any

    def __post_init__(self):
        if self.cmp not in CMP_TABLE:
            raise ValueError(f"bad comparator {self.cmp!r}; "
                             f"known: {COMPARATORS}")

    def mask(self, table):
        return _rows(CMP_TABLE[self.cmp].fn(np.asarray(table[self.col]),
                                            self.value))

    def prunes(self, zone_map):
        return _sound(CMP_TABLE[self.cmp].prunes, zone_map.get(self.col),
                      self.value)

    def columns(self):
        return frozenset((self.col,))

    def to_json(self):
        return {"t": "cmp", "col": self.col, "cmp": self.cmp,
                "value": _py(self.value)}


@dataclasses.dataclass(frozen=True)
class In(Expr):
    """``col IN values`` — membership in a finite list."""

    col: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))

    def mask(self, table):
        return _rows(np.isin(np.asarray(table[self.col]),
                             list(self.values)))

    def prunes(self, zone_map):
        # prune iff every candidate value is outside [lo, hi]; an empty
        # IN-list matches nothing, so it vacuously (and soundly) prunes
        return _sound(
            lambda lo, hi: all(v < lo or v > hi for v in self.values),
            zone_map.get(self.col))

    def columns(self):
        return frozenset((self.col,))

    def to_json(self):
        return {"t": "in", "col": self.col,
                "values": [_py(v) for v in self.values]}


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    """``lo <= col <= hi`` (inclusive both ends)."""

    col: str
    lo: Any
    hi: Any

    def mask(self, table):
        a = np.asarray(table[self.col])
        return _rows(np.greater_equal(a, self.lo)
                     & np.less_equal(a, self.hi))

    def prunes(self, zone_map):
        return _sound(lambda zlo, zhi: zhi < self.lo or zlo > self.hi,
                      zone_map.get(self.col))

    def columns(self):
        return frozenset((self.col,))

    def to_json(self):
        return {"t": "between", "col": self.col, "lo": _py(self.lo),
                "hi": _py(self.hi)}


@dataclasses.dataclass(frozen=True)
class StrPrefix(Expr):
    """``col.startswith(prefix)`` over a string column (zone maps store
    string min/max, so prefix scans prune like range scans)."""

    col: str
    prefix: str

    def mask(self, table):
        a = np.asarray(table[self.col])
        if a.dtype.kind != "S":
            a = a.astype(np.str_)
        return _rows(np.char.startswith(
            a, self.prefix.encode() if a.dtype.kind == "S"
            else self.prefix))

    def prunes(self, zone_map):
        # matching strings live in [prefix, prefix∙∞): everything below
        # prefix, or everything above the last string with that prefix,
        # proves emptiness
        def rule(lo, hi):
            if hi < self.prefix:
                return True
            return lo > self.prefix and not str(lo).startswith(self.prefix)
        return _sound(rule, zone_map.get(self.col))

    def columns(self):
        return frozenset((self.col,))

    def to_json(self):
        return {"t": "prefix", "col": self.col, "prefix": self.prefix}


def _check_children(children):
    if not children:
        raise ValueError("And/Or need at least one child")
    for c in children:
        if not isinstance(c, Expr):
            raise TypeError(f"child {c!r} is not an Expr (use ensure())")


@dataclasses.dataclass(frozen=True)
class And(Expr):
    """Conjunction: a row matches when EVERY child matches; an object
    prunes when ANY child's interval proof empties it."""

    children: tuple

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))
        _check_children(self.children)

    def mask(self, table):
        out = self.children[0].mask(table)
        for c in self.children[1:]:
            out = out & c.mask(table)
        return out

    def prunes(self, zone_map):
        return any(c.prunes(zone_map) for c in self.children)

    def columns(self):
        return frozenset().union(*(c.columns() for c in self.children))

    def to_json(self):
        return {"t": "and",
                "children": [c.to_json() for c in self.children]}


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    """Disjunction: a row matches when ANY child matches; an object
    prunes only when EVERY child's interval proof empties it."""

    children: tuple

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))
        _check_children(self.children)

    def mask(self, table):
        out = self.children[0].mask(table)
        for c in self.children[1:]:
            out = out | c.mask(table)
        return out

    def prunes(self, zone_map):
        return all(c.prunes(zone_map) for c in self.children)

    def columns(self):
        return frozenset().union(*(c.columns() for c in self.children))

    def to_json(self):
        return {"t": "or",
                "children": [c.to_json() for c in self.children]}


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    """Negation.  NEVER prunes: a zone map bounds what values exist,
    not what values are absent, so no interval can prove a negation
    empty (``prunes`` stays the base class's conservative False)."""

    child: Expr

    def __post_init__(self):
        if not isinstance(self.child, Expr):
            raise TypeError(f"Not needs an Expr, got {self.child!r}")

    def mask(self, table):
        return ~self.child.mask(table)

    def columns(self):
        return self.child.columns()

    def to_json(self):
        return {"t": "not", "child": self.child.to_json()}


# --------------------------------------------------------------------------
# construction / normalization / wire form
# --------------------------------------------------------------------------


_FROM_JSON: dict[str, Callable[[dict], Expr]] = {
    "const": lambda d: Const(bool(d["value"])),
    "cmp": lambda d: Cmp(d["col"], d["cmp"], d["value"]),
    "in": lambda d: In(d["col"], tuple(d["values"])),
    "between": lambda d: Between(d["col"], d["lo"], d["hi"]),
    "prefix": lambda d: StrPrefix(d["col"], d["prefix"]),
    "and": lambda d: And(tuple(from_json(c) for c in d["children"])),
    "or": lambda d: Or(tuple(from_json(c) for c in d["children"])),
    "not": lambda d: Not(from_json(d["child"])),
}


def from_json(d: Mapping) -> Expr:
    """Rebuild a tree from its wire form (see ``Expr.to_json``)."""
    try:
        build = _FROM_JSON[d["t"]]
    except KeyError:
        raise ValueError(f"unknown expression node {d.get('t')!r}; "
                         f"known: {sorted(_FROM_JSON)}") from None
    return build(d)


def ensure(x) -> Expr:
    """Normalize one predicate spec: an :class:`Expr`, its serialized
    dict, or a legacy ``(col, cmp, value)`` triple."""
    if isinstance(x, Expr):
        return x
    if isinstance(x, Mapping):
        return from_json(x)
    if isinstance(x, (tuple, list)) and len(x) == 3:
        return Cmp(x[0], x[1], x[2])
    raise TypeError(f"not a predicate: {x!r} (want an Expr, its JSON "
                    "form, or a (col, cmp, value) triple)")


def ensure_pred(p) -> Expr | None:
    """Normalize a whole pushdown-prune payload: None, one Expr, its
    wire dict, or the legacy iterable of (col, cmp, value) triples
    (conjunction).  Returns None when there is nothing to prune on."""
    if p is None or isinstance(p, Expr):
        return p
    if isinstance(p, Mapping):
        return from_json(p)
    return conj_all(ensure(t) for t in p)


def conj(a: Expr | None, b: Expr) -> Expr:
    """AND-compose, flattening nested ``And`` nodes (so N fluent
    ``.filter`` calls build one flat conjunction, not a left spine)."""
    if a is None:
        return b
    left = a.children if isinstance(a, And) else (a,)
    right = b.children if isinstance(b, And) else (b,)
    return And(left + right)


def conj_all(exprs: Iterable[Expr]) -> Expr | None:
    out: Expr | None = None
    for e in exprs:
        out = conj(out, e)
    return out


# --------------------------------------------------------------------------
# normalization (prune-path rewriting)
# --------------------------------------------------------------------------

# each comparator's exact complement — the engine of De Morgan push-down
_NEG_CMP = {"<": ">=", "<=": ">", ">": "<=", ">=": "<",
            "==": "!=", "!=": "=="}

# cmp -> (is_lower_bound, strict) for the interval-merging pass
_BOUND = {">": (True, True), ">=": (True, False),
          "<": (False, True), "<=": (False, False)}


def normalize(e: Expr | None) -> Expr | None:
    """Rewrite a tree into an equivalent, more prunable one:

      * **De Morgan push-down** — ``Not`` sinks to the leaves, where it
        dissolves into the complement comparator (``~(x < 5)`` becomes
        ``x >= 5``); since ``Not`` never prunes but every comparator
        does, a pushed-down tree prunes where the original could not;
      * **constant folding** — empty ``In`` lists, inverted ``Between``
        bounds, and dominated ``And``/``Or`` children collapse to
        :class:`Const`;
      * **same-column interval merging** — interval leaves on one
        column inside a conjunction fuse into the tightest interval
        (``x > 2 AND x > 5`` -> ``x > 5``; ``x > 5 AND x < 1`` ->
        ``Const(False)``; closed bounds fuse into one ``Between``).

    Caveats, by design: the rewrite assumes a total order on compared
    values (predicates over NaN-holding float columns are not made
    worse — merging skips non-finite constants — but NaN rows already
    defeat zone pruning) and interval *contradiction* folding assumes
    scalar per-row values (for multi-element rows the per-leaf
    any-element reduction makes opposing bounds satisfiable, so the
    scan layer only normalizes prune payloads over scalar zone
    metadata, never evaluation filters)."""
    if e is None:
        return None
    return _norm(e, neg=False)


def _mergeable(v) -> bool:
    if isinstance(v, bool):
        return False  # bools order like ints but folding them is noise
    if isinstance(v, (int, np.integer)):
        return True
    if isinstance(v, (float, np.floating)):
        return bool(np.isfinite(v))
    return isinstance(v, str)


def _norm(e: Expr, neg: bool) -> Expr:
    if isinstance(e, Const):
        return Const(e.value != neg)
    if isinstance(e, Cmp):
        return Cmp(e.col, _NEG_CMP[e.cmp], e.value) if neg else e
    if isinstance(e, Between):
        try:
            empty = e.lo > e.hi
        except TypeError:
            empty = False
        if empty:
            return Const(neg)
        if neg:  # ~(lo <= x <= hi)  ==  x < lo OR x > hi
            return Or((Cmp(e.col, "<", e.lo), Cmp(e.col, ">", e.hi)))
        return e
    if isinstance(e, In):
        if not e.values:
            return Const(neg)  # IN () matches nothing
        return Not(e) if neg else e
    if isinstance(e, Not):
        return _norm(e.child, not neg)
    if not isinstance(e, (And, Or)):       # StrPrefix, future leaves
        return Not(e) if neg else e
    is_and = isinstance(e, And) != neg     # De Morgan flips the node
    flat: list[Expr] = []
    for c in e.children:
        k = _norm(c, neg)
        if isinstance(k, And if is_and else Or):
            flat.extend(k.children)
        elif isinstance(k, Const):
            if k.value != is_and:          # dominating constant
                return Const(not is_and)
        else:                              # identity constant: dropped
            flat.append(k)
    kids: list[Expr] = []
    for k in flat:                         # dedup, order-preserving
        if k not in kids:
            kids.append(k)
    if is_and:
        kids = _merge_intervals(kids)
        if kids is None:
            return Const(False)
    if not kids:
        return Const(is_and)               # empty And ≡ True, Or ≡ False
    if len(kids) == 1:
        return kids[0]
    return (And if is_and else Or)(tuple(kids))


def _merge_intervals(kids: list[Expr]) -> list[Expr] | None:
    """Fuse same-column interval leaves of a conjunction; None means a
    provable contradiction (the conjunction is Const(False))."""
    by_col: dict[str, list[Expr]] = {}
    for k in kids:
        if (isinstance(k, Cmp) and k.cmp in _BOUND
                and _mergeable(k.value)) or \
           (isinstance(k, Cmp) and k.cmp == "=="
                and _mergeable(k.value)) or \
           (isinstance(k, Between) and _mergeable(k.lo)
                and _mergeable(k.hi)):
            by_col.setdefault(k.col, []).append(k)
    out: list[Expr] = []
    done: set[int] = set()
    for col, leaves in by_col.items():
        if len(leaves) < 2:
            continue  # nothing to fuse; leave the leaf in place
        try:
            fused = _fuse(col, leaves)
        except TypeError:  # mixed value types: leave unmerged
            continue
        if fused is None:
            return None
        done.update(id(l) for l in leaves)
        out.extend(fused)
    return [k for k in kids if id(k) not in done] + out


def _fuse(col: str, leaves: list[Expr]) -> list[Expr] | None:
    lo = hi = None  # (value, strict)

    def tighter_lo(a, b):
        return b if a is None or b[0] > a[0] \
            or (b[0] == a[0] and b[1] and not a[1]) else a

    def tighter_hi(a, b):
        return b if a is None or b[0] < a[0] \
            or (b[0] == a[0] and b[1] and not a[1]) else a

    for l in leaves:
        if isinstance(l, Between):
            lo = tighter_lo(lo, (l.lo, False))
            hi = tighter_hi(hi, (l.hi, False))
        elif l.cmp == "==":
            lo = tighter_lo(lo, (l.value, False))
            hi = tighter_hi(hi, (l.value, False))
        else:
            is_lo, strict = _BOUND[l.cmp]
            if is_lo:
                lo = tighter_lo(lo, (l.value, strict))
            else:
                hi = tighter_hi(hi, (l.value, strict))
    if lo is not None and hi is not None:
        if lo[0] > hi[0] or (lo[0] == hi[0] and (lo[1] or hi[1])):
            return None  # empty interval: contradiction
        if lo[0] == hi[0]:
            return [Cmp(col, "==", lo[0])]
        if not lo[1] and not hi[1]:
            return [Between(col, lo[0], hi[0])]
    out: list[Expr] = []
    if lo is not None:
        out.append(Cmp(col, ">" if lo[1] else ">=", lo[0]))
    if hi is not None:
        out.append(Cmp(col, "<" if hi[1] else "<=", hi[0]))
    return out
