"""CRUSH-like object placement: placement groups + rendezvous hashing.

Ceph's CRUSH maps object -> PG -> ordered OSD set deterministically from a
compact cluster map, so any client can locate any object with no central
lookup, and OSD failure / cluster resize moves a *minimal* set of PGs.

We reproduce those properties with highest-random-weight (HRW/rendezvous)
hashing: each (pg, osd) pair gets a stable pseudo-random score scaled by
the OSD weight; a PG's replica set is the top-R scoring *up* OSDs.  The
key minimal-movement property (verified by hypothesis tests):

  * removing/failing an OSD only remaps PGs that had that OSD in their
    replica set;
  * adding an OSD only pulls in PGs for which the new OSD now scores in
    the top R.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Iterable, Mapping

_U64 = float(1 << 64)


def _h64(*parts: object) -> int:
    h = hashlib.blake2b("\x00".join(map(str, parts)).encode(),
                        digest_size=8)
    return struct.unpack("<Q", h.digest())[0]


@dataclasses.dataclass(frozen=True)
class ClusterMap:
    """Immutable cluster description; every mutation bumps ``epoch``."""

    osds: tuple[str, ...]
    n_pgs: int = 64
    replicas: int = 3
    epoch: int = 0
    weights: Mapping[str, float] = dataclasses.field(default_factory=dict)
    down: frozenset[str] = frozenset()

    def __post_init__(self):
        if len(set(self.osds)) != len(self.osds):
            raise ValueError("duplicate osd ids")
        if self.n_pgs <= 0 or self.replicas <= 0:
            raise ValueError("n_pgs and replicas must be positive")

    # ------------------------------------------------------------ state
    @property
    def up_osds(self) -> tuple[str, ...]:
        return tuple(o for o in self.osds if o not in self.down)

    def weight(self, osd: str) -> float:
        return float(self.weights.get(osd, 1.0))

    # ------------------------------------------------------------ mapping
    def pg_of(self, obj_name: str) -> int:
        return _h64("pg", obj_name) % self.n_pgs

    def acting_set(self, pg: int, *, n: int | None = None) -> tuple[str, ...]:
        """Ordered replica set (primary first) for a placement group."""
        n = self.replicas if n is None else n
        # weighted rendezvous: score = hash^(1/w); higher wins
        cand = [(
            (_h64("hrw", pg, o) / _U64) ** (1.0 / max(self.weight(o), 1e-9)),
            o) for o in self.up_osds]
        cand.sort(reverse=True)
        return tuple(o for _, o in cand[:n])

    def locate(self, obj_name: str) -> tuple[str, ...]:
        """object -> ordered OSD replica set (primary first)."""
        return self.acting_set(self.pg_of(obj_name))

    def primary(self, obj_name: str) -> str:
        s = self.locate(obj_name)
        if not s:
            raise RuntimeError("no up OSDs")
        return s[0]

    # ------------------------------------------------------------ mutation
    def mark_down(self, osd: str) -> "ClusterMap":
        if osd not in self.osds:
            raise KeyError(osd)
        return dataclasses.replace(self, down=self.down | {osd},
                                   epoch=self.epoch + 1)

    def mark_up(self, osd: str) -> "ClusterMap":
        return dataclasses.replace(self, down=self.down - {osd},
                                   epoch=self.epoch + 1)

    def add_osds(self, new: Iterable[str]) -> "ClusterMap":
        return dataclasses.replace(self, osds=self.osds + tuple(new),
                                   epoch=self.epoch + 1)

    def remove_osd(self, osd: str) -> "ClusterMap":
        return dataclasses.replace(
            self, osds=tuple(o for o in self.osds if o != osd),
            down=self.down - {osd}, epoch=self.epoch + 1)

    def reweight(self, osd: str, w: float) -> "ClusterMap":
        return dataclasses.replace(self, weights={**self.weights, osd: w},
                                   epoch=self.epoch + 1)


def pg_delta(old: ClusterMap, new: ClusterMap) -> dict[int, tuple]:
    """PGs whose acting set changed: pg -> (old_set, new_set).

    This is the rebalance plan between two epochs; ``core.store`` uses it
    for recovery and ``distributed.elastic`` for scale-up/down planning.
    """
    if old.n_pgs != new.n_pgs:
        raise ValueError("pg count change requires a full remap")
    out = {}
    for pg in range(old.n_pgs):
        a, b = old.acting_set(pg), new.acting_set(pg)
        if a != b:
            out[pg] = (a, b)
    return out


def movement_fraction(old: ClusterMap, new: ClusterMap) -> float:
    """Fraction of (pg, replica) assignments that moved — the metric the
    minimal-movement property bounds."""
    moved = total = 0
    for pg in range(old.n_pgs):
        a, b = set(old.acting_set(pg)), set(new.acting_set(pg))
        total += max(len(a), 1)
        moved += len(b - a)
    return moved / max(total, 1)
