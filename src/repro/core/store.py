"""RADOS-like distributed object store (simulated control plane).

OSDs are in-process shards with byte-accurate transfer accounting; the
semantics — primary/replica writes, objclass execution on the primary,
failure, peering/recovery — follow Ceph.  The accounting (client<->OSD
bytes vs OSD-local bytes processed) is what the paper's pushdown claims
are measured against in ``benchmarks/``.

Symmetric batched data plane: EVERY client<->OSD interaction goes
through one per-OSD batch RPC, so fabric ops scale with the number of
OSDs touched (K), never the number of objects (N):

  * reads/scans — ``exec_batch(names, ops)`` groups objects by primary
    OSD, ONE objclass request per OSD; ``ops`` may be a single shared
    pipeline or one pipeline per object;
  * aggregate scans — ``exec_combine(names, ops)`` additionally folds
    partials *on* each OSD (the tail op's associative ``merge``) and
    returns ONE partial per OSD, so ``client_rx`` is O(K) too;
  * writes — ``put_batch(names, blobs, xattrs)`` groups sub-writes by
    primary OSD (one request + server-side replication per object),
    with per-object failover inside the batch;
  * metadata — ``list_zone_maps(names)`` fetches many objects' xattrs
    in one request per OSD (one ``xattr_ops`` per request, not per
    object).

Streaming pipelined data plane: the O(K) request plane is also an
O(overlap) wall-clock plane.  ``put_batch(window_bytes=...)`` accepts a
lazy blob producer and flushes per-OSD sub-write groups into one
long-lived streaming request per primary OSD as each window fills, so
client-side encode overlaps the NIC stream (measured in
``Fabric.overlap_s`` / ``stream_windows``); ``exec_batch_iter`` /
``exec_combine_iter`` / ``exec_concat_iter`` are the read-side twins —
per-OSD result frames are delivered in completion order so the client
decodes early frames while slower OSDs are still scanning.  Replica
writes pipeline down a CHAIN (entry -> replica -> replica, Ceph's
primary-copy forwarding) instead of fanning out, halving the entry
OSD's replication egress (``Fabric.entry_egress_bytes``) at 3x
replication; ``replication="fanout"`` keeps the legacy topology for
comparison.

Every put stamps the object's xattr with a monotonic ``version`` tag;
clients cache zone maps keyed by (epoch, version) and revalidate prune
decisions against current versions, which closes the cross-client
stale-zone-map hazard (see ``GlobalVOL.plan``).

Every client<->OSD round trip is charged ``PER_REQUEST_OVERHEAD_BYTES``
into ``Fabric.overhead_bytes`` — the request-amplification cost that
batching amortizes.  All scatter/gather paths share one persistent
executor (``ObjectStore._pool``) instead of building a thread pool per
call, and skip thread fan-out entirely when no I/O is simulated
(``io_simulated`` — pure compute runs faster sequentially under the
GIL).

Failure model: ``fail_osd`` marks an OSD down (its data is *gone*, as a
disk loss); ``recover`` re-replicates every object that lost a replica
from a surviving copy, on the new cluster map.  Reads and objclass execs
transparently fail over to the next replica in the acting set; in a
batch, failed objects are re-grouped onto their next untried replica and
retried as new (batched) requests.

Self-healing plane (gray failures, not just fail-stop):

  * every write path (``put``, ``put_batch`` windows, each replication
    hop) stamps a content ``digest`` (``format.content_digest`` over the
    encoded blob) into the object's xattrs, so EVERY copy is
    independently verifiable;
  * every read verifies the served copy against its own digest; a
    divergent copy is quarantined on its OSD (``OSD.quarantine``) and
    surfaced as :class:`CorruptObject`, which the batched planes treat
    exactly like a missing replica — per-object failover to the next
    copy in the acting set (``Fabric.corruptions_detected`` counts the
    catches);
  * ``scrub()`` is the background maintenance pass: a per-OSD walker
    verifies every local copy, quarantines divergent/torn ones, and
    heals from the highest-version digest-verified copy through the
    replication chain; ``recover()`` is digest-verified too — it
    refuses a corrupt source, falls down the surviving copies, and
    raises :class:`DataLossError` (naming the objects) instead of
    silently under-reporting total loss;
  * transient request faults (:class:`TransientOSDError`, injected by
    ``core.faults.FaultInjector``) are retried inside the shared
    batched-failover skeleton with bounded exponential backoff under a
    per-request deadline (:class:`RetryPolicy`;
    ``Fabric.retries`` counts them); an exhausted budget escalates to
    replica failover, keeping the retryable/terminal distinction sharp.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json as _json
import queue as _queue
import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Any, Callable, Iterable, Iterator, Sequence

import numpy as np

from repro.core import expr as ex
from repro.core.cache import Negative as _Negative, ResultCache, _MISS
from repro.core.format import content_digest
from repro.core.objclass import (
    ObjOp, apply_pipeline, compact_merge as _compact_merge_blocks,
    concat_encode, decode_pipeline,
    get_impl as _impl, has_hyperslab, has_row_slice, merge_partials,
    normalize_exprs, pipeline_digest, pipeline_mergeable,
    required_columns, resolve_hyperslab, resolve_row_slice,
    run_pipeline, table_n_rows, zone_map_prunes)
from repro.core.placement import ClusterMap

# fixed cost modeled for one client<->OSD round trip (headers, framing,
# dispatch) — what per-object fan-out pays N times and a batch pays once
PER_REQUEST_OVERHEAD_BYTES = 128

# default ingest window for the streaming write plane: sub-write groups
# flush to their per-OSD streams every this-many encoded bytes, so the
# encoder runs at most one window ahead of the NIC
DEFAULT_WINDOW_BYTES = 8 << 20

# bounds for ``put_batch(window_bytes="adaptive")``: the per-window
# retarget W_next = W * encode_rate / NIC_rate is clamped to this range
# so one mis-measured window can neither collapse streaming to per-blob
# flushes nor balloon the ledger past a sane buffer
ADAPTIVE_WINDOW_FLOOR = 256 << 10
ADAPTIVE_WINDOW_CAP = 64 << 20


@dataclasses.dataclass
class Fabric:
    """Byte/op counters for the client<->storage network.

    Counters are exact for any single accounting thread: the store's
    internal workers (replica chains, stream feeders, scatter groups)
    never touch them — deltas are accumulated by the thread that issued
    the call.  Two *independent* client threads driving the store
    concurrently (a prefetching data loader beside an async
    checkpointer, say) interleave their updates without synchronization
    — read invariants around single-threaded windows, as the tests and
    benchmarks do."""

    client_tx: int = 0          # client -> OSD (writes)
    client_rx: int = 0          # OSD -> client (reads / results)
    replica_bytes: int = 0      # OSD -> OSD replication (all hops)
    entry_egress_bytes: int = 0  # replication bytes SENT BY the entry
    #                              OSD (chain: first hop only; fan-out:
    #                              every replica — the 2x the chain cuts)
    recovery_bytes: int = 0     # OSD -> OSD re-replication
    local_bytes: int = 0        # bytes processed inside OSDs (pushdown)
    ops: int = 0                # client<->OSD round trips (requests)
    overhead_bytes: int = 0     # per-request fixed cost (ops * 128 B)
    xattr_ops: int = 0          # metadata (xattr) lookups
    rx_frames: int = 0          # framed result payloads the client parsed
    stream_windows: int = 0     # windowed sub-write groups flushed +
    #                             result frames delivered while streaming
    overlap_s: float = 0.0      # encode time hidden behind an active
    #                             NIC stream (windowed ingest)
    scrub_bytes: int = 0        # bytes digest-verified by scrub walks
    corruptions_detected: int = 0  # divergent/torn copies caught (reads,
    #                                scrub, recover source vetting)
    heals: int = 0              # replica copies restored (scrub/recover)
    retries: int = 0            # transient-fault request retries
    cache_hits: int = 0         # served from an OSD result cache
    cache_misses: int = 0       # cache enabled but entry absent/stale
    cache_evictions: int = 0    # LRU entries dropped for the byte bound
    cache_bytes: int = 0        # bytes ADMITTED into OSD caches (a
    #                             monotonic counter like every other
    #                             field, not a residency gauge — see
    #                             stats()["cache_resident_bytes"])
    queue_wait_s: float = 0.0   # time requests blocked behind another
    #                             scan in an OSD's modeled service queue
    cache_neg_hits: int = 0     # nothing-to-serve answered from an OSD
    #                             negative-cache entry (missing/skipped/
    #                             pruned replays that bypassed the queue)
    chunks_pruned: int = 0      # array chunks dropped OSD-side by
    #                             per-chunk zone maps before any cell
    #                             of the chunk was touched
    replica_lat_s: float = 0.0  # modeled replication write latency
    #                             (chain: per-hop, sequential; fan-out:
    #                             one hop, parallel)
    # -- maintenance plane (core.maintenance daemons; each counter has
    #    ONE writer thread — the daemon that owns that work) --
    compactions: int = 0        # small-object runs folded (compact_merge)
    compaction_bytes: int = 0   # bytes read/shipped/written by compaction
    rebalance_bytes: int = 0    # bytes moved toward fresh placement by
    #                             the live rebalancer (old copies kept
    #                             until the new copy digest-verifies)
    gc_objects: int = 0         # dead versions + quarantined copies
    #                             reclaimed after the retention window
    gc_bytes: int = 0           # bytes those reclaims freed

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> None:
        self.client_tx = self.client_rx = 0
        self.replica_bytes = self.entry_egress_bytes = 0
        self.recovery_bytes = 0
        self.local_bytes = self.ops = 0
        self.overhead_bytes = self.xattr_ops = self.rx_frames = 0
        self.stream_windows = 0
        self.overlap_s = 0.0
        self.scrub_bytes = self.corruptions_detected = 0
        self.heals = self.retries = 0
        self.cache_hits = self.cache_misses = self.cache_evictions = 0
        self.cache_bytes = 0
        self.queue_wait_s = 0.0
        self.cache_neg_hits = self.chunks_pruned = 0
        self.replica_lat_s = 0.0
        self.compactions = self.compaction_bytes = 0
        self.rebalance_bytes = 0
        self.gc_objects = self.gc_bytes = 0


def _serve_meters() -> dict:
    """Per-request serve-plane meters: accumulated OSD-side while a
    batched request runs (possibly on a pool worker), shipped back in
    the response, and folded into the fabric by the CLIENT thread that
    issued the call — pool workers never touch fabric counters."""
    return {"cache_hits": 0, "cache_misses": 0, "cache_evictions": 0,
            "cache_bytes": 0, "queue_wait_s": 0.0,
            "neg_hits": 0, "chunks_pruned": 0}


class OSDDown(RuntimeError):
    pass


class ObjectNotFound(KeyError):
    pass


class TransientOSDError(RuntimeError):
    """A request-scoped gray failure: the OSD is up and its data is
    intact, but THIS request failed (dropped frame, brief overload).
    Retryable by definition — the batched planes retry it with bounded
    exponential backoff (``RetryPolicy``) before escalating to replica
    failover, unlike :class:`OSDDown` (terminal for that OSD)."""


class CorruptObject(Exception):
    """A stored copy failed digest verification (or lost its xattr in a
    torn write under a pipeline that needs it).  The divergent copy is
    already quarantined on its OSD when this surfaces; the client planes
    treat it like a missing replica and fail over to the next copy in
    the acting set."""


class DataLossError(RuntimeError):
    """Every replica of the named objects is lost or corrupt — there is
    no copy left to serve or heal from.  ``objects`` lists them.  Raised
    loudly by ``recover()`` (unless ``allow_loss=True``) and by the
    read/exec planes when failover exhausts an acting set on corrupt
    copies, instead of burying the loss in a stats dict.

    ``census`` maps each named object to its per-OSD copy census —
    ``{"verified": [osd...], "divergent": [osd...], "bare": [osd...],
    "quarantined": [osd...]}`` — so an operator can triage (is there a
    bare copy worth adopting? a quarantined one worth inspecting?)
    before opting into ``recover(allow_loss=True)``."""

    def __init__(self, objects: Sequence[str], msg: str | None = None,
                 census: dict | None = None):
        self.objects: tuple[str, ...] = tuple(objects)
        self.census: dict[str, dict[str, list[int]]] = dict(census or {})
        super().__init__(
            msg or ("all replicas lost or corrupt for "
                    f"{len(self.objects)} object(s): "
                    f"{list(self.objects[:8])}"
                    f"{'...' if len(self.objects) > 8 else ''}"))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Transient-fault retry budget for one client request (a per-OSD
    group call in the batched planes, or one hop of a per-object path):
    up to ``attempts`` tries with exponential backoff ``base_s * 2**k``
    capped at ``cap_s``, never sleeping past the per-request
    ``deadline_s`` (None = no deadline).  Exhaustion is terminal for
    THAT replica — the item fails over down its acting set like any
    other per-object miss.

    ``jitter="decorrelated"`` switches the actual sleeps to AWS-style
    decorrelated jitter — ``sleep_k = min(cap_s, U(base_s,
    3*sleep_{k-1}))`` — so many waiters hammered off the same recovering
    OSD spread out instead of thundering back in lockstep.  The RNG is
    seeded from ``(seed, salt)`` so schedules are reproducible per
    waiter yet distinct across waiters.  ``give_up`` stays deterministic
    (it budgets against the un-jittered ``backoff_s`` curve)."""

    attempts: int = 4
    base_s: float = 0.002
    cap_s: float = 0.1
    deadline_s: float | None = None
    jitter: str = "none"          # "none" | "decorrelated"
    seed: int | None = None

    def backoff_s(self, attempt: int) -> float:
        return min(self.cap_s, self.base_s * (2 ** attempt))

    def give_up(self, attempt: int, t0: float) -> bool:
        """No budget left: attempts spent, or the next backoff sleep
        would cross the request deadline."""
        if attempt + 1 >= self.attempts:
            return True
        return self.deadline_s is not None and (
            time.perf_counter() - t0 + self.backoff_s(attempt)
            > self.deadline_s)

    def backoff(self, salt: int = 0) -> "_Backoff":
        """A per-waiter sleep generator.  ``salt`` distinguishes
        concurrent waiters sharing one policy (the batched planes pass a
        fresh salt per group call)."""
        return _Backoff(self, salt)

    def schedule(self, n: int, salt: int = 0) -> list[float]:
        """The first ``n`` sleeps one waiter would take — for tests
        asserting boundedness / non-synchronization without sleeping."""
        boff = self.backoff(salt)
        return [boff.next_s() for _ in range(n)]


class _Backoff:
    """Stateful per-waiter backoff: deterministic exponential by
    default, decorrelated-jitter when the policy asks for it.  One
    instance per (request, replica) — never shared across threads."""

    def __init__(self, policy: RetryPolicy, salt: int = 0):
        self._policy = policy
        self._attempt = 0
        self._prev = 0.0
        if policy.jitter == "decorrelated":
            seed = (((policy.seed or 0) * 0x9E3779B1 + salt)
                    & 0xFFFFFFFF)
            self._rng: random.Random | None = random.Random(seed)
        else:
            self._rng = None

    def next_s(self) -> float:
        p = self._policy
        if self._rng is None:
            s = p.backoff_s(self._attempt)
            self._attempt += 1
            return s
        lo = p.base_s
        hi = max(lo, 3.0 * (self._prev if self._prev > 0.0 else lo))
        s = min(p.cap_s, self._rng.uniform(lo, hi))
        self._prev = s
        return s


class TokenBucket:
    """Byte-rate limiter for the maintenance daemons: ``consume(n)``
    debits ``n`` bytes against a bucket refilled at ``rate_bytes_s``
    and sleeps until the balance is non-negative, so background work
    (scrub verify, rebalance copies, compaction gathers) trickles at a
    bounded rate instead of saturating the modeled disks/fabric under
    foreground scans.  ``rate_bytes_s=None`` disables limiting.  Burst
    capacity is one rate-second, so a single object larger than the
    rate still passes (after proportional sleep) instead of wedging.
    Thread-safe; each daemon usually owns its own bucket."""

    def __init__(self, rate_bytes_s: float | None):
        self.rate = float(rate_bytes_s) if rate_bytes_s else None
        self._lock = threading.Lock()
        self._balance = self.rate or 0.0  # start with a full burst
        self._last = time.monotonic()

    def consume(self, nbytes: int) -> float:
        """Debit ``nbytes``; sleep off any deficit.  Returns the sleep
        actually paid (seconds) for observability/tests."""
        if self.rate is None or nbytes <= 0:
            return 0.0
        with self._lock:
            now = time.monotonic()
            self._balance = min(
                self.rate, self._balance + (now - self._last) * self.rate)
            self._last = now
            self._balance -= float(nbytes)
            deficit = -self._balance
        if deficit <= 0.0:
            return 0.0
        wait = deficit / self.rate
        time.sleep(wait)
        return wait


class PartialWriteError(ValueError):
    """A windowed ``put_batch`` producer mismatch (ended early, or
    yielded extra items) detected only AFTER earlier sub-writes already
    persisted with stamped versions.  ``persisted`` lists those
    ``(name, version)`` pairs — everything else in the batch is NOT
    durable — so the caller can reconcile (delete, adopt, or retry the
    remainder) instead of guessing what landed."""

    def __init__(self, msg: str, persisted=()):
        super().__init__(msg)
        self.persisted: tuple[tuple[str, int], ...] = tuple(persisted)


class _WriteLedger:
    """Client-side retained-blob accounting for ONE ``put_batch`` call:
    a materialized sub-write blob is pinned (in-batch failover may need
    to resend it) until the write AND its replica chain land, then
    released — so a windowed stream retains O(window) bytes, not
    O(batch).  ``peak_bytes`` is the bound the regression tests gate."""

    def __init__(self, n: int):
        self.blobs: list[bytes | None] = [None] * n
        self.sizes: list[int] = [0] * n
        self.peak_bytes = 0
        self._bytes = 0
        self._lock = threading.Lock()

    def pin(self, i: int, blob: bytes) -> None:
        self.blobs[i] = blob
        self.sizes[i] = len(blob)
        with self._lock:
            self._bytes += len(blob)
            self.peak_bytes = max(self.peak_bytes, self._bytes)

    def release(self, i: int) -> None:
        if self.blobs[i] is None:
            return
        self.blobs[i] = None
        with self._lock:
            self._bytes -= self.sizes[i]


class OSD:
    """One storage server: object data + xattrs + a local op executor.

    ``latency_s`` simulates slow media / stragglers (used by the hedged-
    read tests); ``disk_bw`` (bytes/s, None = instant) serializes write
    cost per OSD — parallel writers to different OSDs overlap, writers to
    the same OSD queue, which is what makes paper-Table-1-style scaling
    measurable in-process.  ``scan_bw`` (bytes/s, None = instant) is the
    serve-side twin: pipeline decode time serialized through one service
    queue per OSD, so scan contention shows up in wall clock (and in
    ``Fabric.queue_wait_s``) — cache hits skip the queue entirely.
    ``cache_bytes`` bounds this OSD's :class:`ResultCache` (0 disables).
    """

    # lock-discipline contract, machine-checked by ``repro.analysis``:
    # these attributes may only be read or written inside a ``with
    # <osd>.lock`` body (any holder of the OSD reference — the store,
    # the fault injector, the maintenance plane — plays by the same
    # rule, since writers mutate them concurrently on pool workers)
    _GUARDED_BY = {"data": "lock", "xattrs": "lock",
                   "quarantine": "lock"}

    def __init__(self, osd_id: str, disk_bw: float | None = None, *,
                 scan_bw: float | None = None, cache_bytes: int = 0):
        self.osd_id = osd_id
        self.data: dict[str, bytes] = {}
        self.xattrs: dict[str, dict] = {}
        self.latency_s: float = 0.0
        self.disk_bw = disk_bw
        self.scan_bw = scan_bw
        self.cache = ResultCache(cache_bytes)
        self._service = threading.Lock()  # modeled scan service queue
        self.lock = threading.Lock()
        # request-entry fault hook (core.faults.FaultInjector): fires
        # once per client request served by this OSD, may sleep (slow
        # OSD) or raise TransientOSDError (fail-N-then-succeed)
        self.faults = None
        # divergent copies pulled out of service by digest verification
        # (reads or scrub): name -> (blob, xattr); kept for post-mortems,
        # never served again
        self.quarantine: dict[str, tuple[bytes, dict]] = {}

    def _touch(self) -> None:
        """One served client request: pay the configured latency and
        give the fault injector its shot (slow answer / transient
        failure) BEFORE any data is read or written."""
        if self.faults is not None:
            self.faults.on_request(self.osd_id)
        if self.latency_s:
            time.sleep(self.latency_s)

    def _quarantine_copy(self, name: str) -> None:
        with self.lock:
            blob = self.data.pop(name, None)
            xattr = self.xattrs.pop(name, None)
            if blob is not None:
                self.quarantine[name] = (blob, xattr or {})
        # a quarantined copy must never be served, cached forms included
        self.cache.invalidate(name)

    def _verify_copy(self, name: str, blob: bytes) -> CorruptObject | None:
        """Digest-check one local copy before serving it.  A copy whose
        xattr carries no ``digest`` (legacy/native write) is
        unverifiable and served as-is; a mismatch quarantines the copy
        and returns the :class:`CorruptObject` for the caller to
        surface (per-object failover)."""
        with self.lock:
            want = (self.xattrs.get(name) or {}).get("digest")
        if want is None or content_digest(blob) == int(want):
            return None
        self._quarantine_copy(name)
        return CorruptObject(f"{name} on {self.osd_id}: stored bytes "
                             "diverge from stamped digest")

    # -- local primitives (called by ObjectStore only) --
    def put(self, name: str, blob: bytes, xattr: dict | None = None) -> None:
        self._touch()
        with self.lock:
            if self.disk_bw:
                time.sleep(len(blob) / self.disk_bw)  # serial disk
            self.data[name] = bytes(blob)
            if xattr is not None:
                self.xattrs[name] = dict(xattr)
        self.cache.invalidate(name)  # rewrite: cached forms are stale

    def put_batch(self, items: Sequence[tuple[str, bytes, dict | None]],
                  stream: Callable[[int], None] | None = None,
                  landed: Callable[[int], None] | None = None) -> None:
        """One batched write request: store every (name, blob, xattr)
        locally.  The per-request latency is paid ONCE for the whole
        batch; per-blob disk time is still serialized (one disk).

        ``stream`` models the arriving client byte stream: it is called
        with each item's size just before that item's disk write (the
        store passes its NIC-transfer hook), so the shared client NIC
        serializes per sub-write instead of stalling behind one
        monolithic transfer.  NIC and disk time stay additive per
        sub-write — the same serial transport model as a single ``put``
        — so batching cuts request count and per-request overhead, never
        payload physics.  ``landed`` is called with each item's batch
        index right after its disk write — the store hangs the
        per-object replica fan-out off it, so replication starts per
        object instead of waiting for the whole batch."""
        self._touch()
        for k, (name, blob, xattr) in enumerate(items):
            if stream is not None:
                stream(len(blob))
            with self.lock:
                if self.disk_bw:
                    time.sleep(len(blob) / self.disk_bw)  # serial disk
                self.data[name] = bytes(blob)
                if xattr is not None:
                    self.xattrs[name] = dict(xattr)
            self.cache.invalidate(name)  # rewrite: cached forms stale
            if landed is not None:
                landed(k)

    def get(self, name: str) -> bytes:
        self._touch()
        with self.lock:
            if name not in self.data:
                raise ObjectNotFound(name)
            blob = self.data[name]
        bad = self._verify_copy(name, blob)
        if bad is not None:
            raise bad
        return blob

    def exec_cls(self, name: str, ops: list[ObjOp]) -> Any:
        """Run an objclass pipeline against a local object (SkyhookDM
        extension / custom read method)."""
        blob = self.get(name)
        ops = self._resolved(name, normalize_exprs(ops), clamp=True)
        return run_pipeline(blob, ops), len(blob)

    def compact_merge(self, blobs: Sequence[bytes], out_name: str,
                      xattr: dict | None = None) -> tuple[bytes, dict]:
        """OSD-side merge op (``objclass.compact_merge``): fold a run of
        consecutive small blocks into ONE block stored locally under
        ``out_name``, stamping a fresh zone map and content digest into
        its xattrs so the merged copy is verifiable and prunable like
        any written object.  Returns ``(blob, stamped_xattr)`` so the
        caller can replicate the merged object down the chain without
        re-reading it."""
        self._touch()
        blob, zm = _compact_merge_blocks(list(blobs))
        stamped = dict(xattr or {})
        stamped["zone_map"] = zm
        stamped["digest"] = content_digest(blob)
        with self.lock:
            if self.disk_bw:
                time.sleep(len(blob) / self.disk_bw)  # serial disk
            self.data[out_name] = bytes(blob)
            self.xattrs[out_name] = stamped
        self.cache.invalidate(out_name)
        return blob, stamped

    def _extent(self, name: str) -> tuple[int, int] | None:
        """The object's CURRENT row extent from its own ``rows`` xattr
        (written by the VOL write path) — what a pushed-down
        ``row_slice`` resolves against."""
        with self.lock:
            x = self.xattrs.get(name)
        r = (x or {}).get("rows")
        return (int(r[0]), int(r[1])) if r else None

    def _resolved(self, name: str, ops: list[ObjOp],
                  clamp: bool = False) -> list[ObjOp] | None:
        """Resolve any ``row_slice`` op (GLOBAL dataset rows) against
        the object's CURRENT extent xattr.  None (only when ``clamp``
        is False) means the slice is provably disjoint from the extent:
        the object serves no rows — a prune-equivalent skip."""
        if not has_row_slice(ops):
            return ops
        ext = self._extent(name)
        if ext is None:
            raise ValueError(
                f"{name}: row_slice needs the object's extent ('rows' "
                "xattr, written by the VOL write path) to resolve")
        return resolve_row_slice(ops, ext, clamp=clamp)

    def _snapshot_copy(
            self, name: str) -> tuple[bytes | None, dict | None]:
        """One local copy AND its xattr under a single lock acquisition
        — the batched serve plane works from this snapshot so a
        concurrent writer can never pair one version's blob with
        another version's extent/digest mid-request."""
        with self.lock:
            blob = self.data.get(name)
            x = self.xattrs.get(name)
            return blob, (dict(x) if x is not None else None)

    def _pay_service(self, nbytes: int, meters: dict) -> None:
        """Pay the modeled decode service for one scanned blob: decode
        time (``nbytes / scan_bw``) serialized through this OSD's one
        service queue.  Time spent blocked behind other scans is the
        request's queue wait; cache hits never call this — skipping the
        queue is the latency win the serve plane buys."""
        if not self.scan_bw or nbytes <= 0:
            return
        t0 = time.perf_counter()
        with self._service:
            meters["queue_wait_s"] += time.perf_counter() - t0
            time.sleep(nbytes / self.scan_bw)

    def _decoded_table(self, name: str, version, blob: bytes,
                       resolved: list[ObjOp],
                       meters: dict) -> tuple[dict, int]:
        """The decoded column table a pipeline needs, through the
        decode-level cache (shared across pipelines that read the same
        columns).  Returns ``(table, scanned_bytes)`` — 0 scanned when
        the decode was elided (no storage bytes were read)."""
        key = None
        if self.cache.capacity > 0 and version is not None:
            cols = required_columns(resolved)
            key = (name, int(version), "cols",
                   tuple(cols) if cols is not None else None)
            got = self.cache.get(key)
            if got is not _MISS:
                return got, 0
        self._pay_service(len(blob), meters)
        table = decode_pipeline(blob, resolved)
        if key is not None:
            ev, ins = self.cache.put(key, table, _result_nbytes(table))
            meters["cache_evictions"] += ev
            meters["cache_bytes"] += ins
        return table, len(blob)

    def _serve_item(self, name: str, ops: list[ObjOp], kind: str,
                    dig: str | None, meters: dict, *,
                    clamp: bool = False, encode: bool = True,
                    prune=None, pdig: str | None = None
                    ) -> tuple[str, Any, int]:
        """Serve one item of a batched objclass request through the
        result cache.  Returns ``(status, payload, scanned_bytes)``
        with status one of ``"ok"`` (payload = pipeline result),
        ``"missing"`` (absent here), ``"corrupt"`` (payload = the
        :class:`CorruptObject`; the copy is quarantined), or ``"skip"``
        (row slice provably disjoint — prune-equivalent).

        ``kind`` namespaces the result-cache key per response mode
        (plain/combine/concat clamp and encode differently, so one
        pipeline digest can map to different payloads).  Cached entries
        are keyed by the snapshot's monotonic version: any write, heal,
        or compaction bumps it, so an entry can never be served across
        a version bump — and every entry was derived from a
        digest-verified blob at insert time.

        ``prune`` (with its digest ``pdig``) is the request's pushdown
        expression: a hyperslab pipeline resolves it against per-chunk
        zone maps, so for those items it becomes part of the result's
        identity — the cache key digest is extended with ``pdig`` and
        the chunk-prune work is metered as ``chunks_pruned``.  A
        nothing-to-serve outcome (absent object, disjoint slice, every
        chunk pruned) is *negatively* cached under the same versioned
        key scheme (version -1 for absence, retired by the eager
        invalidation every write path performs), so a replay skips
        digest verification and op resolution — metered ``neg_hits``."""
        if prune is not None and dig is not None and has_hyperslab(ops):
            dig = f"{dig}|{pdig}"  # result content depends on the prune
        if self.cache.capacity > 0 and dig is not None:
            got = self.cache.get((name, -1, kind + "#neg", dig))
            if isinstance(got, _Negative):
                meters["neg_hits"] += 1
                return got.reason, None, 0
        blob, xattr = self._snapshot_copy(name)
        if blob is None:
            if self.cache.capacity > 0 and dig is not None:
                self.cache.put_negative(
                    (name, -1, kind + "#neg", dig), "missing")
            return "missing", None, 0
        version = (xattr or {}).get("version")
        key = negkey = None
        if (self.cache.capacity > 0 and version is not None
                and dig is not None):
            key = (name, int(version), kind, dig)
            got = self.cache.get(key)
            if got is not _MISS:
                meters["cache_hits"] += 1
                return "ok", got, 0
            negkey = (name, int(version), kind + "#neg", dig)
            got = self.cache.get(negkey)
            if isinstance(got, _Negative):
                meters["neg_hits"] += 1
                return got.reason, None, 0
        # miss: digest-verify THIS snapshot's blob, resolve any row
        # slice against the SAME snapshot's extent, then decode
        want = (xattr or {}).get("digest")
        if want is not None and content_digest(blob) != int(want):
            self._quarantine_copy(name)
            return "corrupt", CorruptObject(
                f"{name} on {self.osd_id}: stored bytes diverge from "
                "stamped digest"), 0
        if has_row_slice(ops):
            r = (xattr or {}).get("rows")
            if r is None:
                if xattr is None:  # TORN write: blob landed, xattr not
                    self._quarantine_copy(name)
                    return "corrupt", CorruptObject(
                        f"{name} on {self.osd_id}: torn write (blob "
                        "landed, xattr missing) cannot serve a row "
                        "slice"), 0
                raise ValueError(  # bare extent-less xattr: caller misuse
                    f"{name}: row_slice needs the object's extent "
                    "('rows' xattr, written by the VOL write path) to "
                    "resolve")
            resolved = resolve_row_slice(
                ops, (int(r[0]), int(r[1])), clamp=clamp)
            if resolved is None:
                if negkey is not None:
                    self.cache.put_negative(negkey, "skip")
                return "skip", None, 0
        else:
            resolved = ops
        if has_hyperslab(resolved):
            ch = (xattr or {}).get("chunks")
            if ch is None:
                if xattr is None:  # TORN write: blob landed, xattr not
                    self._quarantine_copy(name)
                    return "corrupt", CorruptObject(
                        f"{name} on {self.osd_id}: torn write (blob "
                        "landed, xattr missing) cannot serve a "
                        "hyperslab"), 0
                raise ValueError(
                    f"{name}: hyperslab_slice needs the object's chunk "
                    "extent ('chunks' xattr, written by the VOL array "
                    "write path) to resolve")
            resolved, n_chunks_pruned = resolve_hyperslab(
                resolved, (int(ch[0]), int(ch[1])),
                chunk_zone_maps=(xattr or {}).get("chunk_zone_maps"),
                where=prune, clamp=clamp)
            meters["chunks_pruned"] += n_chunks_pruned
            if resolved is None:
                if negkey is not None:
                    self.cache.put_negative(negkey, "skip")
                return "skip", None, 0
        if resolved and resolved[0].name == "select_packed":
            # packed row-copy works on the raw blob — no decoded table
            # to share, so it bypasses the decode-level cache
            self._pay_service(len(blob), meters)
            result = run_pipeline(blob, resolved, encode=encode)
            scanned = len(blob)
        else:
            table, scanned = self._decoded_table(
                name, version, blob, resolved, meters)
            result = apply_pipeline(table, resolved, encode=encode)
        if key is not None:
            meters["cache_misses"] += 1
            ev, ins = self.cache.put(key, result, _result_nbytes(result))
            meters["cache_evictions"] += ev
            meters["cache_bytes"] += ins
        return "ok", result, scanned

    def _prunes_locally(self, name: str, prune, pdig: str | None = None,
                        meters: dict | None = None) -> bool:
        """Pushed-down prune: does this object's CURRENT local zone map
        prove the filter expression matches none of its rows?  Runs
        against the OSD's own xattrs, so the decision can never be
        stale — there is no client cache (and no plan→execute TOCTOU
        window) in the loop.

        With ``pdig`` (the request prune expression's digest) the
        decision itself is cached per ``(name, version, pdig)`` — a
        version bump retires it like any result entry — so a repeat
        scan of a pruned object skips the tree walk; replayed *pruned*
        verdicts are metered ``neg_hits``."""
        if prune is None:
            return False
        with self.lock:
            x = self.xattrs.get(name)
        if x is None:
            return False
        key = None
        if (pdig is not None and self.cache.capacity > 0
                and x.get("version") is not None):
            key = (name, int(x["version"]), "prune", pdig)
            got = self.cache.get(key)
            if got is not _MISS:
                if got and meters is not None:
                    meters["neg_hits"] += 1
                return bool(got)
        verdict = zone_map_prunes(x.get("zone_map", {}), prune)
        if key is not None:
            self.cache.put(key, verdict, _Negative.NBYTES)
        return verdict

    def exec_cls_batch(
            self, items: Sequence[tuple[str, list[ObjOp]]],
            combine: bool = False, concat: bool = False,
            prune=None) -> Any:
        """One batched objclass request: run each (name, pipeline) item
        against local data.  The per-request latency is paid ONCE for
        the whole batch — that is the round-trip amortization batching
        buys.  Per-item failures come back as ``ObjectNotFound`` values
        (not raises) so the rest of the batch still completes.

        ``prune`` is an optional filter-expression tree (the serialized
        wire dict of ``expr.Expr``, or the legacy tuple of
        (col, cmp, value) triples) pushed down with the request: before
        scanning an object the OSD consults its local zone-map xattr
        and skips objects the expression provably cannot match — the
        pruned names ride back in the response (they are a semantic
        skip, not an absence, so the client must not fail them over).
        Only the combine/concat forms accept it (plain responses are
        positional).  A ``row_slice`` op in a pipeline is resolved here
        against each object's own extent xattr; an object whose extent
        is disjoint from the slice is skipped the same prune-equivalent
        way (combine/concat) or serves zero rows (plain batch).

        Every served copy is verified against its stamped content
        digest first; a divergent (or torn, under a row slice) copy is
        quarantined and reported in the response's ``corrupt_names`` —
        the client retries those objects on their next replica exactly
        like missing ones, and counts them in
        ``Fabric.corruptions_detected``.

        With ``combine=True`` the items must share one decomposable
        pipeline whose tail has an associative ``merge``: the OSD folds
        its local partials into ONE and returns a
        ``(partial|None, n_found, scanned_bytes, missing_names,
        pruned_names, corrupt_names)`` tuple — a single partial leaves
        the OSD per request, not one per object (the server-side half
        of the two-level combine).

        With ``concat=True`` every item's pipeline must be table-out:
        the OSD concatenates the per-object result tables (item order)
        and encodes them as ONE framed block, returning
        ``(blob|None, served_indices, row_counts, scanned_bytes,
        missing_names, pruned_names, corrupt_names)`` — the table-out
        half of the same symmetry, bounding per-OSD response framing at
        one frame.

        Every response additionally carries a trailing serve-meters
        dict (``_serve_meters()``): per-request cache hit/miss/eviction
        and queue-wait deltas, folded into the fabric by the client
        thread that issued the call.  Results are served through this
        OSD's :class:`ResultCache` when it is enabled — a hit skips
        digest re-verification, decode, AND the modeled service queue
        (the entry was derived from a digest-verified blob at the same
        monotonic version, so the bytes are provably identical), and
        reports 0 scanned bytes because no storage bytes were read.
        """
        if combine and concat:
            raise ValueError("combine and concat are exclusive")
        self._touch()
        prune = ex.ensure_pred(prune)  # parse the wire form ONCE
        # ...and likewise each pipeline's serialized filter trees (a
        # shared pipeline object is normalized once for the whole batch)
        norm: dict[int, list[ObjOp]] = {}
        items = [(name,
                  norm[id(ops)] if id(ops) in norm
                  else norm.setdefault(id(ops), normalize_exprs(ops)))
                 for name, ops in items]
        meters = _serve_meters()
        # the prune expression's own digest: keys cached prune verdicts
        # and extends hyperslab result keys (their content depends on it)
        pdig = None
        if prune is not None and self.cache.capacity > 0:
            pdig = hashlib.sha1(_json.dumps(
                prune.to_json(), sort_keys=True,
                separators=(",", ":")).encode()).hexdigest()
        # one digest per distinct pipeline object (shared pipelines are
        # common: combine/concat batches reuse ONE list for all items)
        digs: dict[int, str] = {}

        def dig_of(ops: list[ObjOp]) -> str | None:
            if self.cache.capacity <= 0:
                return None  # cache off: skip the hashing entirely
            d = digs.get(id(ops))
            if d is None:
                d = digs.setdefault(id(ops), pipeline_digest(ops))
            return d

        if not combine and not concat:
            if prune is not None:
                raise ValueError("prune needs combine or concat "
                                 "(plain batch responses are positional)")
            out: list[Any] = []
            for name, ops in items:
                status, payload, scanned = self._serve_item(
                    name, ops, "plain", dig_of(ops), meters, clamp=True)
                if status == "missing":
                    out.append(ObjectNotFound(name))
                elif status == "corrupt":
                    out.append(payload)  # quarantined: per-item failover
                else:  # "skip" cannot happen under clamp=True
                    out.append((payload, scanned))
            return out, meters

        pruned: list[str] = []
        missing: list[str] = []
        corrupt: list[str] = []
        scanned = 0
        if concat:
            tables: list[dict] = []
            served: list[int] = []
            counts: list[int] = []
            for k, (name, ops) in enumerate(items):
                if self._prunes_locally(name, prune, pdig, meters):
                    pruned.append(name)
                    continue
                status, out, nb = self._serve_item(
                    name, ops, "concat", dig_of(ops), meters,
                    encode=False, prune=prune, pdig=pdig)
                if status == "missing":  # absent HERE: registers as
                    missing.append(name)  # missing (replica failover),
                    continue  # even if a row slice might have skipped it
                if status == "corrupt":
                    corrupt.append(name)  # quarantined: replica failover
                    continue
                if status == "skip":  # row slice disjoint: no rows here
                    pruned.append(name)
                    continue
                if not isinstance(out, dict) or (
                        ops and not _impl(ops[-1].name).table_out):
                    raise ValueError("concat needs table-out pipelines")
                scanned += nb
                tables.append(out)
                served.append(k)
                counts.append(table_n_rows(out))
            frame = concat_encode(tables) if tables else None
            return (frame, tuple(served), tuple(counts), scanned,
                    tuple(missing), tuple(pruned), tuple(corrupt),
                    meters)

        ops = items[0][1]
        partials: list[Any] = []
        for name, _ in items:
            if self._prunes_locally(name, prune, pdig, meters):
                pruned.append(name)
                continue
            status, partial, nb = self._serve_item(
                name, ops, "combine", dig_of(ops), meters,
                prune=prune, pdig=pdig)
            if status == "missing":  # absent HERE: replica failover
                missing.append(name)
                continue
            if status == "corrupt":
                corrupt.append(name)  # quarantined: replica failover
                continue
            if status == "skip":  # row slice disjoint: no rows here
                pruned.append(name)
                continue
            partials.append(partial)
            scanned += nb
        merged = merge_partials(ops, partials) if partials else None
        return (merged, len(partials), scanned, tuple(missing),
                tuple(pruned), tuple(corrupt), meters)

    def list_xattrs(self, names: Sequence[str]) -> dict[str, dict]:
        """One batched metadata request: the xattrs of every local object
        among ``names`` (absent names are simply omitted).  Request
        latency is paid once for the whole listing."""
        self._touch()
        out: dict[str, dict] = {}
        for name in names:
            with self.lock:
                x = self.xattrs.get(name)
            if x is not None:
                out[name] = dict(x)
        return out

    def nbytes(self) -> int:
        with self.lock:
            return sum(len(b) for b in self.data.values())

    def object_names(self) -> set[str]:
        with self.lock:
            return set(self.data)


class ObjectStore:
    """The cluster: cluster map + OSD daemons + client entry points.

    ``client_bw`` (bytes/s, None = instant) models the client's shared
    NIC: all client<->OSD transfers serialize through one link, so
    parallel writers amortize OSD work but not the forwarding hop — the
    paper's Table-1 structure.
    """

    # lock-discipline contract (see ``repro.analysis``): the monotonic
    # write clock is bumped by every writer thread concurrently
    _GUARDED_BY = {"_vclock": "_lock"}

    def __init__(self, cluster: ClusterMap, *,
                 client_bw: float | None = None,
                 disk_bw: float | None = None,
                 scan_bw: float | None = None,
                 cache_bytes: int = 0,
                 replication: str = "chain",
                 hop_latency_s: float = 0.0,
                 retry: RetryPolicy | None = None):
        if replication not in ("chain", "fanout"):
            raise ValueError(f"bad replication topology {replication!r}; "
                             "known: ('chain', 'fanout')")
        self.cluster = cluster
        self.client_bw = client_bw
        self.disk_bw = disk_bw
        # serve-plane knobs (per OSD): modeled scan/decode bandwidth
        # and the result-cache byte bound — 0 disables caching, which
        # is the default so cold stores pay nothing
        self.scan_bw = scan_bw
        self.cache_bytes = int(cache_bytes or 0)
        self.replication = replication
        # modeled OSD->OSD forwarding delay per replication hop (0 =
        # latency-free, the pre-existing behavior): chain hops pay it
        # sequentially, fan-out pays it once — see _replicate
        self.hop_latency_s = float(hop_latency_s or 0.0)
        # transient-fault budget for every client request (see
        # RetryPolicy); injectable per store so tests/benchmarks can
        # tighten the deadline or disable backoff
        self.retry = retry or RetryPolicy()
        # per-waiter salt for jittered backoff: each retry loop takes a
        # fresh value so concurrent waiters get distinct sleep schedules
        self._salt = itertools.count()
        # the attached FaultInjector (core.faults), if any — kept here
        # so fail_osd/add_osds re-wire replacement OSD objects to it
        self.faults = None
        # the attached MaintenancePlane (core.maintenance), if any —
        # fail_osd/add_osds notify it so the rebalancer wakes up, and
        # close() stops its daemons
        self.maintenance = None
        self.osds: dict[str, OSD] = {
            o: OSD(o, disk_bw, scan_bw=scan_bw,
                   cache_bytes=self.cache_bytes)
            for o in cluster.osds}
        self.fabric = Fabric()
        self._lock = threading.Lock()
        self._nic = threading.Lock()
        # monotonic write clock: every put stamps its object's xattr
        # with a fresh ``version`` so ANY client can detect that a
        # cached zone map is stale (cross-client coherence)
        self._vclock = 0
        # persistent scatter/gather executor for every batched plane —
        # no per-call ThreadPoolExecutor churn.  Sized at 2x the OSD
        # count so windowed ingest can hold one streaming request per
        # primary OSD AND still run the per-object replica chains that
        # hang off their ``landed`` hooks concurrently.
        self._pool = ThreadPoolExecutor(
            max_workers=max(8, 2 * len(self.osds)),
            thread_name_prefix="store-io")
        # hedged reads get their own small persistent pool: an abandoned
        # straggler parks on a worker for its full latency and must not
        # starve exec_batch dispatch on the main pool
        self._hedge_pool = ThreadPoolExecutor(
            max_workers=4, thread_name_prefix="store-hedge")
        # observability for the write ledger: the peak retained-blob
        # bytes of the most recent put_batch on THIS store (windowed
        # streams stay O(window); per-call, so concurrent writers
        # should read it between their own calls)
        self.last_put_ledger_peak_bytes = 0
        # the window-size trajectory of the most recent adaptive
        # put_batch (one entry per retarget) — same per-call caveat
        self.last_adaptive_windows: tuple[int, ...] = ()

    def close(self) -> None:
        if self.maintenance is not None:
            try:
                self.maintenance.stop()
            except Exception:
                pass
        self._pool.shutdown(wait=False)
        self._hedge_pool.shutdown(wait=False)

    def __del__(self):  # release pool threads when the store dies
        try:
            self.close()
        except Exception:
            pass

    def _client_xfer(self, nbytes: int) -> None:
        if self.client_bw:
            with self._nic:  # one NIC: transfers serialize
                time.sleep(nbytes / self.client_bw)

    def _account_request(self) -> None:
        """One client<->OSD round trip: an op + its fixed overhead."""
        self.fabric.ops += 1
        self.fabric.overhead_bytes += PER_REQUEST_OVERHEAD_BYTES

    def _apply_meters(self, m: dict) -> None:
        """Fold one batched response's serve meters into the fabric —
        always on the client thread that issued the request (the OSD
        serve path may have run on a pool worker, which never touches
        fabric counters)."""
        f = self.fabric
        f.cache_hits += m["cache_hits"]
        f.cache_misses += m["cache_misses"]
        f.cache_evictions += m["cache_evictions"]
        f.cache_bytes += m["cache_bytes"]
        f.queue_wait_s += m["queue_wait_s"]
        f.cache_neg_hits += m.get("neg_hits", 0)
        f.chunks_pruned += m.get("chunks_pruned", 0)

    def io_simulated(self) -> bool:
        """True when requests actually *wait* (NIC/disk bandwidth or OSD
        latency is modeled).  Only then is thread fan-out worth it —
        pure in-process compute runs faster sequentially (GIL)."""
        return bool(self.client_bw or self.disk_bw or self.scan_bw
                    or any(o.latency_s for o in self.osds.values()))

    def default_window_bytes(self) -> int | None:
        """The ingest window callers should pass to ``put_batch`` when
        they have no opinion: windowed streaming only pays off when
        transfers actually take time — pure in-process writes run
        faster through the buffered path (no feeder threads)."""
        return DEFAULT_WINDOW_BYTES if self.io_simulated() else None

    def _replicate(self, name: str, blob: bytes, xattr: dict,
                   acting: Sequence[str],
                   entry: str | None = None) -> tuple[int, int, float]:
        """Server-side replication of one landed write from ``entry``
        (the OSD that took it — the primary, or a later replica after
        failover) across the rest of the acting set; returns
        ``(total_bytes_moved, bytes_sent_by_entry, latency_s)`` for the
        caller to charge to ``replica_bytes`` / ``entry_egress_bytes``
        / ``replica_lat_s`` — counters are never touched from
        replication worker threads (lost-update hazard under concurrent
        ``+=``).

        ``hop_latency_s`` models the per-hop forwarding delay and makes
        the chain-vs-fanout *latency* tradeoff observable next to the
        bandwidth one: a chain is store-and-forward, so its hops
        serialize (latency = transferred_hops x hop; each hop sleeps in
        turn on the replication worker), while fan-out sends in
        parallel from the entry OSD (latency = one hop regardless of
        replica count) — the exact mirror of the egress asymmetry
        ``entry_egress_bytes`` exposes, where the chain wins.

        ``chain`` (default) pipelines entry -> replica -> replica, the
        way Ceph forwards primary-copy writes: each hop moves the blob
        once and only the FIRST hop leaves the entry OSD, so the entry's
        egress is one blob regardless of the replica count (half the
        fan-out egress at 3x replication) — tracked separately in
        ``entry_egress_bytes``.  A down OSD mid-chain is skipped and the
        chain continues from the last OSD that holds the blob (per-
        object failover; peering re-replicates the skipped copy later),
        so only hops that actually transferred are charged.

        ``fanout`` is the legacy topology: the entry OSD sends to every
        replica directly (entry egress = (replicas - 1) blobs).
        """
        entry = acting[0] if entry is None else entry
        sender = entry
        moved = entry_moved = 0
        lat = 0.0
        hop = float(self.hop_latency_s or 0.0)
        for rep in acting:
            if rep == entry:
                continue
            try:
                if hop and self.replication == "chain":
                    time.sleep(hop)  # store-and-forward: hops serialize
                self._hop_put(rep, name, blob, xattr)
            except (OSDDown, TransientOSDError):
                continue  # skipped hop: peering/recovery heals it
            moved += len(blob)
            if hop and self.replication == "chain":
                lat += hop
            if self.replication == "fanout" or sender == entry:
                entry_moved += len(blob)
            if self.replication == "chain":
                sender = rep  # the new tail forwards the next hop
        if hop and self.replication != "chain" and moved:
            time.sleep(hop)  # parallel sends: ONE hop of latency
            lat = hop
        return moved, entry_moved, lat

    def _hop_put(self, osd_id: str, name: str, blob: bytes,
                 xattr: dict | None) -> None:
        """One OSD->OSD replication/heal hop, retrying transient faults
        in place (the hop runs on a replication worker, so the backoff
        sleep never blocks the client; fabric counters are untouched
        here).  Exhausted budgets re-raise and the hop is skipped like
        a down OSD — peering/scrub heals the copy later."""
        boff = self.retry.backoff(salt=next(self._salt))
        for attempt in range(max(1, self.retry.attempts)):
            try:
                return self._osd(osd_id).put(name, blob, xattr)
            except TransientOSDError:
                if attempt + 1 >= max(1, self.retry.attempts):
                    raise
                time.sleep(boff.next_s())

    # ------------------------------------------------------------ helpers
    def _acting(self, name: str) -> tuple[str, ...]:
        s = self.cluster.locate(name)
        if not s:
            raise OSDDown("no up OSDs for " + name)
        return s

    def _osd(self, osd_id: str) -> OSD:
        if osd_id in self.cluster.down:
            raise OSDDown(osd_id)
        return self.osds[osd_id]

    def _next_version(self) -> int:
        with self._lock:
            self._vclock += 1
            return self._vclock

    def _next_targets(self, pending: list[int], names: list[str],
                      tried: list[set],
                      last_err: list | None = None,
                      skipped: list[int] | None = None
                      ) -> list[tuple[str, list[int]]]:
        """Group pending item indices by their next untried acting OSD —
        the shared regrouping step of every batched plane's failover
        loop.  An item with no replicas left either raises its last
        error (default, mirroring the per-object paths) or is appended
        to ``skipped`` when the caller tolerates absence."""
        groups: dict[str, list[int]] = {}
        for i in pending:
            acting = self._acting(names[i])
            target = next((o for o in acting if o not in tried[i]), None)
            if target is None:
                if skipped is not None:
                    skipped.append(i)
                    continue
                err = last_err[i] if last_err is not None else None
                if isinstance(err, CorruptObject):
                    # not mere absence: the last surviving copy failed
                    # digest verification — the object is GONE, loudly
                    raise DataLossError(
                        [names[i]],
                        f"{names[i]}: every replica lost or corrupt "
                        f"(last: {err})",
                        census=self.copy_census([names[i]]))
                raise err or ObjectNotFound(names[i])
            groups.setdefault(target, []).append(i)
        # one order for dispatch AND result pairing — keep them the same
        return sorted(groups.items())

    def _retrying(self, run_group):
        """Wrap a per-OSD group call with the store's transient-fault
        policy: a :class:`TransientOSDError` escaping the group (the
        OSD dropped THIS request, it is not down) sleeps a bounded
        exponential backoff and re-issues, until the attempt budget or
        the per-request deadline runs out — then the error is returned
        as the group result (terminal for that replica; the items fail
        over down their acting sets like any whole-request failure).
        Returns ``(result, n_retries)`` so the CALLER thread can
        account ``Fabric.retries`` (wrapped calls may run on pool
        workers, which never touch fabric counters)."""
        policy = self.retry

        def run(osd_id, idxs):
            t0 = time.perf_counter()
            retries = 0
            boff = policy.backoff(salt=next(self._salt))
            while True:
                try:
                    return run_group(osd_id, idxs), retries
                except TransientOSDError as e:
                    if policy.give_up(retries, t0):
                        return e, retries
                    time.sleep(boff.next_s())
                    retries += 1
        return run

    def _dispatch_groups(self, ordered, run_group) -> list:
        """Fan the per-OSD group requests out on the persistent pool —
        but only when requests actually block on simulated I/O; compute-
        bound groups run inline (threads just add GIL contention).
        Transient faults retry inside each group call (``_retrying``);
        the retry count accrues to ``Fabric.retries`` here, on the
        caller's thread."""
        run = self._retrying(run_group)
        if len(ordered) == 1 or not self.io_simulated():
            outs = [run(osd_id, idxs) for osd_id, idxs in ordered]
        else:
            futs = [self._pool.submit(run, osd_id, idxs)
                    for osd_id, idxs in ordered]
            outs = [f.result() for f in futs]
        results = []
        for got, retries in outs:
            self.fabric.retries += retries
            results.append(got)
        return results

    def _scatter_iter(self, names: list[str], run_group, handle,
                      stream: bool = False,
                      completion_order: bool | None = None
                      ) -> Iterator[Any]:
        """The shared replica-failover skeleton of the batched read
        planes (``exec_batch`` / ``exec_combine`` / ``exec_concat``),
        as a generator: group pending items by their next untried
        acting OSD, dispatch one batched request per group, account the
        round trip, and let ``handle`` consume each per-group response
        — returning ``(retry_indices, emitted_items)``.  Under
        ``completion_order`` (default: follows ``stream``) emitted
        items are yielded the moment THEIR group's response lands, so a
        streaming consumer decodes early frames while slower OSDs are
        still scanning; otherwise groups are consumed in dispatch
        (sorted-OSD) order, which keeps order-sensitive reductions —
        float partial folds — bit-deterministic run to run.  Under
        ``stream=True`` each delivered item also counts in
        ``Fabric.stream_windows``.  A whole-request failure (OSD down)
        retries every item of its group."""
        if completion_order is None:
            completion_order = stream
        tried: list[set[str]] = [set() for _ in names]
        last_err: list[Exception | None] = [None] * len(names)
        pending = list(range(len(names)))
        run = self._retrying(run_group)  # transient backoff per group
        while pending:
            ordered = self._next_targets(pending, names, tried, last_err)
            pending = []
            if len(ordered) == 1 or not self.io_simulated():
                completions = ((pair, run(*pair))
                               for pair in ordered)
            else:
                futs = {self._pool.submit(run, o, idxs): (o, idxs)
                        for o, idxs in ordered}
                completions = ((futs[f], f.result())
                               for f in (as_completed(futs)
                                         if completion_order else futs))
            for (osd_id, idxs), (got, retries) in completions:
                self._account_request()  # one round trip per OSD group
                self.fabric.retries += retries
                for i in idxs:
                    tried[i].add(osd_id)
                if isinstance(got, Exception):
                    for i in idxs:
                        last_err[i] = got
                    pending.extend(idxs)
                    continue
                retry, emitted = handle(idxs, got, last_err)
                pending.extend(retry)
                for item in emitted:
                    if stream:
                        self.fabric.stream_windows += 1
                    yield item

    # ------------------------------------------------------------ client IO
    def put(self, name: str, blob: bytes, xattr: dict | None = None) -> int:
        """Replicated write: client -> primary -> replica chain.  Client
        pays one transfer; replication is server-side (``_replicate``:
        chain-pipelined by default, matching Ceph's primary-copy
        forwarding).  The object's xattr is stamped with a fresh
        monotonic ``version``, which is returned.  The xattr also gets
        a content ``digest`` of the blob, so every replica (the chain
        forwards blob AND xattr together) is independently verifiable
        by reads, ``scrub()`` and ``recover()``."""
        version = self._next_version()
        stamped = {**(xattr or {}), "version": version,
                   "digest": content_digest(blob)}
        acting = self._acting(name)
        self.fabric.client_tx += len(blob)
        self._account_request()
        self._client_xfer(len(blob))
        self._osd(acting[0]).put(name, blob, stamped)
        # replication is OSD->OSD (cluster network), not client bytes
        moved, entry_moved, lat = self._replicate(
            name, blob, stamped, acting)
        self.fabric.replica_bytes += moved
        self.fabric.entry_egress_bytes += entry_moved
        self.fabric.replica_lat_s += lat
        return version

    def put_batch(self, names: Iterable[str],
                  blobs: Iterable[bytes | tuple[bytes, dict | None]],
                  xattrs: Sequence[dict | None] | None = None, *,
                  window_bytes: int | str | None = None,
                  window_objects: int | None = None) -> list[int]:
        """Batched replicated write: ONE client request per primary OSD.

        Sub-writes are grouped by their primary OSD and each group goes
        out as a single ``OSD.put_batch`` round trip, so ingesting N
        objects over K OSDs costs K fabric ops instead of N.
        Replication stays server-side per object (``_replicate``: the
        entry OSD chain-forwards down the acting set the moment that
        object's primary write lands, charged to ``replica_bytes`` /
        ``entry_egress_bytes``).  Objects whose group request failed
        (entry OSD down mid-batch) are re-grouped onto their next
        untried replica and retried as fresh batched requests —
        per-object failover inside the batch, mirroring ``exec_batch``.

        **Windowed streaming mode** (``window_bytes`` and/or
        ``window_objects``): ``blobs`` may be a lazy iterable — a
        generator still *encoding* — and sub-writes flush to ONE
        long-lived streaming request per primary OSD as each window
        fills, so client-side encode overlaps the NIC stream instead of
        buffering the whole batch first.  Still exactly one fabric op
        per OSD touched (the stream is one request), identical payload
        accounting, and bit-identical stored bytes; each flushed
        per-OSD sub-write group counts in ``Fabric.stream_windows`` and
        the encode time hidden behind an active stream accrues to
        ``Fabric.overlap_s``.  In this mode an element of ``blobs`` may
        also be a ``(blob, xattr)`` pair, letting one generator produce
        payload and metadata together (``xattrs`` entries are the
        fallback).  Sub-writes whose stream died mid-flight fail over
        through the buffered retry rounds — their blobs are still
        pinned in the write ledger.  The ledger releases each blob the
        moment its write AND replica chain land (no retry can resend
        it), so a long stream retains O(window) bytes, not O(batch) —
        ``last_put_ledger_peak_bytes`` records the peak.  Length
        validation is necessarily lazy here: a producer that ends early
        (or yields extra items) raises :class:`PartialWriteError` only
        once the mismatch is SEEN — after the already-produced
        sub-writes persisted with stamped versions; the exception's
        ``persisted`` lists those (name, version) pairs so the caller
        can reconcile — unlike the buffered path, which validates
        before writing anything.

        ``window_bytes="adaptive"`` sizes the window from the observed
        encode-rate/NIC-rate ratio: each flushed window's encode time
        retargets the next as ``W_next = W * encode_rate / client_bw``
        (clamped to ``ADAPTIVE_WINDOW_FLOOR``..``ADAPTIVE_WINDOW_CAP``)
        so the encoder stays exactly one window ahead of the NIC — a
        fast encoder gets big windows (less flush overhead), a slow one
        small windows (the NIC never starves).  Starts at the static
        8 MB ``DEFAULT_WINDOW_BYTES``, which is also the unconditional
        fallback when ``client_bw`` is unset (no NIC rate to target).
        The retarget trajectory is recorded in
        ``last_adaptive_windows``.

        Every object's xattr is stamped with a fresh monotonic
        ``version`` tag; the per-object versions are returned (in input
        order) so the writing client can keep its zone-map cache
        coherent without a read-back.
        """
        names = list(names)
        windowed = bool(window_bytes) or bool(window_objects)
        if xattrs is not None:
            xattrs = list(xattrs)
            if len(xattrs) != len(names):
                raise ValueError(f"{len(names)} names / "
                                 f"{len(xattrs)} xattrs")
        else:
            xattrs = [None] * len(names)
        # the write ledger pins each materialized blob (in-batch
        # failover may resend it) until its write AND replica chain
        # land, then releases it — a windowed stream retains O(window)
        ledger = _WriteLedger(len(names))
        blobs_l = ledger.blobs
        if not windowed:
            got = [b for b in blobs]
            if len(got) != len(names):
                raise ValueError(f"{len(names)} names / "
                                 f"{len(got)} blobs")
            for i, b in enumerate(got):
                ledger.pin(i, bytes(b))
        if not names:
            return []
        versions = [self._next_version() for _ in names]
        if windowed:
            stamped: list[dict | None] = [None] * len(names)
        else:
            stamped = [{**(x or {}), "version": v,
                        "digest": content_digest(b)}
                       for x, v, b in zip(xattrs, versions, blobs_l)]

        tried: list[set[str]] = [set() for _ in names]
        last_err: list[Exception | None] = [None] * len(names)
        use_pool = self.io_simulated()
        # server-side replication: one chain task per object, submitted
        # the moment that OBJECT's primary write lands (the ``landed``
        # hook), so replication fills disk-idle gaps of the NIC-paced
        # primary streams instead of queueing behind whole groups (the
        # pooled tasks are never waited on from inside a worker — no
        # deadlock); bare tuples are inline results
        rep_out: list[Any] = []

        def replicate(i: int, entry: str) -> tuple[int, int, float]:
            try:
                return self._replicate(names[i], blobs_l[i], stamped[i],
                                       self._acting(names[i]), entry)
            except OSDDown:  # peering/recovery restores it later
                return 0, 0, 0.0
            finally:
                # the write and its whole replica chain have landed:
                # no retry can ever resend this blob — release it (the
                # windowed stream's O(window) memory bound)
                ledger.release(i)

        def submit_replicas(i: int, entry: str) -> None:
            rep_out.append(self._pool.submit(replicate, i, entry)
                           if use_pool else replicate(i, entry))

        def drain_replicas() -> None:
            # the write acks only after its replicas landed; counters
            # accumulate HERE, on the caller's thread (worker threads
            # never touch the fabric — no lost-update hazard)
            for r in rep_out:
                moved, entry_moved, lat = r.result() if use_pool else r
                self.fabric.replica_bytes += moved
                self.fabric.entry_egress_bytes += entry_moved
                self.fabric.replica_lat_s += lat
            rep_out.clear()

        def write_group(osd_id: str,
                        idxs: list[int]) -> list[tuple[int, Any]]:
            done: set[int] = set()

            def landed(k: int) -> None:
                done.add(idxs[k])
                submit_replicas(idxs[k], osd_id)

            try:
                entry = self._osd(osd_id)
                # one framed request; the NIC stream (``_client_xfer``
                # per sub-write) keeps shared-NIC serialization per blob
                entry.put_batch(
                    [(names[i], blobs_l[i], stamped[i]) for i in idxs],
                    stream=self._client_xfer, landed=landed)
            except OSDDown as e:
                # sub-writes that landed before the failure keep their
                # success (their replication is already in flight); only
                # the unlanded remainder fails over — retrying a landed
                # item would double-count its NIC stream + replica bytes
                return [(i, None if i in done else e) for i in idxs]
            return [(i, None) for i in idxs]

        if windowed:
            try:
                pending = self._stream_put(
                    names, blobs, xattrs, versions, ledger, stamped,
                    tried, last_err, submit_replicas,
                    window_bytes=window_bytes,
                    window_objects=window_objects)
            except PartialWriteError:
                drain_replicas()  # landed sub-writes finish replicating
                self.last_put_ledger_peak_bytes = ledger.peak_bytes
                raise
        else:
            pending = list(range(len(names)))

        while pending:
            ordered = self._next_targets(pending, names, tried, last_err)
            outs = self._dispatch_groups(ordered, write_group)
            pending = []
            for (osd_id, idxs), pairs in zip(ordered, outs):
                self._account_request()  # one round trip per OSD group
                if isinstance(pairs, Exception):
                    # transient budget exhausted before ANY sub-write
                    # landed: the whole group fails over
                    for i in idxs:
                        tried[i].add(osd_id)
                        last_err[i] = pairs
                        pending.append(i)
                    continue
                for i, r in pairs:
                    tried[i].add(osd_id)
                    if isinstance(r, Exception):
                        last_err[i] = r
                        pending.append(i)
                        continue
                    self.fabric.client_tx += ledger.sizes[i]
            drain_replicas()
        drain_replicas()
        self.last_put_ledger_peak_bytes = ledger.peak_bytes
        return versions

    def _stream_put(self, names, blob_iter, xattrs, versions, ledger,
                    stamped, tried, last_err, submit_replicas, *,
                    window_bytes, window_objects) -> list[int]:
        """The windowed half of ``put_batch``: consume the (possibly
        still-encoding) blob producer, flush per-OSD sub-write groups
        into long-lived per-primary streaming requests as each window
        fills, and return the item indices that need buffered failover
        (their entry OSD died mid-stream).  Feeder queues are bounded,
        so a stalled stream back-pressures the encoder instead of
        buffering the whole batch; the write ledger releases each blob
        once it fully lands, so retained bytes stay O(window).  A
        producer length mismatch finalizes the started streams first,
        then raises :class:`PartialWriteError` naming every sub-write
        that already persisted (with its stamped version)."""
        blobs_l = ledger.blobs
        streams: dict[str, tuple[_queue.Queue, Any]] = {}

        def stream_group(osd_id: str, q: _queue.Queue) -> list:
            consumed: list[int] = []   # indices in consumption order
            done: set[int] = set()

            def landed(k: int) -> None:
                done.add(consumed[k])
                submit_replicas(consumed[k], osd_id)

            def feed():
                while True:
                    grp = q.get()
                    if grp is None:
                        return
                    for i in grp:
                        consumed.append(i)
                        yield (names[i], blobs_l[i], stamped[i])

            try:
                entry = self._osd(osd_id)
                entry.put_batch(feed(), stream=self._client_xfer,
                                landed=landed)
                return [(i, None) for i in consumed]
            except (OSDDown, TransientOSDError) as e:
                # keep draining so the (still-producing) client never
                # blocks on a dead stream's bounded queue; every
                # unlanded sub-write fails over
                out = [(i, None if i in done else e) for i in consumed]
                while True:
                    grp = q.get()
                    if grp is None:
                        return out
                    out.extend((i, e) for i in grp)

        # adaptive mode: start at the static default and retarget per
        # flushed window from the measured encode rate (see put_batch)
        adaptive = window_bytes == "adaptive"
        if adaptive:
            window_bytes = DEFAULT_WINDOW_BYTES
        trajectory: list[int] = []

        win: dict[str, list[int]] = {}
        win_nbytes = win_nobjs = 0
        enc_s = 0.0  # encode seconds spent on the CURRENT window

        def flush() -> None:
            nonlocal win_nbytes, win_nobjs, enc_s
            for osd_id, idxs in sorted(win.items()):
                if osd_id not in streams:
                    q: _queue.Queue = _queue.Queue(maxsize=8)
                    self._account_request()  # ONE request per stream
                    streams[osd_id] = (
                        q, self._pool.submit(stream_group, osd_id, q))
                streams[osd_id][0].put(idxs)
                self.fabric.stream_windows += 1
            win.clear()
            win_nbytes = win_nobjs = 0
            enc_s = 0.0

        def retarget() -> None:
            # keep the encoder exactly one window ahead: the next
            # window should take as long to ENCODE as this one takes
            # the NIC to DRAIN -> W_next = W * enc_rate / nic_rate
            nonlocal window_bytes
            if not (adaptive and self.client_bw and win_nbytes):
                return
            enc_rate = win_nbytes / max(enc_s, 1e-9)
            window_bytes = int(min(ADAPTIVE_WINDOW_CAP, max(
                ADAPTIVE_WINDOW_FLOOR,
                win_nbytes * enc_rate / self.client_bw)))
            trajectory.append(window_bytes)

        overlap = 0.0
        mismatch: str | None = None
        it = iter(blob_iter)
        try:
            for i in range(len(names)):
                t0 = time.perf_counter()
                try:
                    item = next(it)
                except StopIteration:
                    # the unflushed window is dropped (never streamed);
                    # flushed sub-writes persist and are reported below
                    mismatch = (f"{len(names)} names but the blob "
                                f"producer ended at {i}")
                    break
                dt = time.perf_counter() - t0
                if streams:  # encode time hidden behind an active stream
                    overlap += dt
                enc_s += dt
                blob, x = item if isinstance(item, tuple) \
                    else (item, xattrs[i])
                blob = bytes(blob)
                stamped[i] = {**(x or {}), "version": versions[i],
                              "digest": content_digest(blob)}
                ledger.pin(i, blob)
                win.setdefault(self._acting(names[i])[0], []).append(i)
                win_nbytes += len(blob)
                win_nobjs += 1
                if (window_bytes and win_nbytes >= window_bytes) or \
                        (window_objects and win_nobjs >= window_objects):
                    retarget()
                    flush()
            else:
                flush()
                try:  # mirror the buffered path's length validation: an
                    next(it)  # overlong producer is a caller bug, not
                except StopIteration:  # data to drop silently
                    pass
                else:
                    mismatch = (f"blob producer yielded more than "
                                f"{len(names)} items")
        finally:
            # sentinel every started stream even when the producer blew
            # up mid-encode — a stream left unterminated would park a
            # pool worker on its queue forever
            for q, _ in streams.values():
                q.put(None)

        failed: list[int] = []
        landed: list[int] = []
        for osd_id, (q, fut) in streams.items():
            for i, r in fut.result():
                tried[i].add(osd_id)
                if isinstance(r, Exception):
                    last_err[i] = r
                    failed.append(i)
                else:
                    self.fabric.client_tx += ledger.sizes[i]
                    landed.append(i)
        self.fabric.overlap_s += overlap
        if adaptive:
            self.last_adaptive_windows = tuple(trajectory)
        if mismatch is not None:
            landed.sort()
            raise PartialWriteError(
                f"{mismatch}; {len(landed)} sub-writes of the batch "
                "already persisted with stamped versions (listed in "
                ".persisted) — nothing else in the batch is durable",
                persisted=((names[i], versions[i]) for i in landed))
        return failed

    def _osd_call(self, fn, *args):
        """One request on a per-object CLIENT path, with the same
        transient retry budget as the batched planes.  Runs on the
        caller's thread, so retries accrue to ``Fabric.retries``
        directly; an exhausted budget re-raises (terminal for that
        replica — the caller's failover loop moves on)."""
        t0 = time.perf_counter()
        attempt = 0
        boff = self.retry.backoff(salt=next(self._salt))
        while True:
            try:
                return fn(*args)
            except TransientOSDError:
                if self.retry.give_up(attempt, t0):
                    raise
                time.sleep(boff.next_s())
                self.fabric.retries += 1
                attempt += 1

    def _osd_call_quiet(self, fn, *args):
        """Transient-retry twin of ``_osd_call`` for MAINTENANCE-daemon
        paths: same backoff budget, but it touches no fabric counter —
        ``Fabric.retries`` is client-owned (caller-thread-only
        accounting), so a daemon retry must never ``+=`` it from a
        background thread while a client thread is doing the same."""
        t0 = time.perf_counter()
        attempt = 0
        boff = self.retry.backoff(salt=next(self._salt))
        while True:
            try:
                return fn(*args)
            except TransientOSDError:
                if self.retry.give_up(attempt, t0):
                    raise
                time.sleep(boff.next_s())
                attempt += 1

    def get(self, name: str) -> bytes:
        """Read from the primary, failing over down the acting set.
        The served copy is digest-verified on its OSD; a divergent copy
        is quarantined there and the read fails over like a miss."""
        return self.get_with_version(name)[0]

    def get_with_version(self, name: str) -> tuple[bytes, int]:
        """``get`` that also returns the copy's stamped xattr
        ``version`` tag from the SAME round trip (-1 when the copy has
        no version xattr) — how a client learns an object's version
        without a separate ``xattr_ops`` lookup."""
        err: Exception | None = None
        for osd_id in self._acting(name):
            try:
                osd = self._osd(osd_id)
                blob = self._osd_call(osd.get, name)
                with osd.lock:
                    version = int((osd.xattrs.get(name) or {})
                                  .get("version", -1))
                self.fabric.client_rx += len(blob)
                self.fabric.rx_frames += 1
                self._account_request()
                self._client_xfer(len(blob))
                return blob, version
            except CorruptObject as e:  # quarantined on its OSD
                self.fabric.corruptions_detected += 1
                self._account_request()  # the request DID round-trip
                err = e
            except (OSDDown, ObjectNotFound, TransientOSDError) as e:
                err = e
        if isinstance(err, CorruptObject):
            raise DataLossError(
                [name], f"{name}: every replica lost or corrupt "
                        f"(last: {err})",
                census=self.copy_census([name]))
        raise err if err else ObjectNotFound(name)

    def get_hedged(self, name: str, timeout_s: float) -> bytes:
        """Hedged read (straggler mitigation): fire the primary, and if it
        does not answer within ``timeout_s``, race a replica.

        Uses the store's persistent executor (no pool churn, no leaked
        straggler thread — the worker is reclaimed when the straggler
        returns) and pays the same NIC accounting as every other read.
        """
        acting = self._acting(name)
        if len(acting) == 1:
            return self.get(name)
        fut = self._hedge_pool.submit(self._osd(acting[0]).get, name)
        try:
            blob = fut.result(timeout=timeout_s)
        except Exception:
            blob = None
            for osd_id in acting[1:]:  # hedge down the acting set
                try:
                    blob = self._osd(osd_id).get(name)
                    self._account_request()  # extra round trip
                    break
                except CorruptObject:
                    self.fabric.corruptions_detected += 1
                    self._account_request()
                    continue
                except (OSDDown, ObjectNotFound, TransientOSDError):
                    continue
            if blob is None:
                # no replica could serve: the slow primary is still the
                # best (only) hope — wait it out like a plain get()
                blob = fut.result()
        self.fabric.client_rx += len(blob)
        self.fabric.rx_frames += 1
        self._account_request()
        self._client_xfer(len(blob))
        return blob

    def exec(self, name: str, ops: list[ObjOp]) -> Any:
        """Execute an objclass pipeline ON the object's primary OSD and
        return only the result — the pushdown path.  Only the result size
        crosses the client<->storage fabric."""
        err: Exception | None = None
        for osd_id in self._acting(name):
            try:
                osd = self._osd(osd_id)
                result, scanned = self._osd_call(osd.exec_cls, name, ops)
                rx = _result_nbytes(result)
                self.fabric.local_bytes += scanned
                self.fabric.client_rx += rx
                self.fabric.rx_frames += 1
                self._account_request()
                self._client_xfer(rx)
                return result
            except CorruptObject as e:  # quarantined: fail over
                self.fabric.corruptions_detected += 1
                self._account_request()
                err = e
            except (OSDDown, ObjectNotFound, TransientOSDError) as e:
                err = e
        if isinstance(err, CorruptObject):
            raise DataLossError(
                [name], f"{name}: every replica lost or corrupt "
                        f"(last: {err})",
                census=self.copy_census([name]))
        raise err if err else ObjectNotFound(name)

    def exec_batch(self, names: Iterable[str],
                   ops: list[ObjOp] | Sequence[list[ObjOp]]) -> list[Any]:
        """Batched objclass execution: ONE request per involved OSD.

        Objects are grouped by their primary OSD and each group goes out
        as a single ``exec_cls_batch`` round trip, so ``Fabric.ops``
        grows with the number of OSDs touched, not the number of
        objects.  ``ops`` is either one pipeline applied to every object
        or a per-object sequence of pipelines (``len == len(names)``).

        Failover: objects whose request failed (OSD down, replica
        missing the object) are re-grouped onto their next untried
        replica and retried as fresh batched requests; per-object
        results are returned in input order, bit-identical to the
        per-object ``exec`` path.
        """
        gen, results = self._exec_batch_impl(names, ops)
        for _ in gen:
            pass
        return results

    def exec_batch_iter(self, names: Iterable[str],
                        ops: list[ObjOp] | Sequence[list[ObjOp]]
                        ) -> Iterator[tuple[int, Any]]:
        """Streaming twin of ``exec_batch``: yields ``(index, result)``
        pairs the moment their per-OSD group response lands (completion
        order), so the consumer decodes early results while slower OSDs
        are still scanning.  Same requests, failover, and accounting as
        the buffered form; delivered results count in
        ``Fabric.stream_windows``."""
        gen, _ = self._exec_batch_impl(names, ops, stream=True)
        return gen

    def _exec_batch_impl(self, names, ops, stream: bool = False):
        names = list(names)
        results: list[Any] = [None] * len(names)
        if not names:
            return iter(()), results
        if ops and isinstance(ops[0], (list, tuple)):
            pipelines = [list(p) for p in ops]
            if len(pipelines) != len(names):
                raise ValueError(
                    f"{len(pipelines)} pipelines for {len(names)} objects")
        else:
            pipelines = [list(ops)] * len(names)

        def run_group(osd_id: str, idxs: list[int]) -> Any:
            try:
                osd = self._osd(osd_id)
                return osd.exec_cls_batch(
                    [(names[i], pipelines[i]) for i in idxs])
            except OSDDown as e:  # whole request failed
                return e

        def handle(idxs, got, last_err):
            got, meters = got
            self._apply_meters(meters)
            group_rx = 0
            retry = []
            emitted = []
            for i, r in zip(idxs, got):
                if isinstance(r, Exception):  # per-item miss on this OSD
                    if isinstance(r, CorruptObject):
                        self.fabric.corruptions_detected += 1
                    last_err[i] = r
                    retry.append(i)
                    continue
                result, scanned = r
                self.fabric.local_bytes += scanned
                group_rx += _result_nbytes(result)
                self.fabric.rx_frames += 1
                results[i] = result
                emitted.append((i, result))
            self.fabric.client_rx += group_rx
            self._client_xfer(group_rx)
            return retry, emitted

        gen = self._scatter_iter(names, run_group, handle, stream=stream)
        return gen, results

    def exec_combine(self, names: Iterable[str], ops: list[ObjOp],
                     prune=None) -> Any:
        """Batched pushdown with SERVER-SIDE combine.

        Each involved OSD runs the (shared, decomposable) pipeline over
        its local objects, folds the per-object partials with the tail
        op's associative ``merge``, and returns ONE partial — so an
        N-object aggregate scan over K OSDs moves K partials
        (``client_rx`` O(K)) in K round trips, instead of N partials in
        K round trips (``exec_batch``) or N in N (per-object ``exec``).

        Objects missing from an OSD fail over to the next replica in
        their acting set exactly like ``exec_batch``.  Returns one
        merged partial per issued request that found at least one
        object; finish with ``objclass.combine_partials`` (merged
        partials are shape-identical to raw ones).

        ``prune`` pushes a filter-expression tree (an ``expr.Expr`` —
        OR-groups, IN-lists, ranges, prefixes — its wire dict, or the
        legacy tuple of (col, cmp, value) triples) down with each
        request, serialized by ``_prune_wire``: the OSD skips objects
        whose CURRENT local zone map proves the expression matches
        nothing, and the call returns ``(partials, pruned_names)``
        instead of the bare partial list.  Pruned objects are a
        semantic skip — they are NOT retried on replicas.
        """
        gen, pruned_out = self._exec_combine_impl(names, ops, prune)
        partials = list(gen)
        return (partials, pruned_out) if prune is not None else partials

    def exec_combine_iter(self, names: Iterable[str], ops: list[ObjOp],
                          prune=None, pruned_out: list | None = None
                          ) -> Iterator[Any]:
        """Streaming twin of ``exec_combine``: yields each OSD's merged
        partial as the scatter progresses.  Partials are scalar-sized
        (there is no decode to overlap), so delivery keeps DISPATCH
        order — a float fold over the yields is bit-deterministic run
        to run, unlike a completion-order stream.  OSD-pruned names
        accumulate into ``pruned_out`` (complete once the iterator is
        exhausted)."""
        gen, _ = self._exec_combine_impl(names, ops, prune, stream=True,
                                         pruned_out=pruned_out)
        return gen

    def _exec_combine_impl(self, names, ops, prune, stream: bool = False,
                           pruned_out: list | None = None):
        names = list(names)
        out_pruned: list[str] = pruned_out if pruned_out is not None \
            else []
        if not names:
            return iter(()), out_pruned
        ops = list(ops)
        if not pipeline_mergeable(ops):
            raise ValueError("exec_combine needs a decomposable pipeline "
                             "whose tail has an associative merge")
        wire = _prune_wire(prune)

        def run_group(osd_id: str, idxs: list[int]) -> Any:
            try:
                osd = self._osd(osd_id)
                return osd.exec_cls_batch(
                    [(names[i], ops) for i in idxs], combine=True,
                    prune=wire)
            except OSDDown as e:
                return e

        def handle(idxs, got, last_err):
            merged, _, scanned, missing, pruned, corrupt, meters = got
            self._apply_meters(meters)
            self.fabric.local_bytes += scanned
            self.fabric.corruptions_detected += len(corrupt)
            emitted = []
            if merged is not None:
                rx = _result_nbytes(merged)
                self.fabric.client_rx += rx
                self.fabric.rx_frames += 1
                self._client_xfer(rx)
                emitted.append(merged)
            out_pruned.extend(pruned)
            miss, bad = set(missing), set(corrupt)
            retry = [i for i in idxs if names[i] in miss | bad]
            for i in retry:
                last_err[i] = CorruptObject(names[i]) \
                    if names[i] in bad else ObjectNotFound(names[i])
            return retry, emitted

        # dispatch order even when streaming: merged partials are a few
        # bytes each, so there is no decode to overlap — but the fold
        # over them is float-order-sensitive and must stay deterministic
        gen = self._scatter_iter(names, run_group, handle, stream=stream,
                                 completion_order=False)
        return gen, out_pruned

    def exec_concat(self, names: Iterable[str],
                    ops: list[ObjOp] | Sequence[list[ObjOp]],
                    prune=None) -> tuple[list, list[str]]:
        """Batched pushdown with SERVER-SIDE table concat — the
        table-out twin of ``exec_combine``.

        Each involved OSD runs its items' (table-out) pipelines over
        local data, concatenates the per-object result tables, and
        returns ONE encoded block per request — a filter→project scan
        over N objects on K OSDs moves exactly K framed responses
        (``rx_frames`` O(K)) instead of N.  ``ops`` is one shared
        pipeline or a per-object sequence (``len == len(names)``),
        mirroring ``exec_batch``.

        Returns ``(frames, pruned_names)`` where each frame is
        ``(input_indices, blob, row_counts)``: the indices (into
        ``names``) this frame serves, in the order their rows appear in
        the concatenated block, with ``row_counts[j]`` rows belonging
        to ``indices[j]`` — everything the client needs to re-slice the
        block into per-object tables and restore global row order.
        ``prune`` behaves exactly as in ``exec_combine`` (OSD-side
        zone-map skip against current xattrs, no replica retry).
        Missing objects fail over to the next replica as fresh batched
        requests.
        """
        gen, pruned_out = self._exec_concat_impl(names, ops, prune)
        return list(gen), pruned_out

    def exec_concat_iter(self, names: Iterable[str],
                         ops: list[ObjOp] | Sequence[list[ObjOp]],
                         prune=None, pruned_out: list | None = None
                         ) -> Iterator[tuple]:
        """Streaming twin of ``exec_concat``: yields each OSD's framed
        block ``(input_indices, blob, row_counts)`` the moment its
        response lands (completion order), so the client decodes early
        frames while slower OSDs are still scanning — the scan-side
        half of the windowed overlap (delivered frames count in
        ``Fabric.stream_windows``).  OSD-pruned names accumulate into
        ``pruned_out`` (complete once the iterator is exhausted)."""
        gen, _ = self._exec_concat_impl(names, ops, prune, stream=True,
                                        pruned_out=pruned_out)
        return gen

    def _exec_concat_impl(self, names, ops, prune, stream: bool = False,
                          pruned_out: list | None = None):
        names = list(names)
        out_pruned: list[str] = pruned_out if pruned_out is not None \
            else []
        if not names:
            return iter(()), out_pruned
        if ops and isinstance(ops[0], (list, tuple)):
            pipelines = [list(p) for p in ops]
            if len(pipelines) != len(names):
                raise ValueError(
                    f"{len(pipelines)} pipelines for {len(names)} objects")
        else:
            pipelines = [list(ops)] * len(names)

        wire = _prune_wire(prune)

        def run_group(osd_id: str, idxs: list[int]) -> Any:
            try:
                osd = self._osd(osd_id)
                return osd.exec_cls_batch(
                    [(names[i], pipelines[i]) for i in idxs],
                    concat=True, prune=wire)
            except OSDDown as e:
                return e

        def handle(idxs, got, last_err):
            (blob, served, counts, scanned, missing, pruned, corrupt,
             meters) = got
            self._apply_meters(meters)
            self.fabric.local_bytes += scanned
            self.fabric.corruptions_detected += len(corrupt)
            emitted = []
            if blob is not None:
                self.fabric.client_rx += len(blob)
                self.fabric.rx_frames += 1
                self._client_xfer(len(blob))
                emitted.append(
                    (tuple(idxs[k] for k in served), blob, counts))
            out_pruned.extend(pruned)
            miss, bad = set(missing), set(corrupt)
            retry = [i for i in idxs if names[i] in miss | bad]
            for i in retry:
                last_err[i] = CorruptObject(names[i]) \
                    if names[i] in bad else ObjectNotFound(names[i])
            return retry, emitted

        gen = self._scatter_iter(names, run_group, handle, stream=stream)
        return gen, out_pruned

    def delete(self, name: str) -> None:
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                osd.data.pop(name, None)
                osd.xattrs.pop(name, None)
            osd.cache.invalidate(name)

    def exists(self, name: str) -> bool:
        for o in self.cluster.up_osds:
            osd = self.osds[o]
            with osd.lock:  # writers mutate osd.data concurrently
                if name in osd.data:
                    return True
        return False

    def list_objects(self, prefix: str = "") -> list[str]:
        seen: set[str] = set()
        for o in self.cluster.up_osds:
            seen |= {n for n in self.osds[o].object_names()
                     if n.startswith(prefix)}
        return sorted(seen)

    def xattr(self, name: str) -> dict:
        """Metadata lookup (one round trip, counted in ``xattr_ops`` —
        clients should cache zone maps per cluster epoch, see
        ``GlobalVOL``)."""
        self.fabric.xattr_ops += 1
        for osd_id in self._acting(name):
            osd = self.osds[osd_id]
            with osd.lock:  # writers mutate osd.xattrs concurrently
                if name in osd.xattrs:
                    return dict(osd.xattrs[name])
        return {}

    def list_zone_maps(self, names: Iterable[str]) -> dict[str, dict]:
        """Batched metadata plane: many objects' xattrs (zone map +
        version) in ONE ``OSD.list_xattrs`` request per primary OSD, so
        warming a client's zone-map cache over N objects costs K
        ``xattr_ops`` instead of N.  Names whose target OSD is down or
        lacks the xattr fail over down the acting set; names found
        nowhere are simply absent from the result (mirroring ``xattr``
        returning {})."""
        names = list(dict.fromkeys(names))
        if not names:
            return {}
        out: dict[str, dict] = {}
        tried: list[set[str]] = [set() for _ in names]
        pending = list(range(len(names)))

        def fetch_group(osd_id: str, idxs: list[int]) -> Any:
            try:
                return self._osd(osd_id).list_xattrs(
                    [names[i] for i in idxs])
            except OSDDown as e:
                return e

        while pending:
            skipped: list[int] = []
            ordered = self._next_targets(pending, names, tried,
                                         skipped=skipped)
            outs = self._dispatch_groups(ordered, fetch_group)
            pending = []
            for (osd_id, idxs), got in zip(ordered, outs):
                self.fabric.xattr_ops += 1  # one lookup per OSD request
                for i in idxs:
                    tried[i].add(osd_id)
                    if isinstance(got, Exception) or names[i] not in got:
                        pending.append(i)  # retry on the next replica
                    else:
                        out[names[i]] = got[names[i]]
        return out

    # ------------------------------------------------------------ failures
    def fail_osd(self, osd_id: str) -> None:
        """Disk loss: data gone, OSD marked down, epoch bumped."""
        old = self.cluster
        self.cluster = old.mark_down(osd_id)
        self.osds[osd_id] = OSD(  # data destroyed (cache with it)
            osd_id, self.disk_bw, scan_bw=self.scan_bw,
            cache_bytes=self.cache_bytes)
        if self.faults is not None:  # keep the injector wired to the
            self.faults.attach_osd(self.osds[osd_id])  # replacement OSD
        if self.maintenance is not None:  # wake the live rebalancer
            self.maintenance.note_topology_change()

    def add_osds(self, ids: Iterable[str]) -> None:
        ids = list(ids)
        self.cluster = self.cluster.add_osds(ids)
        for i in ids:
            self.osds[i] = OSD(i, self.disk_bw, scan_bw=self.scan_bw,
                               cache_bytes=self.cache_bytes)
            if self.faults is not None:
                self.faults.attach_osd(self.osds[i])
        if self.maintenance is not None:
            self.maintenance.note_topology_change()

    # ------------------------------------------------------------ scrub/heal
    def _verified_copies(self, name: str) -> tuple[list, list, list]:
        """Classify every up-OSD copy of one object WITHOUT serving it:
        ``(verified, divergent, undigested)``.  ``verified`` holds
        ``(version, osd_id, blob, xattr)`` tuples whose stored bytes
        match their stamped digest; ``divergent`` holds copies that
        fail their own digest OR lost their xattr (torn write) while a
        digested copy exists elsewhere; ``undigested`` holds copies
        with no digest to check (legacy/native writes) — unverifiable,
        not provably corrupt."""
        verified, divergent, bare = [], [], []
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                blob = osd.data.get(name)
                xattr = dict(osd.xattrs.get(name) or {})
            if blob is None:
                continue
            digest = xattr.get("digest")
            if digest is None:
                bare.append((osd_id, blob, xattr))
            elif content_digest(blob) == int(digest):
                verified.append((int(xattr.get("version", -1)),
                                 osd_id, blob, xattr))
            else:
                divergent.append((osd_id, blob, xattr))
        if verified or any(x for _, _, x in bare):
            # torn copies (blob, no xattr at all) are divergent once any
            # OTHER copy proves the object should carry metadata
            torn = [(o, b, x) for o, b, x in bare if not x]
            bare = [(o, b, x) for o, b, x in bare if x]
            divergent.extend(torn)
        verified.sort(key=lambda t: -t[0])  # newest version first
        return verified, divergent, bare

    def _quarantined_on(self, name: str) -> list[str]:
        """Up OSDs holding a quarantined copy of ``name`` — snapshotted
        under each OSD's lock (read paths quarantine concurrently)."""
        out = []
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                held = name in osd.quarantine
            if held:
                out.append(osd_id)
        return out

    def _quarantined_names(self) -> set[str]:
        """Every quarantined name across the up OSDs (same snapshot
        discipline) — the scrub/recover inventory extension."""
        names: set[str] = set()
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                names |= set(osd.quarantine)
        return names

    def scrub(self, heal: bool = True) -> dict:
        """Background integrity pass (the maintenance half of the
        self-healing plane): walk every up OSD, digest-verify each
        local copy, quarantine divergent/torn ones, and — with
        ``heal=True`` — restore every acting-set copy from the
        highest-version verified source through the replication chain
        (``_replicate``; bytes accrue to ``Fabric.recovery_bytes``,
        copies to ``Fabric.heals``).

        Idempotent: a second scrub right after a healing one finds
        nothing (all copies verified, quarantine is out of service).
        Returns stats: bytes verified, corruptions found, copies
        healed, plus the names it could not help — ``lost`` (had a
        digest somewhere but NO verified copy survives) and
        ``undigested`` (legacy objects with no digest to check; never
        touched).  Scrub is a maintenance client: its verify reads are
        OSD-local (counted in ``Fabric.scrub_bytes``, not client
        traffic), and only heal traffic crosses the OSD fabric."""
        inventory = set(self.list_objects()) | self._quarantined_names()
        found = healed = 0
        lost: list[str] = []
        undigested: list[str] = []
        for name in sorted(inventory):
            step = self._scrub_object(name, heal=heal)
            found += step["corrupt"]
            healed += step["healed"]
            if step["lost"]:
                lost.append(name)  # digested object, no good copy
            elif step["undigested"]:
                undigested.append(name)  # legacy: nothing to check
        return {"objects_scrubbed": len(inventory),
                "corrupt_copies": found, "healed_copies": healed,
                "lost": tuple(lost), "undigested": tuple(undigested),
                "epoch": self.cluster.epoch}

    def _scrub_object(self, name: str, heal: bool = True) -> dict:
        """One object's scrub step — the unit both on-demand ``scrub()``
        and the maintenance plane's continuous walker iterate: classify
        every copy (``_verified_copies``), quarantine divergent/torn
        ones, and heal missing acting-set copies from the best verified
        source through the replication chain.  Returns ``{"bytes":
        verified bytes (the walker's rate-limit currency), "corrupt":
        copies quarantined, "healed": copies restored, "lost"/
        "undigested": flags}``."""
        out = {"bytes": 0, "corrupt": 0, "healed": 0,
               "lost": False, "undigested": False}
        verified, divergent, bare = self._verified_copies(name)
        for _, _, blob, _ in verified:
            out["bytes"] += len(blob)
            self.fabric.scrub_bytes += len(blob)
        for osd_id, blob, _ in divergent:
            out["bytes"] += len(blob)
            self.fabric.scrub_bytes += len(blob)
            self.osds[osd_id]._quarantine_copy(name)
            self.fabric.corruptions_detected += 1
            out["corrupt"] += 1
        if not verified:
            if divergent or self._quarantined_on(name):
                out["lost"] = True
            elif bare:
                out["undigested"] = True
            return out
        if not heal:
            return out
        _, src, blob, xattr = verified[0]
        holders = {osd_id for _, osd_id, _, _ in verified}
        targets = [o for o in self._acting(name) if o not in holders]
        if not targets:
            return out
        moved, _, _ = self._replicate(name, blob, xattr,
                                      [src] + targets, entry=src)
        copies = moved // len(blob) if blob else len(targets)
        self.fabric.recovery_bytes += moved
        self.fabric.heals += copies
        out["healed"] = copies
        return out

    def copy_census(self, names: Iterable[str]
                    ) -> dict[str, dict[str, list[str]]]:
        """Per-object copy census for operator triage: which up OSDs
        hold a digest-``verified`` copy, a ``divergent`` one (fails its
        own digest), a ``bare`` unverifiable one (no digest stamped),
        and which hold a ``quarantined`` copy pulled from service.
        Rides on every :class:`DataLossError` so the choice to
        ``recover(allow_loss=True)`` is an informed one.  OSD-local
        inspection only — no fabric traffic is charged."""
        out: dict[str, dict[str, list[str]]] = {}
        for name in dict.fromkeys(names):
            verified, divergent, bare = self._verified_copies(name)
            out[name] = {
                "verified": [o for _, o, _, _ in verified],
                "divergent": [o for o, _, _ in divergent],
                "bare": [o for o, _, _ in bare],
                "quarantined": self._quarantined_on(name),
            }
        return out

    def recover(self, old_map: ClusterMap | None = None, *,
                expected: Iterable[str] | None = None,
                allow_loss: bool = False) -> dict:
        """Peering: for every object, ensure each OSD in the (new)
        acting set holds a copy, sourcing from a DIGEST-VERIFIED
        surviving replica — a corrupt copy is never propagated; it is
        quarantined and the source search falls down the remaining
        copies (undigested legacy copies are used only when no digested
        copy exists).  Runs after fail_osd/add_osds.

        An object with no usable copy left is DATA LOSS and raises
        :class:`DataLossError` naming the objects — pass
        ``allow_loss=True`` to get the legacy stats-only behavior
        (the lost names still ride in the returned dict).  ``expected``
        extends the inventory with names the caller knows should exist
        (e.g. from an ObjectMap), so even objects whose every replica
        vanished — invisible to ``list_objects`` — are detected."""
        inventory = set(self.list_objects()) | self._quarantined_names()
        if expected is not None:
            inventory |= set(expected)
        moved = 0
        lost: list[str] = []
        for name in sorted(inventory):
            acting = self._acting(name)
            verified, divergent, bare = self._verified_copies(name)
            for osd_id, _, _ in divergent:  # refuse corrupt sources
                self.osds[osd_id]._quarantine_copy(name)
                self.fabric.corruptions_detected += 1
            if verified:
                _, _, src_blob, src_xattr = verified[0]
            elif bare:  # unverifiable legacy copy beats nothing
                _, src_blob, src_xattr = bare[0]
            else:
                lost.append(name)  # all replicas lost (over-failure)
                continue
            for osd_id in acting:
                osd = self._osd(osd_id)
                with osd.lock:  # writers land copies concurrently
                    held = name in osd.data
                if not held:
                    try:
                        self._hop_put(osd_id, name, src_blob, src_xattr)
                    except (OSDDown, TransientOSDError):
                        continue  # next peering pass heals it
                    self.fabric.recovery_bytes += len(src_blob)
                    self.fabric.heals += 1
                    moved += 1
        if lost and not allow_loss:
            raise DataLossError(
                lost, f"recover(): {len(lost)} object(s) have no "
                      f"surviving verified replica: {lost[:8]}"
                      f"{'...' if len(lost) > 8 else ''}",
                census=self.copy_census(lost))
        return {"objects_moved": moved, "objects_lost": len(lost),
                "lost": tuple(lost), "epoch": self.cluster.epoch}

    # ------------------------------------------------------ maintenance ops
    # primitives the background MaintenancePlane (core.maintenance)
    # drives: each runs on the calling daemon thread — OSD-local work
    # plus OSD->OSD traffic, never client fabric bytes — and eagerly
    # invalidates cached forms (result cache + negative entries) of
    # every object it rewrites, so the serve plane can never answer
    # from a pre-rewrite entry.

    def invalidate_cached(self, name: str) -> None:
        """Drop every up OSD's cached forms of one object — positive
        result-cache entries AND negative (nothing-to-serve) entries
        share the per-name index, so one call retires both."""
        for osd_id in self.cluster.up_osds:
            self.osds[osd_id].cache.invalidate(name)

    def _maint_put(self, name: str, blob: bytes,
                   xattr: dict | None = None) -> tuple[int, int]:
        """Maintenance-plane write: stamp a fresh version + digest and
        land the object on its acting set (entry + replica chain), like
        ``put`` but WITHOUT client fabric accounting — the bytes are
        cluster-internal.  Returns ``(version, bytes_moved)``."""
        version = self._next_version()
        stamped = {**(xattr or {}), "version": version,
                   "digest": content_digest(blob)}
        acting = self._acting(name)
        self._hop_put(acting[0], name, blob, stamped)
        moved, _, _ = self._replicate(name, blob, stamped, acting)
        self.invalidate_cached(name)
        return version, len(blob) + moved

    def compact_run(self, names: Sequence[str], out_name: str,
                    rows: tuple[int, int] | None = None
                    ) -> tuple[int, int]:
        """Fold one run of small objects into ``out_name``: gather each
        member's best digest-verified copy, ship the run to the merge
        OSD (``out_name``'s primary) where the ``compact_merge``
        objclass op concatenates and re-encodes it, then replicate the
        merged object down its acting set.  ``rows`` stamps the merged
        object's GLOBAL row extent so pushed-down ``row_slice`` ops
        resolve against it exactly as they did against the members.
        Returns ``(version, bytes)`` — bytes include member gathers,
        the merge write, and replication (``Fabric.compaction_bytes``).
        The members are NOT deleted here: the caller (the maintenance
        plane) retires them through versioned GC after its retention
        window, so in-flight scans still find them until every compiled
        plan has refreshed onto the new map."""
        blobs: list[bytes] = []
        gathered = 0
        for member in names:
            verified, _, bare = self._verified_copies(member)
            if verified:
                blobs.append(verified[0][2])
            elif bare:
                blobs.append(bare[0][1])
            else:
                raise DataLossError(
                    [member], f"compact_run: no usable copy of {member}",
                    census=self.copy_census([member]))
            gathered += len(blobs[-1])
        version = self._next_version()
        xattr: dict = {"version": version}
        if rows is not None:
            xattr["rows"] = [int(rows[0]), int(rows[1])]
        acting = self._acting(out_name)
        entry = self._osd(acting[0])
        blob, stamped = self._osd_call_quiet(
            entry.compact_merge, blobs, out_name, xattr)
        moved, _, _ = self._replicate(out_name, blob, stamped, acting)
        self.invalidate_cached(out_name)
        nbytes = gathered + len(blob) + moved
        self.fabric.compactions += 1
        self.fabric.compaction_bytes += nbytes
        return version, nbytes

    def rebalance_object(self, name: str) -> int:
        """Move one object toward its CURRENT placement: copy the best
        verified source onto every acting OSD that lacks a copy, then —
        only once EVERY acting copy digest-verifies — drop stray copies
        parked on non-acting OSDs.  A failed hop or unverified acting
        copy keeps the strays (they are still the safety margin), so a
        crash mid-step never reduces the number of good copies.
        Divergent copies are left for the scrub walker to quarantine —
        the walker owns corruption accounting.  Returns bytes moved
        (``Fabric.rebalance_bytes``)."""
        acting = self._acting(name)
        verified, divergent, bare = self._verified_copies(name)
        if not verified and not bare:
            return 0
        if verified:
            _, _, blob, xattr = verified[0]
        else:
            _, blob, xattr = bare[0]
        # divergent copies count as holders too: overwriting one would
        # silently repair it and rob the walker of the detection
        holders = {o for _, o, _, _ in verified} | \
            {o for o, _, _ in bare} | {o for o, _, _ in divergent}
        moved = 0
        for osd_id in acting:
            if osd_id in holders:
                continue
            try:
                self._hop_put(osd_id, name, blob, xattr)
            except (OSDDown, TransientOSDError):
                continue  # next pass finishes the move
            moved += len(blob)
        # verify-before-drop: every acting copy must check out
        digest = (xattr or {}).get("digest")
        for osd_id in acting:
            osd = self.osds[osd_id]
            with osd.lock:
                copy = osd.data.get(name)
                have = (osd.xattrs.get(name) or {}).get("digest")
            if copy is None:
                return moved  # move incomplete: keep the strays
            if digest is not None and (
                    have is None or content_digest(copy) != int(have)):
                return moved
        for osd_id in self.cluster.up_osds:
            if osd_id in acting:
                continue
            osd = self.osds[osd_id]
            with osd.lock:
                stray = osd.data.pop(name, None)
                osd.xattrs.pop(name, None)
            if stray is not None:
                osd.cache.invalidate(name)
        if moved:
            self.invalidate_cached(name)
            self.fabric.rebalance_bytes += moved
        return moved

    def purge_quarantined(self, name: str) -> int:
        """Release every quarantined copy of one object (versioned GC,
        after the retention window).  Returns bytes freed."""
        freed = 0
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                entry = osd.quarantine.pop(name, None)
            if entry is not None:
                freed += len(entry[0])
        return freed

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        return {
            "fabric": self.fabric.snapshot(),
            "epoch": self.cluster.epoch,
            "osd_bytes": {o: self.osds[o].nbytes()
                          for o in self.cluster.osds},
            "n_objects": len(self.list_objects()),
            "cache_resident_bytes": {
                o: self.osds[o].cache.resident_bytes
                for o in self.cluster.osds},
        }


def _prune_wire(prune):
    """Client half of the predicate transport: normalize an Expr (or
    legacy triples) to the serialized tree dict that rides inside the
    batched request — the OSD parses it back with ``expr.from_json``.

    The tree is run through ``expr.normalize`` first (De Morgan
    push-down, constant folding, same-column interval merging): the
    prune payload only ever drives zone-map *interval* decisions over
    scalar metadata, exactly the domain where the rewrite makes more
    trees prunable — evaluation filters inside pipelines are never
    normalized, so row semantics are untouched."""
    pred = ex.normalize(ex.ensure_pred(prune))
    return None if pred is None else pred.to_json()


def _result_nbytes(result: Any) -> int:
    if isinstance(result, (bytes, bytearray)):
        return len(result)
    if isinstance(result, dict):
        return sum(np.asarray(v).nbytes for v in result.values())
    return 64  # scalar-ish


def make_store(n_osds: int, *, replicas: int = 3, n_pgs: int = 128,
               prefix: str = "osd", client_bw: float | None = None,
               disk_bw: float | None = None,
               scan_bw: float | None = None,
               cache_bytes: int = 0,
               replication: str = "chain",
               hop_latency_s: float = 0.0,
               retry: RetryPolicy | None = None) -> ObjectStore:
    cm = ClusterMap(tuple(f"{prefix}.{i}" for i in range(n_osds)),
                    n_pgs=n_pgs, replicas=min(replicas, n_osds))
    return ObjectStore(cm, client_bw=client_bw, disk_bw=disk_bw,
                       scan_bw=scan_bw, cache_bytes=cache_bytes,
                       replication=replication,
                       hop_latency_s=hop_latency_s, retry=retry)
