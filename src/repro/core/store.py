"""RADOS-like distributed object store (simulated control plane).

OSDs are in-process shards with byte-accurate transfer accounting; the
semantics — primary/replica writes, objclass execution on the primary,
failure, peering/recovery — follow Ceph.  The accounting (client<->OSD
bytes vs OSD-local bytes processed) is what the paper's pushdown claims
are measured against in ``benchmarks/``.

Failure model: ``fail_osd`` marks an OSD down (its data is *gone*, as a
disk loss); ``recover`` re-replicates every object that lost a replica
from a surviving copy, on the new cluster map.  Reads and objclass execs
transparently fail over to the next replica in the acting set.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Iterable

from repro.core.objclass import ObjOp, run_pipeline
from repro.core.placement import ClusterMap, pg_delta


@dataclasses.dataclass
class Fabric:
    """Byte/op counters for the client<->storage network."""

    client_tx: int = 0          # client -> OSD (writes)
    client_rx: int = 0          # OSD -> client (reads / results)
    replica_bytes: int = 0      # OSD -> OSD primary-copy fan-out
    recovery_bytes: int = 0     # OSD -> OSD re-replication
    local_bytes: int = 0        # bytes processed inside OSDs (pushdown)
    ops: int = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> None:
        self.client_tx = self.client_rx = 0
        self.replica_bytes = self.recovery_bytes = 0
        self.local_bytes = self.ops = 0


class OSDDown(RuntimeError):
    pass


class ObjectNotFound(KeyError):
    pass


class OSD:
    """One storage server: object data + xattrs + a local op executor.

    ``latency_s`` simulates slow media / stragglers (used by the hedged-
    read tests); ``disk_bw`` (bytes/s, None = instant) serializes write
    cost per OSD — parallel writers to different OSDs overlap, writers to
    the same OSD queue, which is what makes paper-Table-1-style scaling
    measurable in-process.
    """

    def __init__(self, osd_id: str, disk_bw: float | None = None):
        self.osd_id = osd_id
        self.data: dict[str, bytes] = {}
        self.xattrs: dict[str, dict] = {}
        self.latency_s: float = 0.0
        self.disk_bw = disk_bw
        self.lock = threading.Lock()

    # -- local primitives (called by ObjectStore only) --
    def put(self, name: str, blob: bytes, xattr: dict | None = None) -> None:
        if self.latency_s:
            time.sleep(self.latency_s)
        with self.lock:
            if self.disk_bw:
                time.sleep(len(blob) / self.disk_bw)  # serial disk
            self.data[name] = bytes(blob)
            if xattr is not None:
                self.xattrs[name] = dict(xattr)

    def get(self, name: str) -> bytes:
        if self.latency_s:
            time.sleep(self.latency_s)
        with self.lock:
            if name not in self.data:
                raise ObjectNotFound(name)
            return self.data[name]

    def exec_cls(self, name: str, ops: list[ObjOp]) -> Any:
        """Run an objclass pipeline against a local object (SkyhookDM
        extension / custom read method)."""
        blob = self.get(name)
        return run_pipeline(blob, ops), len(blob)

    def nbytes(self) -> int:
        with self.lock:
            return sum(len(b) for b in self.data.values())

    def object_names(self) -> set[str]:
        with self.lock:
            return set(self.data)


class ObjectStore:
    """The cluster: cluster map + OSD daemons + client entry points.

    ``client_bw`` (bytes/s, None = instant) models the client's shared
    NIC: all client<->OSD transfers serialize through one link, so
    parallel writers amortize OSD work but not the forwarding hop — the
    paper's Table-1 structure.
    """

    def __init__(self, cluster: ClusterMap, *,
                 client_bw: float | None = None,
                 disk_bw: float | None = None):
        self.cluster = cluster
        self.client_bw = client_bw
        self.disk_bw = disk_bw
        self.osds: dict[str, OSD] = {o: OSD(o, disk_bw)
                                     for o in cluster.osds}
        self.fabric = Fabric()
        self._lock = threading.Lock()
        self._nic = threading.Lock()

    def _client_xfer(self, nbytes: int) -> None:
        if self.client_bw:
            with self._nic:  # one NIC: transfers serialize
                time.sleep(nbytes / self.client_bw)

    # ------------------------------------------------------------ helpers
    def _acting(self, name: str) -> tuple[str, ...]:
        s = self.cluster.locate(name)
        if not s:
            raise OSDDown("no up OSDs for " + name)
        return s

    def _osd(self, osd_id: str) -> OSD:
        if osd_id in self.cluster.down:
            raise OSDDown(osd_id)
        return self.osds[osd_id]

    # ------------------------------------------------------------ client IO
    def put(self, name: str, blob: bytes, xattr: dict | None = None) -> None:
        """Replicated write: client -> primary -> (fan-out) replicas.
        Client pays one transfer; replica fan-out is server-side, matching
        Ceph's primary-copy replication."""
        acting = self._acting(name)
        self.fabric.client_tx += len(blob)
        self.fabric.ops += 1
        self._client_xfer(len(blob))
        for i, osd_id in enumerate(acting):
            self._osd(osd_id).put(name, blob, xattr)
            if i > 0:  # replica fan-out is OSD->OSD (cluster network),
                self.fabric.replica_bytes += len(blob)  # not client bytes

    def get(self, name: str) -> bytes:
        """Read from the primary, failing over down the acting set."""
        err: Exception | None = None
        for osd_id in self._acting(name):
            try:
                blob = self._osd(osd_id).get(name)
                self.fabric.client_rx += len(blob)
                self.fabric.ops += 1
                self._client_xfer(len(blob))
                return blob
            except (OSDDown, ObjectNotFound) as e:  # failover
                err = e
        raise err if err else ObjectNotFound(name)

    def get_hedged(self, name: str, timeout_s: float) -> bytes:
        """Hedged read (straggler mitigation): fire the primary, and if it
        does not answer within ``timeout_s``, race a replica."""
        acting = self._acting(name)
        if len(acting) == 1:
            return self.get(name)
        pool = ThreadPoolExecutor(max_workers=1)
        fut = pool.submit(self._osd(acting[0]).get, name)
        try:
            blob = fut.result(timeout=timeout_s)
        except Exception:
            blob = self._osd(acting[1]).get(name)
        finally:
            pool.shutdown(wait=False)  # don't block on the straggler
        self.fabric.client_rx += len(blob)
        self.fabric.ops += 1
        return blob

    def exec(self, name: str, ops: list[ObjOp]) -> Any:
        """Execute an objclass pipeline ON the object's primary OSD and
        return only the result — the pushdown path.  Only the result size
        crosses the client<->storage fabric."""
        err: Exception | None = None
        for osd_id in self._acting(name):
            try:
                result, scanned = self._osd(osd_id).exec_cls(name, ops)
                self.fabric.local_bytes += scanned
                self.fabric.client_rx += _result_nbytes(result)
                self.fabric.ops += 1
                return result
            except (OSDDown, ObjectNotFound) as e:
                err = e
        raise err if err else ObjectNotFound(name)

    def exec_many(self, names: Iterable[str], ops: list[ObjOp],
                  workers: int = 8) -> list[Any]:
        names = list(names)
        with ThreadPoolExecutor(max_workers=max(1, workers)) as pool:
            return list(pool.map(lambda n: self.exec(n, ops), names))

    def delete(self, name: str) -> None:
        for osd_id in self.cluster.up_osds:
            osd = self.osds[osd_id]
            with osd.lock:
                osd.data.pop(name, None)
                osd.xattrs.pop(name, None)

    def exists(self, name: str) -> bool:
        return any(name in self.osds[o].data for o in self.cluster.up_osds)

    def list_objects(self, prefix: str = "") -> list[str]:
        seen: set[str] = set()
        for o in self.cluster.up_osds:
            seen |= {n for n in self.osds[o].object_names()
                     if n.startswith(prefix)}
        return sorted(seen)

    def xattr(self, name: str) -> dict:
        for osd_id in self._acting(name):
            osd = self.osds[osd_id]
            if name in osd.xattrs:
                return osd.xattrs[name]
        return {}

    # ------------------------------------------------------------ failures
    def fail_osd(self, osd_id: str) -> None:
        """Disk loss: data gone, OSD marked down, epoch bumped."""
        old = self.cluster
        self.cluster = old.mark_down(osd_id)
        self.osds[osd_id] = OSD(osd_id, self.disk_bw)  # data destroyed

    def add_osds(self, ids: Iterable[str]) -> None:
        ids = list(ids)
        self.cluster = self.cluster.add_osds(ids)
        for i in ids:
            self.osds[i] = OSD(i, self.disk_bw)

    def recover(self, old_map: ClusterMap | None = None) -> dict:
        """Peering: for every object, ensure each OSD in the (new) acting
        set holds a copy, sourcing from any surviving replica.  Returns
        recovery stats.  Runs after fail_osd/add_osds."""
        moved = missing = 0
        for name in self.list_objects():
            acting = self._acting(name)
            src_blob = None
            src_xattr: dict = {}
            for osd_id in self.cluster.up_osds:
                osd = self.osds[osd_id]
                if name in osd.data:
                    src_blob = osd.data[name]
                    src_xattr = osd.xattrs.get(name, {})
                    break
            if src_blob is None:
                missing += 1  # all replicas lost (over-failure)
                continue
            for osd_id in acting:
                osd = self._osd(osd_id)
                if name not in osd.data:
                    osd.put(name, src_blob, src_xattr)
                    self.fabric.recovery_bytes += len(src_blob)
                    moved += 1
        return {"objects_moved": moved, "objects_lost": missing,
                "epoch": self.cluster.epoch}

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        return {
            "fabric": self.fabric.snapshot(),
            "epoch": self.cluster.epoch,
            "osd_bytes": {o: self.osds[o].nbytes()
                          for o in self.cluster.osds},
            "n_objects": len(self.list_objects()),
        }


def _result_nbytes(result: Any) -> int:
    if isinstance(result, (bytes, bytearray)):
        return len(result)
    if isinstance(result, dict):
        import numpy as np
        n = 0
        for v in result.values():
            n += np.asarray(v).nbytes
        return n
    return 64  # scalar-ish


def make_store(n_osds: int, *, replicas: int = 3, n_pgs: int = 128,
               prefix: str = "osd", client_bw: float | None = None,
               disk_bw: float | None = None) -> ObjectStore:
    cm = ClusterMap(tuple(f"{prefix}.{i}" for i in range(n_osds)),
                    n_pgs=n_pgs, replicas=min(replicas, n_osds))
    return ObjectStore(cm, client_bw=client_bw, disk_bw=disk_bw)
