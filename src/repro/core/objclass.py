"""Storage-side operation registry — Ceph object classes / SkyhookDM
extensions (paper §2 goal 2, §4.2).

An ``ObjOp`` is a named operation executed *inside* an OSD against one
object's block.  A pipeline ``[select, filter, project, agg]`` runs
server-side and only the (usually much smaller) result crosses the wire.

Composability (paper §3.2) is explicit: every op declares whether it is
*decomposable* — i.e. per-object partials exist with an associative
``combine`` — or *holistic* (median & friends), which forces a gather of
its input to the client unless an approximate decomposable form is
accepted (we provide a P² quantile estimator as that approximation).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable, Mapping

import numpy as np

from repro.core import expr as ex
from repro.core import format as fmt
from repro.core.logical import Dataspace, Hyperslab


@dataclasses.dataclass(frozen=True)
class ObjOp:
    """One pipeline stage: ``op(name, **params)``."""

    name: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"name": self.name, "params": dict(self.params)}

    @staticmethod
    def from_json(d: dict) -> "ObjOp":
        return ObjOp(d["name"], d.get("params", {}))


def op(name: str, **params: Any) -> ObjOp:
    return ObjOp(name, params)


@dataclasses.dataclass(frozen=True)
class OpImpl:
    local: Callable[..., Any]              # table -> table | partial
    combine: Callable[[list], Any] | None  # partials -> result (if decomp.)
    decomposable: bool
    table_in: bool = True                  # consumes a table (vs a partial)
    table_out: bool = True                 # emits a table (vs a partial)
    # associative partials -> ONE partial (same shape as ``local``'s
    # output).  Unlike ``combine`` (partials -> final result) a merge can
    # run *on the OSD*, folding its local partials into a single partial
    # per batched request — the server-side half of a two-level combine.
    merge: Callable[[list], Any] | None = None


_REGISTRY: dict[str, OpImpl] = {}


def register(name: str, impl: OpImpl) -> None:
    if name in _REGISTRY:
        raise KeyError(f"op {name!r} already registered")
    _REGISTRY[name] = impl


def get_impl(name: str) -> OpImpl:
    if name not in _REGISTRY:
        raise KeyError(f"unknown objclass op {name!r}; "
                       f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# built-in ops (tables are dict[str, np.ndarray])
# --------------------------------------------------------------------------


def _select(table, rows: tuple[int, int]):
    s, e = rows
    return {k: v[s:e] for k, v in table.items()}


def _project(table, cols: list[str]):
    missing = [c for c in cols if c not in table]
    if missing:
        raise KeyError(f"project: missing {missing}")
    return {c: table[c] for c in cols}


def _filter_expr(params: Mapping) -> ex.Expr:
    """The expression of one ``filter`` op: a predicate tree in
    ``expr`` (wire dict or Expr), or the legacy flat
    ``(col, cmp, value)`` params — normalized to ONE representation so
    every layer walks the same tree."""
    e = params.get("expr")
    if e is not None:
        return ex.ensure(e)
    return ex.Cmp(params["col"], params["cmp"], params["value"])


def _filter(table, **params):
    """Tree-walking filter: one vectorized numpy mask per leaf, mask
    combinators per node (``expr.Expr.mask``)."""
    flat = _filter_expr(params).mask(table)
    return {k: v[flat] for k, v in table.items()}


# ---- decomposable aggregates: partial = dict of ndarrays ----


def _agg_local(table, col: str, fn: str):
    a = np.asarray(table[col], dtype=np.float64).ravel()
    if fn == "count":
        return {"count": np.float64(a.size)}
    if a.size == 0:  # identity partials
        if fn == "mean":
            return {"sum": np.float64(0.0), "count": np.float64(0)}
        ident = {"sum": 0.0, "min": np.inf, "max": -np.inf}
        return {fn: np.float64(ident[fn])}
    if fn == "sum":
        return {"sum": a.sum()}
    if fn == "min":
        return {"min": a.min()}
    if fn == "max":
        return {"max": a.max()}
    if fn == "mean":
        return {"sum": a.sum(), "count": np.float64(a.size)}
    raise ValueError(fn)


def _agg_merge(partials: list, fn: str, **_):
    """Fold agg partials into ONE partial of the same shape (associative,
    so OSD-merged partials re-merge/combine exactly like raw ones)."""
    keys = set().union(*(p.keys() for p in partials))
    out = {}
    for k in keys:
        vals = [p[k] for p in partials]
        if k == "min":
            out[k] = np.float64(min(vals))
        elif k == "max":
            out[k] = np.float64(max(vals))
        else:  # sum / count accumulate
            out[k] = np.float64(sum(vals))
    return out


def _agg_combine(partials: list, fn: str, **_):
    if not partials:  # everything pruned/filtered: identity element
        return {"sum": 0.0, "count": 0.0, "min": float("inf"),
                "max": float("-inf"), "mean": 0.0}[fn]
    if fn == "sum":
        return float(sum(p["sum"] for p in partials))
    if fn == "count":
        return float(sum(p["count"] for p in partials))
    if fn == "min":
        return float(min(p["min"] for p in partials))
    if fn == "max":
        return float(max(p["max"] for p in partials))
    if fn == "mean":
        c = sum(p["count"] for p in partials)
        return float(sum(p["sum"] for p in partials) / max(c, 1.0))
    raise ValueError(fn)


# ---- multi-aggregate: N decomposable aggregates as ONE mergeable tail ----


def _magg_key(fn: str, col: str) -> str:
    return f"{fn}({col})"


def _magg_local(table, specs):
    """Partial = one agg partial per (fn, col) spec, keyed "fn(col)"."""
    return {_magg_key(fn, col): _agg_local(table, col, fn)
            for fn, col in specs}


def _magg_merge(partials: list, specs, **_):
    return {_magg_key(fn, col):
            _agg_merge([p[_magg_key(fn, col)] for p in partials], fn)
            for fn, col in specs}


def _magg_combine(partials: list, specs, **_):
    return {_magg_key(fn, col):
            _agg_combine([p[_magg_key(fn, col)] for p in partials], fn)
            for fn, col in specs}


# ---- holistic: exact median (NOT decomposable) ----


def _median_local(table, col: str):
    # the "local" part of a holistic op can only project its input column
    return {col: np.asarray(table[col]).ravel()}


def median_exact(columns: list[dict], col: str) -> float:
    allv = np.concatenate([p[col] for p in columns]) if columns else \
        np.zeros((0,))
    return float(np.median(allv)) if allv.size else float("nan")


# ---- decomposable approximation: fixed-bin quantile sketch ----


def _qsketch_local(table, col: str, lo: float, hi: float, bins: int = 1024):
    a = np.asarray(table[col], dtype=np.float64).ravel()
    hist, _ = np.histogram(a, bins=bins, range=(lo, hi))
    return {"hist": hist.astype(np.int32), "lo": lo, "hi": hi,
            "n": np.int64(a.size)}


def _qsketch_merge(partials: list, **_):
    """Histograms add; the merged sketch is shape-identical to a local
    one, so sketches merged per OSD combine exactly like raw partials."""
    return {"hist": np.sum([p["hist"] for p in partials],
                           axis=0).astype(np.int32),
            "lo": partials[0]["lo"], "hi": partials[0]["hi"],
            "n": np.int64(sum(int(p["n"]) for p in partials))}


def _qsketch_combine(partials: list, q: float = 0.5, **_):
    if not partials:
        return float("nan")
    hist = np.sum([p["hist"] for p in partials], axis=0)
    n = int(sum(int(p["n"]) for p in partials))
    lo, hi = partials[0]["lo"], partials[0]["hi"]
    if n == 0:
        return float("nan")
    cum = np.cumsum(hist)
    idx = int(np.searchsorted(cum, q * n))
    idx = min(idx, len(hist) - 1)
    edges = np.linspace(lo, hi, len(hist) + 1)
    return float(0.5 * (edges[idx] + edges[idx + 1]))


# ---- codecs as ops (paper's `compress` offload) ----


def _recompress(table, codecs: Mapping[str, str]):
    # physical transformation executed storage-side; returns a table
    # (the LocalVOL re-encodes with the new codecs on write-back)
    return table


register("select", OpImpl(_select, None, decomposable=True))
register("project", OpImpl(_project, None, decomposable=True))
register("filter", OpImpl(_filter, None, decomposable=True))
register("agg", OpImpl(
    _agg_local, _agg_combine, decomposable=True, table_out=False,
    merge=_agg_merge))
register("multi_agg", OpImpl(
    _magg_local, _magg_combine, decomposable=True, table_out=False,
    merge=_magg_merge))
register("median", OpImpl(
    _median_local, None, decomposable=False, table_out=False))
register("quantile_sketch", OpImpl(
    _qsketch_local, _qsketch_combine, decomposable=True, table_out=False,
    merge=_qsketch_merge))
register("recompress", OpImpl(_recompress, None, decomposable=True))


# ---- zero-decode packed-row select (server-local optimization, §3.3) ----


def select_packed(blob: bytes, rows: tuple[int, int], col: str) -> dict:
    """Slice whole rows out of a planar-bitpacked column WITHOUT decoding.

    Works because each row of a (S,)-shaped int column with S % 32 == 0
    occupies exactly S/32 word-groups: the OSD can serve a row range as a
    contiguous word slice.  The client (or the TPU shard) does the unpack
    — this is the storage-side `compress` offload staying compressed all
    the way down the wire and into HBM.
    """
    header = fmt.block_header(blob)
    if header["layout"] != "col":
        raise ValueError("select_packed needs col layout")
    import struct as _struct
    (hlen,) = _struct.unpack("<I", blob[4:8])
    off = 8 + hlen
    for c, blen in zip(header["columns"], header["lens"]):
        if c["name"] == col:
            if not c["codec"].startswith("bitpack"):
                raise ValueError(f"{col} is not bitpacked ({c['codec']})")
            bits = int(c["codec"][len("bitpack"):])
            shape = c["shape"]
            if len(shape) != 2 or shape[1] % 32:
                raise ValueError(f"need (n_rows, S%32==0), got {shape}")
            n_rows, S = shape
            gpr = S // 32                       # word-groups per row
            words = np.frombuffer(
                blob, np.uint32, count=n_rows * gpr * bits,
                offset=off).reshape(n_rows, gpr, bits)
            s, e = rows
            return {"packed": words[s:e].copy(),
                    "bits": np.int64(bits), "seq_len": np.int64(S)}
        off += blen
    raise KeyError(col)


register("select_packed", OpImpl(
    lambda *a, **k: None, None, decomposable=True, table_out=False))


# ---- OSD-resolved row ranges (pushed-down row-range pruning) ----


def _row_slice_unresolved(table, rows):
    raise ValueError(
        "row_slice carries GLOBAL dataset rows; resolve it against the "
        "object's extent first (resolve_row_slice — on the OSD, from "
        "its own 'rows' xattr)")


register("row_slice", OpImpl(_row_slice_unresolved, None,
                             decomposable=True))


def has_row_slice(ops: list[ObjOp]) -> bool:
    return any(o.name == "row_slice" for o in ops)


def resolve_row_slice(ops: list[ObjOp], extent: tuple[int, int],
                      clamp: bool = False) -> list[ObjOp] | None:
    """Rewrite every ``row_slice`` op (GLOBAL dataset rows) into this
    object's local ``select``, given the object's CURRENT extent
    ``(row_start, row_stop)`` — on the OSD from its own ``rows`` xattr,
    so a compiled plan keeps serving correct rows after the dataset is
    re-partitioned under it.  Returns None when a slice is provably
    disjoint from the extent (the object serves no rows — a
    prune-equivalent skip), unless ``clamp`` forces an empty
    ``select(0, 0)`` instead (positional responses need a result)."""
    out: list[ObjOp] = []
    for o in ops:
        if o.name != "row_slice":
            out.append(o)
            continue
        g0, g1 = (int(v) for v in o.params["rows"])
        s0, s1 = int(extent[0]), int(extent[1])
        lo, hi = max(g0, s0), min(g1, s1)
        if lo >= hi:
            if not clamp:
                return None
            lo = hi = s0
        out.append(op("select", rows=(lo - s0, hi - s0)))
    return out


# ---- OSD-resolved N-d hyperslab selection (dataspace pushdown) ----


def _hyperslab_unresolved(table, **_):
    raise ValueError(
        "hyperslab_slice carries a GLOBAL N-d selection; resolve it "
        "against the object's chunk extent first (resolve_hyperslab — "
        "on the OSD, from its own 'chunks' xattr)")


def _hyperslab_local(table, space, sel, chunk_start, cids):
    """Resolved executor: slice the selected cells out of this object's
    stacked ``(k, *chunk)`` block.  Emits a two-column table — ``cells``
    (the selected values, C-order per chunk piece) and ``chunk`` (the
    global chunk id of each cell) — because the block format requires
    equal-length columns; the client re-derives each piece's N-d
    placement from (selection ∩ chunk slab), so chunk-id runs are the
    only per-cell overhead on the wire.  Chunks are stored padded to the
    full chunk shape; selections never reach the pad because the
    intersection is clipped to the dataspace's logical shape."""
    sp = Dataspace.from_json(space)
    hs = Hyperslab.from_json(sel)
    data = np.asarray(table["data"])
    cells, ids = [], []
    for local in cids:
        cid = int(chunk_start) + int(local)
        r = hs.intersect_slab(sp.chunk_slab(cid))
        if r is None:
            continue
        locs, _offs, _counts = r
        piece = data[local][tuple(slice(*l) for l in locs)]
        cells.append(np.ascontiguousarray(piece).ravel())
        ids.append(np.full(piece.size, cid, dtype=np.int32))
    if cells:
        return {"cells": np.concatenate(cells),
                "chunk": np.concatenate(ids)}
    return {"cells": np.zeros(0, dtype=np.dtype(sp.dtype)),
            "chunk": np.zeros(0, dtype=np.int32)}


register("hyperslab_slice", OpImpl(_hyperslab_unresolved, None,
                                   decomposable=True))
register("hyperslab_local", OpImpl(_hyperslab_local, None,
                                   decomposable=True))


def has_hyperslab(ops: list[ObjOp]) -> bool:
    return any(o.name == "hyperslab_slice" for o in ops)


def resolve_hyperslab(ops: list[ObjOp], chunks: tuple[int, int],
                      chunk_zone_maps=None, where=None,
                      clamp: bool = False
                      ) -> tuple[list[ObjOp] | None, int]:
    """Rewrite every ``hyperslab_slice`` op (GLOBAL N-d selection) into
    this object's local ``hyperslab_local``, given the object's CURRENT
    chunk extent ``[chunk_start, chunk_stop)`` — on the OSD from its own
    ``chunks`` xattr, the same late-binding contract as
    :func:`resolve_row_slice`, so a compiled plan keeps serving correct
    cells after the array is re-chunked/re-partitioned under it.

    ``chunk_zone_maps`` (per-LOCAL-chunk zone maps from the object's
    xattrs, computed over UNPADDED chunk values) plus the request's
    ``where`` prune expression drop whole chunks before any cell is
    touched; the count of dropped chunks is returned so the serve layer
    can meter OSD-side chunk pruning.  Returns ``(None, n_pruned)``
    when the object serves no cells (disjoint selection, or every
    intersecting chunk pruned) — a prune-equivalent skip — unless
    ``clamp`` forces an empty result instead (positional responses)."""
    pred = ex.ensure_pred(where)
    out: list[ObjOp] = []
    n_pruned = 0
    served_any = False
    for o in ops:
        if o.name != "hyperslab_slice":
            out.append(o)
            continue
        sp = Dataspace.from_json(o.params["space"])
        hs = Hyperslab.from_json(o.params["sel"])
        c0, c1 = int(chunks[0]), int(chunks[1])
        cids = [cid for cid in sp.chunk_ids_overlapping(hs)
                if c0 <= cid < c1]
        if pred is not None and chunk_zone_maps is not None:
            kept = []
            for cid in cids:
                zm = chunk_zone_maps[cid - c0]
                if zm is not None and pred.prunes(zm):
                    n_pruned += 1
                else:
                    kept.append(cid)
            cids = kept
        served_any = served_any or bool(cids)
        out.append(op("hyperslab_local", space=o.params["space"],
                      sel=o.params["sel"], chunk_start=c0,
                      cids=[cid - c0 for cid in cids]))
    if not served_any and not clamp:
        return None, n_pruned
    return out, n_pruned


# --------------------------------------------------------------------------
# zone-map pruning (shared by the client planner and the OSDs)
# --------------------------------------------------------------------------


def normalize_exprs(ops: list[ObjOp]) -> list[ObjOp]:
    """Parse each ``filter`` op's serialized expression ONCE per
    request (wire dict -> Expr), so per-object evaluation and column
    analysis reuse the parsed tree instead of re-parsing it per
    object."""
    out: list[ObjOp] = []
    for o in ops:
        e = o.params.get("expr") if o.name == "filter" else None
        if e is not None and not isinstance(e, ex.Expr):
            o = ObjOp(o.name, {**o.params, "expr": ex.ensure(e)})
        out.append(o)
    return out


def filter_predicates(ops: list[ObjOp]) -> ex.Expr | None:
    """The conjunction of every ``filter`` op's expression tree — the
    ONE predicate a prune decision consults (None: no filters)."""
    return ex.conj_all(_filter_expr(o.params)
                       for o in ops if o.name == "filter")


def zone_map_prunes(zone_map: Mapping, predicates) -> bool:
    """True when the zone map PROVES the filter expression matches no
    row of the object — interval arithmetic over the predicate tree
    (``expr.Expr.prunes``): a leaf prunes when its [lo, hi] interval is
    disjoint from the matching set, ``And`` prunes if ANY child prunes,
    ``Or`` only if ALL children prune, ``Not``/unknown leaves never
    prune — conservative by construction.

    This is the one prune rule in the system: ``GlobalVOL.plan`` applies
    it to cached zone maps (client-side prune) and ``OSD.exec_cls_batch``
    applies it to the object's CURRENT xattrs (pushed-down prune), so
    the two strategies always agree on identical metadata.
    ``predicates`` may be an :class:`~repro.core.expr.Expr`, its wire
    dict, or the legacy iterable of (col, cmp, value) triples.
    """
    pred = ex.ensure_pred(predicates)
    return pred is not None and pred.prunes(zone_map)


# --------------------------------------------------------------------------
# pipeline execution (runs ON the OSD — see core.store)
# --------------------------------------------------------------------------


def pipeline_decomposable(ops: list[ObjOp]) -> bool:
    return all(get_impl(o.name).decomposable for o in ops)


def pipeline_mergeable(ops: list[ObjOp]) -> bool:
    """True when per-object partials can be folded server-side: the whole
    pipeline is decomposable and the tail emits partials with an
    associative ``merge`` — the precondition for the per-OSD combine
    (one partial per OSD request instead of one per object)."""
    if not ops:
        return False
    tail = get_impl(ops[-1].name)
    return (pipeline_decomposable(ops) and not tail.table_out
            and tail.combine is not None and tail.merge is not None)


def merge_partials(ops: list[ObjOp], partials: list) -> Any:
    """Server-side (per-OSD) fold: partials -> ONE same-shaped partial."""
    tail = ops[-1]
    impl = get_impl(tail.name)
    if impl.merge is None:
        raise ValueError(f"{tail.name} has no partial merge")
    return impl.merge(partials, **tail.params)


# ops whose column needs are fully described by a single "col" param
_SINGLE_COL_OPS = frozenset({"agg", "median", "quantile_sketch"})
# ops that touch no columns at all (pure row-range slicing)
_COL_FREE_OPS = frozenset({"select", "row_slice"})


def required_columns(ops: list[ObjOp]) -> list[str] | None:
    """Minimal column set a pipeline needs decoded, or None for "all".

    The whole pipeline is analyzed — not just a leading ``project`` — so
    a filter→agg scan decodes only the filter and aggregate columns.
    Returns None (decode everything) when the pipeline's *output* is the
    full table (table-out tail with no projection) or when it contains
    an op we cannot analyze (e.g. ``recompress``), which keeps results
    bit-identical to the full-decode path in every case.
    """
    if not ops:
        return None
    needed: set[str] = set()
    have_project = False
    for o in ops:
        if o.name in _COL_FREE_OPS:
            continue
        if o.name == "project":
            needed.update(o.params["cols"])
            have_project = True
            continue
        if o.name == "filter":
            needed.update(_filter_expr(o.params).columns())
            continue
        if o.name in _SINGLE_COL_OPS:
            needed.add(o.params["col"])
            continue
        if o.name == "multi_agg":
            needed.update(col for _, col in o.params["specs"])
            continue
        return None  # unknown/pass-through op: be conservative
    tail = get_impl(ops[-1].name)
    if tail.table_out and not have_project:
        return None  # output carries every column: decode all
    return sorted(needed)


def decode_pipeline(blob: bytes, ops: list[ObjOp]) -> dict:
    """The decode half of :func:`run_pipeline`: the minimal column
    table the pipeline needs, straight from the block.  Split out so
    the OSD result cache can keep decoded column sets around and feed
    them back through :func:`apply_pipeline` without touching the blob
    again (the decode is the service cost the cache elides)."""
    return fmt.decode_block(blob, columns=required_columns(ops))


def apply_pipeline(table: dict, ops: list[ObjOp],
                   encode: bool = True) -> Any:
    """The post-decode half of :func:`run_pipeline`: run the op chain
    over an already-decoded column table.  Every built-in op builds a
    NEW dict (slices/masks/partials) and never mutates its input, so a
    cached table can be replayed through any number of pipelines."""
    out: Any = table
    for o in ops:
        impl = get_impl(o.name)
        if not impl.table_in and not isinstance(out, dict):
            raise TypeError(f"{o.name}: pipeline type mismatch")
        out = impl.local(out, **o.params)
        if not impl.table_out:
            return out  # partial; must be the last op
    return fmt.encode_block(out) if encode else out


def run_pipeline(blob: bytes, ops: list[ObjOp], encode: bool = True) -> Any:
    """Execute a pipeline against one object's block, server-side.

    Returns either an encoded table block (table-out pipelines) or a
    partial (dict of small ndarrays) for aggregate tails.  Column
    pruning is computed from the *whole* pipeline (filter cols + agg /
    median / sketch cols + projection — :func:`required_columns`) and
    pushed into block decoding, so a filter→agg scan never decodes
    untouched columns (col layout).  Bitpack columns decode through the
    Pallas kernel (``kernels/bitunpack``) when a jax device backend is
    selected, with the numpy butterfly codec as the bit-exact fallback
    (``format.set_bitunpack_backend``).

    ``encode=False`` returns a table-out result as the raw column dict
    instead of an encoded block — the per-OSD concat path uses it to
    fold many result tables into ONE framed block without a redundant
    encode/decode round per object.
    """
    if ops and ops[0].name == "select_packed":
        if len(ops) != 1:
            raise ValueError("select_packed must be the only op")
        return select_packed(blob, **ops[0].params)
    return apply_pipeline(decode_pipeline(blob, ops), ops, encode=encode)


def _canon(v: Any) -> Any:
    """Canonical JSON-able form of one op-param value: Exprs flatten to
    their wire dicts, numpy scalars/arrays to plain lists, tuples to
    lists — so a pipeline built from wire dicts and its normalized
    (parsed-Expr) twin digest identically."""
    if isinstance(v, ex.Expr):
        return v.to_json()
    if isinstance(v, Mapping):
        return {str(k): _canon(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_canon(x) for x in v]
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    return v


def pipeline_digest(ops: list[ObjOp]) -> str:
    """A stable content digest of one pipeline — the pipeline/columns
    half of the OSD result-cache key ``(name, version, digest)``.  Two
    pipelines digest equal iff their canonical serialized forms match,
    so a shared-plan re-scan hits while any changed filter value,
    projection, or row range misses."""
    payload = [{"name": o.name, "params": _canon(o.params)} for o in ops]
    return hashlib.sha1(
        json.dumps(payload, sort_keys=True, separators=(",", ":"),
                   default=repr).encode()).hexdigest()


def compact_merge(blobs: list[bytes], *, layout: str = "col",
                  codecs: Mapping[str, str] | None = None
                  ) -> tuple[bytes, dict]:
    """OSD-side small-object merge: fold a run of consecutive blocks
    into ONE re-encoded block (row order preserved) and return it with
    the merged table's zone map.  The maintenance plane's compactor uses
    this to collapse one-blob-per-append ``ckpt``/kvcache runs into
    target-sized objects without the rows ever leaving the storage side;
    codecs are re-derived for the merged value range
    (``format.auto_codecs``) unless pinned by the caller."""
    if not blobs:
        raise ValueError("compact_merge of zero blocks")
    tables = [fmt.decode_block(b) for b in blobs]
    keys = list(tables[0].keys())
    for t in tables[1:]:
        if list(t.keys()) != keys:
            raise ValueError("compact_merge: schema mismatch across run")
    merged = {k: np.concatenate([np.asarray(t[k]) for t in tables],
                                axis=0)
              for k in keys}
    blob = fmt.encode_block(
        merged, layout=layout,
        codecs=codecs if codecs is not None else fmt.auto_codecs(merged))
    return blob, fmt.zone_map(merged)


def _compact_unresolved(table, **_):
    raise ValueError(
        "compact_merge folds whole encoded blocks, not one object's "
        "table; it is dispatched via OSD.compact_merge by the "
        "maintenance plane, never through a scan pipeline")


register("compact_merge", OpImpl(_compact_unresolved, None,
                                 decomposable=False, table_out=False))


def concat_encode(tables: list[Mapping[str, np.ndarray]]) -> bytes:
    """Server-side table concat: fold result tables into ONE encoded
    block (item order preserved) — the table-out analogue of
    ``merge_partials``."""
    keys = list(tables[0].keys())
    return fmt.encode_block(
        {k: np.concatenate([np.asarray(t[k]) for t in tables], axis=0)
         for k in keys})


def table_n_rows(table: Mapping[str, np.ndarray]) -> int:
    for v in table.values():
        return int(np.asarray(v).shape[0])
    return 0


def combine_partials(ops: list[ObjOp], partials: list) -> Any:
    """Client/driver-side combine for the pipeline's terminal op."""
    tail = ops[-1]
    impl = get_impl(tail.name)
    if impl.table_out:
        raise ValueError("pipeline ends in a table; use concat instead")
    if impl.combine is None:
        raise ValueError(f"{tail.name} is holistic — no combine; gather "
                         "its projected inputs and compute centrally")
    return impl.combine(partials, **tail.params)
