"""Fault-injection harness for the self-healing storage plane.

A :class:`FaultInjector` hangs off an :class:`~repro.core.store.ObjectStore`
and gives tests/benchmarks a controlled way to produce the gray failures
the paper's "failure management" claim is about — not just fail-stop
(``store.fail_osd``) but the nastier middle ground:

* **bit rot** — :meth:`flip_bits` mutates stored bytes in place on one
  replica; the stamped digest no longer matches, so any read path that
  touches the copy quarantines it and fails over (``scrub()`` finds it
  proactively).
* **torn write** — :meth:`tear_write` drops an object's xattrs on one
  replica while leaving the blob: the write landed but its metadata
  (digest, version, extent) did not — the classic crash between the two
  mutations of a non-atomic update.
* **slow OSD** — :meth:`slow` adds per-request latency to one daemon,
  exercising the hedged-read/straggler machinery without killing it.
* **transient failures** — :meth:`transient_failures` makes the next N
  requests to one OSD raise :class:`~repro.core.store.TransientOSDError`
  and then recover, exercising the client's deadline/backoff retry layer.

Injection bypasses every request hook (it mutates OSD state directly
under the OSD lock), so injecting a fault is never itself subject to
faults.  Every injected corruption is recorded in :attr:`injected` so a
harness can assert ``fabric.corruptions_detected`` == injected.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from repro.core.store import ObjectStore, OSD, TransientOSDError


@dataclass
class _OSDFaults:
    """Mutable per-OSD fault state, consulted by ``OSD._touch``."""
    slow_extra_s: float = 0.0
    transient_left: int = 0


@dataclass
class _Injection:
    """Record of one injected corruption (for detection accounting)."""
    kind: str          # "bitflip" | "torn"
    name: str
    osd_id: str


class FaultInjector:
    """Deterministic fault source wired into one store's OSDs.

    Construct with the store; the injector attaches itself to
    ``store.faults`` and to every live OSD (and ``fail_osd``/``add_osds``
    re-attach it to replacement daemons), so its :meth:`on_request` hook
    fires at the top of every served request.
    """

    def __init__(self, store: ObjectStore):
        self.store = store
        self._lock = threading.Lock()
        self._per_osd: dict[str, _OSDFaults] = {}
        self.injected: list[_Injection] = []
        store.faults = self
        for osd in store.osds.values():
            self.attach_osd(osd)

    # ------------------------------------------------------------ wiring
    def attach_osd(self, osd: OSD) -> None:
        osd.faults = self

    def _state(self, osd_id: str) -> _OSDFaults:
        with self._lock:
            return self._per_osd.setdefault(osd_id, _OSDFaults())

    # ------------------------------------------------------------ hook
    def on_request(self, osd_id: str) -> None:
        """Called by ``OSD._touch`` at the top of every served request —
        on the serving thread, so the slow-OSD sleep stalls exactly the
        requests that hit the slow daemon."""
        st = self._state(osd_id)
        with self._lock:
            extra = st.slow_extra_s
            fail = st.transient_left > 0
            if fail:
                st.transient_left -= 1
        if extra:
            time.sleep(extra)
        if fail:
            raise TransientOSDError(
                f"{osd_id}: injected transient failure")

    # ------------------------------------------------------------ faults
    def flip_bits(self, name: str, osd_id: str | None = None,
                  n_bits: int = 1) -> str:
        """Corrupt one stored replica in place (bit rot).  Flips
        ``n_bits`` bits spread across the blob on ``osd_id`` (default:
        the first up OSD holding a copy).  Returns the OSD hit."""
        osd = self._holder(name, osd_id)
        with osd.lock:
            blob = bytearray(osd.data[name])
            for k in range(max(1, n_bits)):
                pos = (k * 2654435761) % len(blob)  # spread, deterministic
                blob[pos] ^= 1 << (k % 8)
            osd.data[name] = bytes(blob)
        self.injected.append(_Injection("bitflip", name, osd.osd_id))
        return osd.osd_id

    def tear_write(self, name: str, osd_id: str | None = None) -> str:
        """Tear one replica: the blob stays but its xattrs vanish — the
        write landed, the metadata commit did not.  Returns the OSD
        hit."""
        osd = self._holder(name, osd_id)
        with osd.lock:
            osd.xattrs.pop(name, None)
        self.injected.append(_Injection("torn", name, osd.osd_id))
        return osd.osd_id

    def slow(self, osd_id: str, extra_s: float) -> None:
        """Make every request served by ``osd_id`` take ``extra_s``
        extra seconds (0 restores normal speed)."""
        with self._lock:
            self._per_osd.setdefault(osd_id, _OSDFaults()) \
                .slow_extra_s = float(extra_s)

    def transient_failures(self, osd_id: str, n: int) -> None:
        """Arm ``osd_id`` to fail its next ``n`` requests with
        :class:`TransientOSDError`, then serve normally — the
        fail-N-then-succeed gray failure the retry layer is for."""
        with self._lock:
            self._per_osd.setdefault(osd_id, _OSDFaults()) \
                .transient_left = int(n)

    def clear(self) -> None:
        """Disarm all per-OSD latency/transient faults (injected
        corruption stays — that is damage, not a knob)."""
        with self._lock:
            self._per_osd.clear()

    def campaign(self, names: list[str], *, flips: int = 3,
                 torn: int = 1, seed: int = 0) -> list[_Injection]:
        """A churn campaign against the scrub walker: inject ``flips``
        bit-rot faults and ``torn`` torn writes across DISTINCT
        ``(object, OSD)`` targets, always on a CURRENT acting-set
        holder (so the damage is in service, not on a stray), and never
        corrupting more than ``replicas - 1`` copies of one object —
        the walker must always have a verified copy to heal from.
        Deterministic per ``seed``.  Returns the injections placed
        (also appended to :attr:`injected`); fewer than requested when
        the name list can't support the budget safely."""
        import random as _random
        rng = _random.Random(seed)
        per_name: dict[str, int] = {}
        used: set[tuple[str, str]] = set()
        placed: list[_Injection] = []
        want = [("bitflip", flips), ("torn", torn)]
        for kind, budget in want:
            k = 0
            attempts = 0
            while k < budget and attempts < 64 * max(1, budget):
                attempts += 1
                name = rng.choice(names)
                acting = self.store.cluster.locate(name)
                cap = max(1, len(acting) - 1)
                if per_name.get(name, 0) >= cap:
                    continue
                holders = []
                for o in acting:
                    osd = self.store.osds[o]
                    with osd.lock:
                        held = name in osd.data
                    if held and (name, o) not in used:
                        holders.append(o)
                if not holders:
                    continue
                osd_id = rng.choice(holders)
                if kind == "bitflip":
                    self.flip_bits(name, osd_id)
                else:
                    self.tear_write(name, osd_id)
                used.add((name, osd_id))
                per_name[name] = per_name.get(name, 0) + 1
                placed.append(self.injected[-1])
                k += 1
        return placed

    # ------------------------------------------------------------ accounting
    @property
    def corruptions_injected(self) -> int:
        return len(self.injected)

    # ------------------------------------------------------------ helpers
    def _holder(self, name: str, osd_id: str | None) -> OSD:
        if osd_id is not None:
            osd = self.store.osds[osd_id]
            with osd.lock:
                held = name in osd.data
            if not held:
                raise KeyError(f"{name} not on {osd_id}")
            return osd
        for oid in self.store.cluster.up_osds:
            osd = self.store.osds[oid]
            with osd.lock:
                held = name in osd.data
            if held:
                return osd
        raise KeyError(f"{name}: no up OSD holds a copy")
