"""Composable scan API — ONE plan→compile→execute surface (paper §3.2).

The paper's promise is *composability of access operations* over an
object-mapped dataset.  This module is where that promise lives:

  * :class:`Scan` — a fluent, immutable logical plan.  Filters compose
    as a predicate-expression tree (``.filter`` ANDs a comparison;
    ``.or_``/``.isin``/``.filter_expr`` AND OR-groups, IN-lists,
    ranges, string prefixes, negations — ``core.expr``), aggregates
    compose side by side, a holistic median can opt into its
    decomposable sketch approximation, and a row range restricts the
    scan — all independent of how anything executes::

        vol.scan("events").or_(("run", "<", 10), ("run", ">", 90)) \\
                          .filter("hits", ">=", 3) \\
                          .agg("mean", "e_pt").agg("count", "e_pt") \\
                          .execute()

  * :class:`PhysicalPlan` — what a ``Scan`` compiles to: the storage
    pipeline, the prune strategy, the execution class, and the per-OSD
    request shards.  ``Scan.explain()`` returns it for inspection.

  * :class:`ScanEngine` — the ONE executor.  ``GlobalVOL.read`` /
    ``GlobalVOL.query``, ``SkyhookDriver.execute`` (and its client-side
    baseline), and the training-data loader all route through it; the
    tail/combine/holistic/approx-rewrite decision exists nowhere else.

Execution classes
-----------------
``osd-combine``      mergeable aggregate tails: each OSD folds its local
                     partials (``exec_combine``) — client_rx O(K).
``server-concat``    table-out pipelines: each OSD concatenates its
                     result tables into ONE framed block
                     (``exec_concat``) — rx_frames O(K).
``holistic-gather``  exact median: filters/projection still run
                     storage-side (as a server-concat of the projected
                     column), the holistic tail runs client-side.
``table-gather``     per-object raw results (e.g. zero-decode
                     ``select_packed``) via ``exec_batch``.
``client-gather``    the no-pushdown baseline: full objects to the
                     client, pipeline evaluated locally.

Prune strategies
----------------
``pushdown`` (default): the serialized predicate tree rides inside the
batched objclass request and each OSD prunes against its own CURRENT
zone-map xattrs — zero client zone-map requests, and no plan→execute
TOCTOU window (the OSD can never see a stale zone map).  ``client``:
the classic cached-zone-map prune with version-tag revalidation
(``GlobalVOL.plan``) — kept for workloads that want to skip whole OSD
round trips when everything prunes.  ``none``: scan everything.  Both
strategies share one prune rule (``objclass.zone_map_prunes`` over the
same expression tree), so on identical metadata they prune identical
sets — including ``Or``-of-disjoint-ranges sets no flat conjunction
could prune.

Row ranges ship OSD-side too: ``.rows()`` compiles to a ``row_slice``
op carrying GLOBAL dataset rows; each OSD resolves its objects'
sub-ranges from their own extent (``rows``) xattrs at execute time, so
one compiled plan keeps serving correct rows after the dataset is
re-partitioned under it — and a row-ranged aggregate now rides the
per-OSD combine plane (shared pipeline) instead of per-object gathers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core import expr as ex
from repro.core import format as fmt
from repro.core import objclass as oc
from repro.core.logical import (Dataspace, Hyperslab, RowRange,
                                concat_tables)
from repro.core.partition import objmap_key

EXEC_OSD_COMBINE = "osd-combine"
EXEC_SERVER_CONCAT = "server-concat"
EXEC_HOLISTIC_GATHER = "holistic-gather"
EXEC_TABLE_GATHER = "table-gather"
EXEC_PARTIAL_GATHER = "partial-gather"
EXEC_CLIENT_GATHER = "client-gather"

PRUNE_STRATEGIES = ("auto", "pushdown", "client", "none")
_AGG_FNS = ("sum", "count", "min", "max", "mean")


# --------------------------------------------------------------------------
# Scan — the fluent logical plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    """An immutable, composable scan description.

    Every fluent call returns a NEW ``Scan`` (the receiver is never
    mutated), so partial scans are safely shareable::

        base = vol.scan("events").filter("run", "<", 50)
        a, _ = base.agg("mean", "e_pt").execute()
        b, _ = base.project("e_pt").execute()

    A ``Scan`` built through ``GlobalVOL.scan`` is *bound* (it knows its
    vol and can ``explain()``/``execute()`` itself); a bare
    ``Scan(dataset=...)`` is a pure value that a driver executes.
    """

    dataset: str | None = None
    predicate: Any = None                   # expr.Expr | None (filter tree)
    projection: tuple[str, ...] | None = None
    aggregates: tuple = ()                  # ((fn, col), ...)
    median_col: str | None = None
    approx: bool = False
    row_range: tuple[int, int] | None = None
    prune_strategy: str = "auto"
    _vol: Any = dataclasses.field(default=None, compare=False, repr=False)
    _runner: Any = dataclasses.field(default=None, compare=False,
                                     repr=False)

    # ------------------------------------------------------------ fluent
    def filter(self, col: str, cmp: str, value) -> "Scan":
        """AND a comparison into the scan's predicate tree."""
        return self.filter_expr(ex.Cmp(col, cmp, value))

    def filter_expr(self, e) -> "Scan":
        """AND an arbitrary predicate expression into the scan: an
        ``expr`` tree (``And``/``Or``/``Not``/``Cmp``/``In``/
        ``Between``/``StrPrefix``), its serialized dict, or a
        ``(col, cmp, value)`` triple."""
        return dataclasses.replace(
            self, predicate=ex.conj(self.predicate, ex.ensure(e)))

    def or_(self, *alternatives) -> "Scan":
        """AND an OR-group of alternatives into the scan::

            scan.or_(("run", "<", 10), ("run", ">", 90))

        Each alternative is an expression or a (col, cmp, value)
        triple.  The whole group prunes an object only when EVERY
        alternative's interval proof empties it."""
        if len(alternatives) < 2:
            raise ValueError("or_ needs at least two alternatives")
        return self.filter_expr(
            ex.Or(tuple(ex.ensure(a) for a in alternatives)))

    def isin(self, col: str, values) -> "Scan":
        """AND an IN-list membership predicate into the scan."""
        return self.filter_expr(ex.In(col, tuple(values)))

    def project(self, *cols: str) -> "Scan":
        if len(cols) == 1 and isinstance(cols[0], (list, tuple)):
            cols = tuple(cols[0])
        if not cols:
            raise ValueError("project needs at least one column")
        return dataclasses.replace(self, projection=tuple(cols))

    def agg(self, fn: str, col: str) -> "Scan":
        """Add an aggregate; N aggregates compile to ONE mergeable
        ``multi_agg`` tail (still one partial per OSD)."""
        if fn == "median":
            return self.median(col)
        if fn not in _AGG_FNS:
            raise ValueError(f"bad aggregate {fn!r}; known: {_AGG_FNS} "
                             "(median via .median())")
        if self.median_col is not None:
            raise ValueError("median is holistic; it cannot compose "
                             "with other aggregates in one scan")
        return dataclasses.replace(
            self, aggregates=self.aggregates + ((fn, col),))

    def median(self, col: str, *, approx: bool = False) -> "Scan":
        """Exact median (holistic gather) or, with ``approx=True``, its
        decomposable quantile-sketch rewrite (paper §3.2)."""
        if self.aggregates:
            raise ValueError("median is holistic; it cannot compose "
                             "with other aggregates in one scan")
        return dataclasses.replace(self, median_col=col, approx=approx)

    def rows(self, rows, stop: int | None = None) -> "Scan":
        """Restrict the scan to a row range: ``.rows(RowRange(a, b))``
        or ``.rows(a, b)``."""
        if stop is not None:
            rows = RowRange(int(rows), int(stop))
        elif not isinstance(rows, RowRange):
            rows = RowRange(*rows)
        return dataclasses.replace(self, row_range=(rows.start, rows.stop))

    def prune(self, strategy: str) -> "Scan":
        if strategy not in PRUNE_STRATEGIES:
            raise ValueError(f"bad prune strategy {strategy!r}; "
                             f"known: {PRUNE_STRATEGIES}")
        return dataclasses.replace(self, prune_strategy=strategy)

    def bind(self, vol, runner=None) -> "Scan":
        """Attach the executing vol (and optionally a scheduling runner
        — e.g. a driver's worker dispatcher) to this scan."""
        return dataclasses.replace(self, _vol=vol, _runner=runner)

    # ------------------------------------------------------------ compile
    def pipeline(self) -> list[oc.ObjOp]:
        """The logical objclass pipeline this scan describes: a row
        range ships as a ``row_slice`` op (GLOBAL rows, resolved per
        object ON the OSD from its extent xattr) and the whole filter
        tree ships serialized inside ONE ``filter`` op's params."""
        ops: list[oc.ObjOp] = []
        if self.row_range is not None:
            ops.append(oc.op("row_slice", rows=tuple(self.row_range)))
        if self.predicate is not None:
            ops.append(oc.op("filter", expr=self.predicate.to_json()))
        if self.projection:
            ops.append(oc.op("project", cols=list(self.projection)))
        if self.median_col is not None:
            ops.append(oc.op("median", col=self.median_col))
        elif len(self.aggregates) == 1:
            fn, col = self.aggregates[0]
            ops.append(oc.op("agg", col=col, fn=fn))
        elif self.aggregates:
            ops.append(oc.op("multi_agg", specs=tuple(self.aggregates)))
        return ops

    def _bound(self, omap=None):
        if self._vol is None:
            raise ValueError("unbound Scan — build it via vol.scan(...) "
                             "or hand it to a SkyhookDriver")
        if omap is None:
            omap = self._vol.open(self.dataset)
        return self._vol.engine, omap

    def explain(self, omap=None) -> "PhysicalPlan":
        engine, omap = self._bound(omap)
        return engine.compile(omap, self)

    def execute(self, omap=None) -> tuple[Any, dict]:
        engine, omap = self._bound(omap)
        before = self._vol.store.fabric.snapshot()
        return engine.execute(engine.compile(omap, self),
                              runner=self._runner, before=before,
                              omap=omap)


def scan(dataset: str) -> Scan:
    """An unbound scan over a named dataset (bind via a vol/driver)."""
    return Scan(dataset=dataset)


# --------------------------------------------------------------------------
# PhysicalPlan — what a Scan compiles to
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    """The compiled form of one scan: what ships, where, and how the
    results come back.  Frozen — executing a plan never mutates it, so
    a plan can be compiled once and executed many times (each execution
    re-reads CURRENT storage state; under ``prune="pushdown"`` even the
    prune decisions are made at execute time, on the OSDs)."""

    dataset: str
    exec_cls: str                    # one of the EXEC_* classes
    prune: str                       # "pushdown" | "client" | "none"
    names: tuple[str, ...]           # kept sub-requests, global row order
    ops: tuple[oc.ObjOp, ...]        # the logical pipeline
    exec_ops: tuple[oc.ObjOp, ...]   # what actually ships (holistic tails
    #                                  ship their projected-gather form)
    pipelines: tuple | None = None   # per-object pipelines (loader
    #                                  runs); None = shared exec_ops
    predicates: Any = None           # expr.Expr tree pushed to the OSDs
    #                                  when prune == "pushdown"
    pruned: tuple[str, ...] = ()     # client-side pruned at compile time
    shards: tuple = ()               # ((osd_id, (name idx, ...)), ...)
    pushdown: bool = False           # pipeline ops run storage-side?
    approx_rewrite: bool = False
    assemble: str = "table"          # "table" | "parts" (loader) |
    #                                  "array" (N-d hyperslab result)
    access: str | None = None        # LocalVOL access-stats kind
    n_objects: int = 0               # dataset size before pruning
    omap_version: int = -1           # store version of the ObjectMap the
    #                                  plan compiled against (-1 unknown):
    #                                  row-sliced plans re-derive ``names``
    #                                  at execute time when the map moved
    array_meta: Any = None           # hyperslab plans: {"space", "sel",
    #                                  "squeeze", "fill"} — what client
    #                                  assembly (and the targeting
    #                                  refresh) needs to rebuild the N-d
    #                                  result from chunk-id-tagged cells


# --------------------------------------------------------------------------
# ScanEngine — the one executor
# --------------------------------------------------------------------------


class ScanEngine:
    """Compiles scans/pipelines to :class:`PhysicalPlan` and executes
    them against the store.

    ``execute`` takes an optional ``runner`` — the driver passes a
    worker-sharding dispatcher (Fig. 4), everything else uses the
    store's own per-OSD batch plane directly.  A runner is transport
    only: it must preserve the store-call semantics, never re-decide
    the plan.

    Runner protocol: ``runner(mode, names, pipelines, predicates,
    shards)`` where mode is ``"combine"`` → ``(partials,
    pruned_names)``, ``"concat"`` → ``(frames, pruned_names)`` with
    frames ``(global_indices, blob, row_counts)``, or ``"batch"`` →
    per-object results aligned with ``names``.  ``shards`` is the
    plan's per-OSD grouping (``(osd_id, name_indices)`` pairs) so a
    scheduling runner need not re-derive placement.

    The ``partials`` / ``frames`` half of a combine/concat response may
    be LAZY — an iterator that yields per-OSD results as they land
    (the store's ``exec_*_iter`` planes, or a driver streaming shard
    results in worker-completion order).  The engine consumes it
    frame-by-frame, decoding/folding each result while slower OSDs are
    still scanning, and reads ``pruned_names`` (which may fill during
    iteration) only after exhaustion.
    """

    def __init__(self, vol):
        self.vol = vol

    # ------------------------------------------------------------ compile
    def compile(self, omap, scan: Scan) -> PhysicalPlan:
        return self._compile(omap, scan.pipeline(),
                             allow_approx=scan.approx,
                             prune=scan.prune_strategy)

    def compile_ops(self, omap, ops: Sequence[oc.ObjOp], *,
                    allow_approx: bool = False, prune: str = "auto",
                    baseline: bool = False) -> PhysicalPlan:
        """Compile a raw objclass pipeline (the ``GlobalVOL.query`` /
        ``Query`` shim entry point)."""
        return self._compile(omap, list(ops), allow_approx=allow_approx,
                             prune=prune, baseline=baseline)

    def compile_read(self, omap, rows: RowRange,
                     columns: Sequence[str] | None = None) -> PhysicalPlan:
        ops = [oc.op("row_slice", rows=(rows.start, rows.stop))]
        if columns is not None:
            ops.append(oc.op("project", cols=list(columns)))
        return self._compile(omap, ops, access="fetch")

    def _compile(self, omap, ops, *, allow_approx=False,
                 prune="auto", baseline=False, access=None) -> PhysicalPlan:
        if prune not in PRUNE_STRATEGIES:
            raise ValueError(f"bad prune strategy {prune!r}; "
                             f"known: {PRUNE_STRATEGIES}")
        ops = list(ops)
        rows = None
        for o in ops:
            if o.name == "row_slice":
                g0, g1 = o.params["rows"]
                # clamp BOTH ends: a range wholly past the dataset is
                # an empty scan (no candidates), not a compile error
                stop = max(0, min(int(g1), omap.dataset.n_rows))
                rows = RowRange(min(max(0, int(g0)), stop), stop)
        rewritten = False
        if ops and ops[-1].name == "median" and allow_approx \
                and not baseline:
            col = ops[-1].params["col"]
            lo, hi = self.vol._column_bounds(omap, col)
            ops[-1] = oc.op("quantile_sketch", col=col, lo=lo, hi=hi)
            rewritten = True
        predicates = oc.filter_predicates(ops)

        tail = oc.get_impl(ops[-1].name) if ops else None
        if baseline:
            exec_cls = EXEC_CLIENT_GATHER
        elif tail is not None and not tail.table_out:
            if tail.combine is None:
                exec_cls = EXEC_HOLISTIC_GATHER
            elif oc.pipeline_mergeable(ops):
                exec_cls = EXEC_OSD_COMBINE
            else:  # partial tail the OSD cannot fold
                exec_cls = EXEC_PARTIAL_GATHER
        else:
            exec_cls = EXEC_SERVER_CONCAT

        # request targeting: a row range restricts the scan to the
        # objects its CURRENT omap says intersect; the row_slice op
        # itself still rides to the OSDs, each of which re-resolves its
        # objects' sub-ranges from their own extent xattrs at execute
        # time (a re-partitioned object serves its current rows)
        if rows is not None:
            subs = omap.lookup(rows)
            names = [e.name for e, _ in subs]
        else:
            names = [e.name for e in omap]

        if baseline and rows is not None:
            # the client baseline gathers whole candidate objects in row
            # order, so the global slice becomes one plain select over
            # their concatenated rows
            base = subs[0][0].row_start if subs else 0
            ops = [oc.op("select", rows=(rows.start - base,
                                         rows.stop - base))
                   if o.name == "row_slice" else o for o in ops]

        if exec_cls == EXEC_HOLISTIC_GATHER:
            # ship the projected-gather form; the holistic tail itself
            # runs client-side over the gathered column
            col = ops[-1].params["col"]
            exec_ops = tuple(ops[:-1]) + (oc.op("project", cols=[col]),)
        else:
            exec_ops = tuple(ops)

        # partial-gather's positional response cannot carry OSD prune
        # info.  "auto" falls back to the client-side planner; an
        # EXPLICIT "pushdown" request must not be silently served with
        # the weaker (TOCTOU-prone) strategy — refuse instead.
        if exec_cls == EXEC_PARTIAL_GATHER and prune == "pushdown" \
                and predicates is not None:
            raise ValueError(
                "prune='pushdown' cannot serve a partial-gather plan "
                "(per-object positional responses carry no OSD prune "
                "info); use prune='auto'/'client'")

        pruned: tuple[str, ...] = ()
        if baseline or predicates is None or prune == "none":
            prune_s = "none"
        elif prune == "client" or exec_cls == EXEC_PARTIAL_GATHER:
            # client-side prune, restricted to THIS scan's candidate
            # objects (a row-ranged scan must not warm/revalidate zone
            # maps for the rest of the dataset)
            plan0 = self.vol.plan(omap, ops, names=names)
            kept = {n for n, _ in plan0.sub_requests}
            pruned = tuple(n for n in names if n not in kept)
            names = [n for n in names if n in kept]
            prune_s = "client"
        else:
            prune_s = "pushdown"

        if access is None and exec_cls in (EXEC_OSD_COMBINE,
                                           EXEC_PARTIAL_GATHER):
            access = "scan"

        by_osd: dict[str, list[int]] = {}
        if not baseline:
            cluster = self.vol.store.cluster
            for i, n in enumerate(names):
                by_osd.setdefault(cluster.primary(n), []).append(i)

        return PhysicalPlan(
            dataset=omap.dataset.name,
            exec_cls=exec_cls,
            prune=prune_s,
            names=tuple(names),
            ops=tuple(ops),
            exec_ops=exec_ops,
            pipelines=None,
            predicates=predicates if prune_s == "pushdown" else None,
            pruned=pruned,
            shards=tuple(sorted(
                (osd, tuple(idxs)) for osd, idxs in by_osd.items())),
            pushdown=exec_cls in (
                EXEC_OSD_COMBINE, EXEC_SERVER_CONCAT,
                EXEC_PARTIAL_GATHER, EXEC_TABLE_GATHER),
            approx_rewrite=rewritten,
            access=access,
            n_objects=omap.n_objects,
            omap_version=getattr(omap, "version", -1),
        )

    def compile_hyperslab(self, amap, hs: Hyperslab, *, where=None,
                          fill=0, prune: str = "auto") -> PhysicalPlan:
        """Compile an N-d hyperslab selection over a chunked array map
        (``partition.ArrayObjectMap``) into a ``hyperslab_slice``
        pipeline on the server-concat plane.

        The op carries only the plan-constant geometry (dataspace +
        normalized selection); each OSD resolves it against its
        objects' CURRENT ``chunks`` extent xattrs at execute time —
        the same late-binding contract as ``row_slice``, so a compiled
        plan keeps serving correct cells after the array is
        re-partitioned.  ``where`` is a predicate over the cell values
        (column name ``data``): it ships as the request's pushdown
        prune tree (normalized — ``expr.normalize``) and each OSD drops
        whole chunks against its per-chunk zone-map xattrs before any
        cell moves; dropped chunks surface as ``fill`` in the assembled
        result.  Zero client zone-map requests either way."""
        if prune not in PRUNE_STRATEGIES:
            raise ValueError(f"bad prune strategy {prune!r}; "
                             f"known: {PRUNE_STRATEGIES}")
        if prune == "client":
            raise ValueError(
                "hyperslab plans prune per chunk ON the OSDs (per-chunk "
                "zone maps are storage-side state); use prune="
                "'auto'/'pushdown'/'none'")
        space = amap.space
        pred = ex.normalize(ex.ensure_pred(where)) \
            if prune != "none" else None
        targets = amap.lookup(hs)
        names = [e.name for e, _ in targets]
        by_osd: dict[str, list[int]] = {}
        cluster = self.vol.store.cluster
        for i, n in enumerate(names):
            by_osd.setdefault(cluster.primary(n), []).append(i)
        ops = (oc.op("hyperslab_slice", space=space.to_json(),
                     sel=hs.to_json()),)
        return PhysicalPlan(
            dataset=space.name,
            exec_cls=EXEC_SERVER_CONCAT,
            prune="pushdown" if pred is not None else "none",
            names=tuple(names),
            ops=ops,
            exec_ops=ops,
            predicates=pred,
            shards=tuple(sorted(
                (osd, tuple(idxs)) for osd, idxs in by_osd.items())),
            pushdown=True,
            assemble="array",
            access="fetch",
            n_objects=amap.n_objects,
            omap_version=getattr(amap, "version", -1),
            array_meta={"space": space.to_json(), "sel": hs.to_json(),
                        "squeeze": tuple(hs.squeeze), "fill": fill},
        )

    def compile_gather(self, names: Sequence[str],
                       pipelines: Sequence[Sequence[oc.ObjOp]],
                       packed: bool = False) -> PhysicalPlan:
        """Per-object sub-request gather (the data loader's plan):
        table-out pipelines ride the server-concat plane (one framed
        response per OSD); packed pipelines (``select_packed`` emits raw
        word partials, not tables) gather per object."""
        return PhysicalPlan(
            dataset="", prune="none",
            exec_cls=EXEC_TABLE_GATHER if packed else EXEC_SERVER_CONCAT,
            names=tuple(names), ops=(), exec_ops=(),
            pipelines=tuple(tuple(p) for p in pipelines),
            assemble="parts", pushdown=True, n_objects=len(names))

    # ------------------------------------------------------------ execute
    def _refresh(self, plan: PhysicalPlan, omap) -> PhysicalPlan:
        """Row-slice targeting refresh (ROADMAP standing item): a
        compiled plan's ``names`` were derived from the ObjectMap it
        compiled against.  The pushed-down ``row_slice`` already keeps
        re-partitioned objects serving their CURRENT rows, but an
        object whose extent GREW into the range after a re-partition
        was never targeted at compile time and would silently be
        skipped.  So before executing a row-sliced plan, compare its
        stamped map version against the current one — the caller's
        ``omap`` hint when it has one (free), else ONE xattr probe of
        ``<dataset>/.objmap`` — and recompile the plan from the fresh
        map when the version moved."""
        if plan.omap_version < 0 or not plan.dataset \
                or plan.exec_cls == EXEC_CLIENT_GATHER \
                or not any(o.name in ("row_slice", "hyperslab_slice")
                           for o in plan.ops):
            return plan
        hint_v = getattr(omap, "version", -1) if omap is not None else -1
        if hint_v == plan.omap_version:
            return plan  # executing against the map it compiled from
        if hint_v >= 0:
            current_v, fresh = hint_v, omap
        else:
            key = objmap_key(plan.dataset)
            current_v = int(self.vol.store.xattr(key)
                            .get("version", -1))
            fresh = None
        if current_v == plan.omap_version:
            return plan
        if fresh is None:
            fresh = self.vol.open(plan.dataset)
        if plan.array_meta is not None:
            # hyperslab plans re-target from the fresh chunk map; the
            # predicate (already normalized at first compile) and fill
            # ride along unchanged
            return self.compile_hyperslab(
                fresh, Hyperslab.from_json(plan.array_meta["sel"]),
                where=plan.predicates,
                fill=plan.array_meta.get("fill", 0),
                prune=plan.prune if plan.predicates is not None
                else "none")
        return self._compile(fresh, list(plan.ops),
                             prune=plan.prune, access=plan.access)

    def execute(self, plan: PhysicalPlan, runner=None,
                before: dict | None = None, omap=None) -> tuple[Any, dict]:
        """Run one compiled plan; returns ``(result, stats)`` with the
        unified stats emission every caller shares.  ``before`` lets the
        caller open the fabric-accounting window ahead of ``compile`` so
        the reported cost includes compile-time traffic (the client
        strategy's zone-map warm/revalidation, the approx rewrite's
        column-bounds fetch) — every query front end passes it.
        ``omap`` is a currency hint for the row-slice targeting refresh:
        callers that just compiled against a map they hold pass it so a
        matching version skips the refresh probe entirely."""
        store = self.vol.store
        run = runner or self._direct
        if before is None:
            before = store.fabric.snapshot()
        plan = self._refresh(plan, omap)
        names = list(plan.names)
        ops = list(plan.ops)
        pipes = [list(p) for p in plan.pipelines] \
            if plan.pipelines is not None else list(plan.exec_ops)
        preds = plan.predicates
        osd_pruned: list[str] = []
        result_rows: int | None = None

        shards = plan.shards

        if plan.exec_cls == EXEC_OSD_COMBINE:
            partials_src, pruned_src = run("combine", names, pipes,
                                           preds, shards)
            # consume lazily: each OSD's partial folds in as it lands
            partials = list(partials_src)
            osd_pruned = list(pruned_src)
            result = oc.combine_partials(ops, partials)
            result_rows = 1
        elif plan.exec_cls == EXEC_PARTIAL_GATHER:
            raw = run("batch", names, pipes, None, shards)
            result = oc.combine_partials(ops, raw)
            result_rows = 1
        elif plan.exec_cls == EXEC_HOLISTIC_GATHER:
            col = ops[-1].params["col"]
            frames_src, pruned_src = run("concat", names, pipes, preds,
                                         shards)
            # frame-by-frame: decode each OSD's block on arrival, while
            # slower OSDs are still scanning
            cols = [{col: fmt.decode_block(blob)[col].ravel()}
                    for _, blob, _ in frames_src]
            osd_pruned = list(pruned_src)
            result = oc.median_exact(cols, col)
            result_rows = 1
        elif plan.exec_cls == EXEC_SERVER_CONCAT:
            frames_src, pruned_src = run("concat", names, pipes, preds,
                                         shards)
            parts: list = [None] * len(names)
            for frame in frames_src:  # decode overlaps slower OSDs
                _place_frame(parts, frame)
            osd_pruned = list(pruned_src)
            if plan.assemble == "parts":
                result = parts
            elif plan.assemble == "array":
                result = _assemble_array(plan, parts)
                result_rows = int(result.size)
            else:
                result = concat_tables(
                    [p for p in parts if p is not None])
                result_rows = oc.table_n_rows(result)
        elif plan.exec_cls == EXEC_TABLE_GATHER:
            result = run("batch", names, pipes, None, shards)
        elif plan.exec_cls == EXEC_CLIENT_GATHER:
            result = self._client_eval(names, ops)
            result_rows = _result_rows(ops, result)
        else:
            raise ValueError(f"unknown execution class {plan.exec_cls!r}")

        if plan.access is not None:
            scanned = len(names) - len(osd_pruned)
            for _ in range(scanned):
                self.vol.local.note_access(plan.access)

        after = store.fabric.snapshot()
        stats = {k: after[k] - before[k] for k in after}
        stats.update(
            objects_touched=len(names) - len(osd_pruned),
            objects_pruned=len(plan.pruned) + len(osd_pruned),
            pushdown=plan.pushdown,
            approx_rewrite=plan.approx_rewrite,
            exec_class=plan.exec_cls,
            prune=plan.prune,
            result_rows=result_rows,
        )
        return result, stats

    def fetch_objects(self, names: Sequence[str],
                      pipelines: Sequence[Sequence[oc.ObjOp]],
                      packed: bool = False) -> list:
        """Execute a per-object gather plan and return per-object
        results aligned with ``names`` (decoded tables, or raw packed
        partials) — the loader's entry point into the engine."""
        plan = self.compile_gather(names, pipelines, packed=packed)
        parts, _ = self.execute(plan)
        return parts

    def fetch_objects_stream(self, names: Sequence[str],
                             pipelines: Sequence[Sequence[oc.ObjOp]],
                             packed: bool = False):
        """Streaming twin of ``fetch_objects``: yields ``(index,
        result)`` pairs the moment their per-OSD frame lands and
        decodes, in arrival order — the loader's windowed consume.  A
        consumer holding results for early indices finishes before the
        slowest OSD responds; results are bit-identical to the buffered
        gather."""
        store = self.vol.store
        plan = self.compile_gather(names, pipelines, packed=packed)
        pipes = [list(p) for p in plan.pipelines]
        if plan.exec_cls == EXEC_TABLE_GATHER:
            yield from store.exec_batch_iter(list(plan.names), pipes)
            return
        for frame in store.exec_concat_iter(list(plan.names), pipes):
            yield from _iter_frame(frame)

    # ------------------------------------------------------------ internals
    def _direct(self, mode, names, pipelines, predicates, shards=()):
        del shards  # the store regroups by primary OSD itself
        store = self.vol.store
        if mode == "combine":
            pruned: list[str] = []
            return store.exec_combine_iter(
                names, pipelines, prune=predicates,
                pruned_out=pruned), pruned
        if mode == "concat":
            pruned = []
            return store.exec_concat_iter(
                names, pipelines, prune=predicates,
                pruned_out=pruned), pruned
        return store.exec_batch(names, pipelines)

    def _client_eval(self, names, ops):
        """The no-pushdown baseline: whole objects to the client, the
        pipeline evaluated locally (byte accounting shows what pushdown
        saves)."""
        store = self.vol.store
        result: Any = concat_tables(
            [fmt.decode_block(store.get(n)) for n in names])
        for o in ops:
            impl = oc.get_impl(o.name)
            if o.name == "median":
                result = float(np.median(
                    np.asarray(result[o.params["col"]]).ravel()))
            elif not impl.table_out:
                result = impl.combine([impl.local(result, **o.params)],
                                      **o.params)
            else:
                result = impl.local(result, **o.params)
        return result


def _split_frames(n: int, frames) -> list:
    """Re-slice per-OSD concatenated frames into per-object tables,
    placed at their input positions (global row order restored)."""
    parts: list[dict | None] = [None] * n
    for frame in frames:
        _place_frame(parts, frame)
    return parts


def _iter_frame(frame: tuple):
    """Decode one per-OSD concatenated frame and yield its per-object
    ``(input_index, table)`` slices — the ONE place the frame layout
    (row_counts offsets into the concatenated block) is interpreted."""
    idxs, blob, counts = frame
    tab = fmt.decode_block(blob)
    off = 0
    for i, c in zip(idxs, counts):
        yield i, {k: v[off:off + c] for k, v in tab.items()}
        off += c


def _place_frame(parts: list, frame: tuple) -> None:
    """Slot one frame's per-object tables at their input positions
    (global row order restored) — the incremental half of the
    streaming consume."""
    for i, part in _iter_frame(frame):
        parts[i] = part


def _assemble_array(plan: PhysicalPlan, parts: list) -> np.ndarray:
    """Rebuild the dense N-d result of a hyperslab plan from the
    per-object ``{"cells", "chunk"}`` tables the OSDs served.

    Each object's cells arrive as C-order runs tagged with their global
    chunk id; the client re-derives every run's placement from
    (selection ∩ chunk slab) — the same arithmetic the OSD used to cut
    the run — so no per-cell coordinates ever cross the wire.  Chunks
    that are absent (pruned OSD-side by the predicate, or skipped
    whole-object) stay at the plan's fill value."""
    meta = plan.array_meta
    sp = Dataspace.from_json(meta["space"])
    hs = Hyperslab.from_json(meta["sel"])
    out = np.full(hs.out_shape(), meta.get("fill", 0),
                  dtype=np.dtype(sp.dtype))
    for part in parts:
        if part is None:
            continue
        cells = np.asarray(part["cells"])
        cids = np.asarray(part["chunk"])
        if cells.size == 0:
            continue
        # cells of one chunk are contiguous: split on chunk-id change
        run_starts = np.flatnonzero(np.diff(cids)) + 1
        bounds = [0, *run_starts.tolist(), len(cids)]
        for s, e in zip(bounds[:-1], bounds[1:]):
            hit = hs.intersect_slab(sp.chunk_slab(int(cids[s])))
            if hit is None:
                raise ValueError(
                    f"{plan.dataset}: served chunk {int(cids[s])} is "
                    "disjoint from the selection")
            _locs, offs, counts = hit
            out[tuple(slice(o, o + n)
                      for o, n in zip(offs, counts))] = \
                cells[s:e].reshape(counts)
    if meta.get("squeeze"):
        out = np.squeeze(out, axis=tuple(meta["squeeze"]))
    return out


def _result_rows(ops, result) -> int:
    if ops and not oc.get_impl(ops[-1].name).table_out:
        return 1  # scalar / one aggregate row
    return oc.table_n_rows(result) if isinstance(result, dict) else 1
