"""Physical block format + codecs ("physical design management", paper §5).

Objects hold *blocks*: a self-describing serialization of a column table
(standing in for the paper's Flatbuffers/Arrow wrappers).  A block has:

  header (json): schema, n_rows, layout ("row"|"col"), per-column codec,
                 per-column zone map (min/max) for object pruning — the
                 paper's RocksDB-index analogue, kept *inside* the object
                 plus mirrored into OSD xattrs.
  body: per-column encoded buffers (col layout) or one interleaved buffer
        (row layout).

Codecs:
  none          — raw little-endian buffer
  zlib          — DEFLATE (cheap stand-in for generic compression)
  bitpack<b>    — planar bitpack for unsigned ints < 2**b: each group of
                  32 values becomes b uint32 words, word k holding bit k
                  of all 32 values.  TPU-friendly: decode is shift/mask
                  vector ops only (see kernels/codec) so the *storage
                  side* decompression can run on the device that owns the
                  shard — the paper's `compress` offload adapted to TPU.

Layout transformation (row<->col) is lossless and is the mechanism behind
``LocalVOL``'s physical-design optimization.
"""

from __future__ import annotations

import json
import struct
import warnings
import zlib
from typing import Mapping

import numpy as np

from repro.core.logical import Column

_MAGIC = b"SKYB"
_VERSION = 2

# crc32c when the (optional) C extension is around, zlib's crc32
# otherwise — both run at C speed over the encoded blob; the store only
# needs A content digest that is cheap enough to verify on every read,
# not a specific polynomial
try:  # pragma: no cover - environment-dependent
    from crc32c import crc32c as _crc
except Exception:  # pragma: no cover
    _crc = zlib.crc32


def content_digest(blob: bytes) -> int:
    """Content digest of an encoded object blob (crc32c when available,
    crc32 otherwise).  Stamped into every object's xattrs at write time
    (``ObjectStore.put`` / ``put_batch`` / each replication hop) so any
    copy is independently verifiable: reads, ``scrub()`` and
    digest-verified ``recover()`` all check stored bytes against this
    value before serving or propagating them."""
    return _crc(bytes(blob)) & 0xFFFFFFFF


# --------------------------------------------------------------------------
# planar bitpack codec (numpy reference; kernels/codec has the Pallas twin)
# --------------------------------------------------------------------------


def bitpack_width(max_value: int) -> int:
    """Bits needed for values in [0, max_value]."""
    return max(1, int(max_value).bit_length())


def auto_codecs(table: Mapping[str, np.ndarray], *,
                bitpack_ints: bool = True) -> dict[str, str]:
    """Default per-column codec choice for a col-layout block: bitpack
    non-negative integer columns whose width pays off (<= 24 bits; wider
    loses to raw int32).  Shared by ``LocalVOL.encode`` and the OSD-side
    ``compact_merge`` op so a compacted object round-trips through the
    same codec policy as a freshly written one."""
    out: dict[str, str] = {}
    if not bitpack_ints:
        return out
    for k, a in table.items():
        a = np.asarray(a)
        if (np.issubdtype(a.dtype, np.integer)
                and a.size and int(a.min()) >= 0):
            bits = bitpack_width(int(a.max()))
            if bits <= 24:
                out[k] = f"bitpack{bits}"
    return out


# (swap distance, mask) pairs for the 5 butterfly stages of a 32x32
# bit-matrix transpose (Hacker's Delight §7-3): stage j exchanges the
# masked j-bit sub-blocks between rows k and k+j.
_BUTTERFLY = ((16, 0x0000FFFF), (8, 0x00FF00FF), (4, 0x0F0F0F0F),
              (2, 0x33333333), (1, 0x55555555))


def _bit_transpose32(a: np.ndarray) -> np.ndarray:
    """Vectorized 32x32 bit-matrix transpose over the leading axis.

    ``a`` is (G, 32) uint32; returns (G, 32) uint32 with
    ``out[:, i] bit j == a[:, j] bit i``.  Five masked shift-swap
    stages over the whole array — no per-bit Python loop, and the
    working set is just the (G, 32) matrix itself.
    """
    if not a.size:
        return a.copy()
    at = a.T.copy()                              # (32, G): G contiguous;
    # always a private buffer — the butterfly XORs in place and must
    # never scribble on the caller's array (a.T can alias it when G==1)
    for j, m in _BUTTERFLY:
        m = np.uint32(m)
        # rows with (k & j) == 0 are the first j of every 2j-row block,
        # so each stage is a pure reshape — contiguous views, no gathers
        g = at.reshape(-1, 2, j, at.shape[-1])   # (pairs, lo|hi, j, G)
        lo, hi = g[:, 0], g[:, 1]
        # swap the high-bit block of the lo rows with the low-bit block
        # of the hi rows: [[A,B],[C,D]] -> [[A,C],[B,D]] at every scale
        t = ((lo >> np.uint32(j)) ^ hi) & m
        hi ^= t
        lo ^= t << np.uint32(j)
    return at.T


def bitpack_encode(values: np.ndarray, bits: int) -> np.ndarray:
    """(n,) uint32-able -> (ceil(n/32), bits) uint32, planar layout.

    Each 32-value group is one 32x32 bit matrix; the planar encoding is
    exactly its transpose, done via :func:`_bit_transpose32` (word
    planes >= ``bits`` are all-zero and dropped).  Bit-exact with the
    historical per-bit-loop implementation, minus the Python loop.
    """
    v = np.ascontiguousarray(values, dtype=np.uint32).ravel()
    if v.size and int(v.max()) >= (1 << bits):
        raise ValueError(f"value {int(v.max())} needs more than {bits} bits")
    n = v.size
    n_groups = -(-n // 32) if n else 0
    padded = np.zeros((n_groups * 32,), np.uint32)
    padded[:n] = v
    g = padded.reshape(n_groups, 32)                       # (G, 32)
    return np.ascontiguousarray(_bit_transpose32(g)[:, :bits])


def bitpack_decode(words: np.ndarray, bits: int, n: int) -> np.ndarray:
    """(G, bits) uint32 -> (n,) uint32.

    Inverse planar transform = the same 32x32 bit transpose with the
    missing (all-zero) word planes restored.  No per-bit Python loop.
    """
    w = np.ascontiguousarray(words, dtype=np.uint32).reshape(-1, bits)
    full = np.zeros((w.shape[0], 32), np.uint32)
    full[:, :bits] = w
    return _bit_transpose32(full).ravel()[:n]


# --------------------------------------------------------------------------
# bitpack decode backend selection (numpy butterfly vs Pallas kernel)
# --------------------------------------------------------------------------

# "auto": the Pallas kernel (kernels/bitunpack) decodes bitpack columns
# whenever a jax *device* backend (tpu/gpu) is selected — the storage-
# side decode runs on the accelerator that owns the shard; on CPU (or
# with no jax at all) the numpy butterfly codec is used.  "device" and
# "numpy" force one side (tests force "device" to exercise the kernel in
# interpret mode on CPU and assert bit-exactness).
_BITUNPACK_MODE = "auto"
_bitunpack_impl = None  # resolved lazily; None = not resolved yet


def set_bitunpack_backend(mode: str) -> None:
    """Select the bitpack-column decode backend: "auto" | "numpy" |
    "device" (see module comment).  Takes effect on the next decode."""
    global _BITUNPACK_MODE, _bitunpack_impl
    if mode not in ("auto", "numpy", "device"):
        raise ValueError(f"unknown bitunpack backend {mode!r}")
    _BITUNPACK_MODE = mode
    _bitunpack_impl = None


def _resolve_bitunpack():
    global _bitunpack_impl
    if _bitunpack_impl is not None:
        return _bitunpack_impl
    want_device = _BITUNPACK_MODE == "device"
    if _BITUNPACK_MODE == "auto":
        try:
            import jax
            want_device = jax.default_backend() in ("tpu", "gpu")
        except Exception:
            want_device = False
    impl = bitpack_decode
    if want_device:
        try:
            from repro.kernels.bitunpack import bitunpack_words
            impl = bitunpack_words
        except Exception:
            if _BITUNPACK_MODE == "device":
                raise  # forced backend: a missing kernel must be loud
            impl = bitpack_decode  # auto: no jax/pallas -> numpy fallback
    _bitunpack_impl = impl
    return impl


def _bitunpack_dispatch(words, bits: int, n: int) -> np.ndarray:
    """Decode through the selected backend.  In "auto" mode a device
    kernel that fails at call time (lowering/runtime error on this
    backend) pins the numpy fallback for the rest of the process, with
    a warning — a scan must never die on a codec *routing* choice.  In
    forced "device" mode the error propagates: tests force that mode to
    assert the kernel actually ran, so a silent fallback would let a
    broken kernel pass green against the numpy path."""
    global _bitunpack_impl
    impl = _resolve_bitunpack()
    if impl is not bitpack_decode:
        try:
            return impl(words, bits, n)
        except Exception as e:
            if _BITUNPACK_MODE == "device":
                raise
            warnings.warn(f"device bitunpack failed ({e!r}); "
                          "pinning numpy fallback", RuntimeWarning)
            _bitunpack_impl = bitpack_decode
    return bitpack_decode(words, bits, n)


# --------------------------------------------------------------------------
# per-column encode/decode
# --------------------------------------------------------------------------


def _encode_column(a: np.ndarray, codec: str) -> bytes:
    raw = np.ascontiguousarray(a)
    if codec == "none":
        return raw.tobytes()
    if codec == "zlib":
        return zlib.compress(raw.tobytes(), level=1)
    if codec.startswith("bitpack"):
        bits = int(codec[len("bitpack"):])
        if not np.issubdtype(raw.dtype, np.integer):
            raise TypeError(f"bitpack needs ints, got {raw.dtype}")
        return bitpack_encode(raw.ravel(), bits).tobytes()
    raise ValueError(f"unknown codec {codec!r}")


def _decode_column(buf, codec: str, dtype: str,
                   shape: tuple[int, ...]) -> np.ndarray:
    """Decode one column buffer (bytes or memoryview).

    Codec ``none`` is zero-copy: the returned (read-only) array aliases
    the block's buffer instead of materializing a private copy — the
    scan hot path never duplicates raw column bytes.
    """
    n = int(np.prod(shape, dtype=np.int64)) if shape else 0
    if codec == "none":
        return np.frombuffer(buf, dtype=dtype).reshape(shape)
    if codec == "zlib":
        # decompress already yields a fresh buffer; alias it, no copy
        return np.frombuffer(zlib.decompress(buf), dtype=dtype).reshape(
            shape)
    if codec.startswith("bitpack"):
        bits = int(codec[len("bitpack"):])
        words = np.frombuffer(buf, dtype=np.uint32)
        return _bitunpack_dispatch(words, bits, n).astype(dtype).reshape(
            shape)
    raise ValueError(f"unknown codec {codec!r}")


# --------------------------------------------------------------------------
# block encode/decode
# --------------------------------------------------------------------------


def zone_map(table: Mapping[str, np.ndarray]) -> dict:
    """Per-column min/max (object-pruning index).  Numeric columns map
    to float bounds; string columns to lexicographic bounds, which make
    equality/range/prefix predicates (``expr.StrPrefix``) prunable the
    same interval-arithmetic way."""
    zm = {}
    for k, a in table.items():
        a = np.asarray(a)
        if not a.size:
            continue
        if np.issubdtype(a.dtype, np.number):
            zm[k] = [float(a.min()), float(a.max())]
        elif a.dtype.kind in ("U", "S"):
            # str dtypes have no min/max ufunc loop; sort is C-speed
            srt = np.sort(a.ravel())
            lo, hi = srt[0], srt[-1]
            if a.dtype.kind == "S":
                lo, hi = (lo.decode("utf-8", "replace"),
                          hi.decode("utf-8", "replace"))
            zm[k] = [str(lo), str(hi)]
    return zm


def encode_block(
    table: Mapping[str, np.ndarray],
    *,
    layout: str = "col",
    codecs: Mapping[str, str] | None = None,
) -> bytes:
    """Serialize a column table into a block."""
    if layout not in ("row", "col"):
        raise ValueError(layout)
    codecs = dict(codecs or {})
    cols = []
    n_rows = None
    for name, a in table.items():
        a = np.asarray(a)
        if n_rows is None:
            n_rows = a.shape[0] if a.ndim else 0
        elif a.shape[0] != n_rows:
            raise ValueError(f"ragged block: {name}")
        cols.append({"name": name, "dtype": str(a.dtype),
                     "shape": list(a.shape),
                     "codec": codecs.get(name, "none")})

    bufs: list[bytes] = []
    if layout == "col":
        for c in cols:
            bufs.append(_encode_column(np.asarray(table[c["name"]]),
                                       c["codec"]))
    else:  # row layout: interleave via a structured scratch array
        if any(c["codec"] != "none" for c in cols):
            raise ValueError("row layout supports codec 'none' only")
        fields = [(c["name"], c["dtype"],
                   tuple(c["shape"][1:]) or ()) for c in cols]
        rec = np.zeros(n_rows or 0, dtype=np.dtype(fields))
        for c in cols:
            rec[c["name"]] = table[c["name"]]
        bufs.append(rec.tobytes())

    header = {"v": _VERSION, "layout": layout, "n_rows": int(n_rows or 0),
              "columns": cols, "zone_map": zone_map(table),
              "lens": [len(b) for b in bufs]}
    hjson = json.dumps(header).encode()
    return b"".join([_MAGIC, struct.pack("<I", len(hjson)), hjson, *bufs])


def block_header(blob: bytes) -> dict:
    if blob[:4] != _MAGIC:
        raise ValueError("not a block")
    (hlen,) = struct.unpack("<I", blob[4:8])
    return json.loads(blob[8:8 + hlen])


def decode_block(blob: bytes,
                 columns: list[str] | None = None) -> dict[str, np.ndarray]:
    """Deserialize (optionally projecting a column subset without touching
    other columns' bytes — col layout only reads what it needs)."""
    header = block_header(blob)
    (hlen,) = struct.unpack("<I", blob[4:8])
    off = 8 + hlen
    out: dict[str, np.ndarray] = {}
    if header["layout"] == "col":
        view = memoryview(blob)  # zero-copy column slicing
        for c, blen in zip(header["columns"], header["lens"]):
            if columns is None or c["name"] in columns:
                out[c["name"]] = _decode_column(
                    view[off:off + blen], c["codec"], c["dtype"],
                    tuple(c["shape"]))
            off += blen
    else:
        fields = [(c["name"], c["dtype"],
                   tuple(c["shape"][1:]) or ()) for c in header["columns"]]
        rec = np.frombuffer(blob[off:off + header["lens"][0]],
                            dtype=np.dtype(fields))
        for c in header["columns"]:
            if columns is None or c["name"] in columns:
                out[c["name"]] = np.ascontiguousarray(rec[c["name"]])
    if columns is not None:
        missing = set(columns) - set(out)
        if missing:
            raise KeyError(f"columns not in block: {sorted(missing)}")
    return out


def transform_layout(blob: bytes, to: str,
                     codecs: Mapping[str, str] | None = None) -> bytes:
    """Row<->col physical transformation (paper §5 'physical design')."""
    table = decode_block(blob)
    return encode_block(table, layout=to, codecs=codecs)


def schema_columns(blob: bytes) -> list[Column]:
    return [Column(c["name"], c["dtype"], tuple(c["shape"][1:]))
            for c in block_header(blob)["columns"]]
