"""Physical block format + codecs ("physical design management", paper §5).

Objects hold *blocks*: a self-describing serialization of a column table
(standing in for the paper's Flatbuffers/Arrow wrappers).  A block has:

  header (json): schema, n_rows, layout ("row"|"col"), per-column codec,
                 per-column zone map (min/max) for object pruning — the
                 paper's RocksDB-index analogue, kept *inside* the object
                 plus mirrored into OSD xattrs.
  body: per-column encoded buffers (col layout) or one interleaved buffer
        (row layout).

Codecs:
  none          — raw little-endian buffer
  zlib          — DEFLATE (cheap stand-in for generic compression)
  bitpack<b>    — planar bitpack for unsigned ints < 2**b: each group of
                  32 values becomes b uint32 words, word k holding bit k
                  of all 32 values.  TPU-friendly: decode is shift/mask
                  vector ops only (see kernels/codec) so the *storage
                  side* decompression can run on the device that owns the
                  shard — the paper's `compress` offload adapted to TPU.

Layout transformation (row<->col) is lossless and is the mechanism behind
``LocalVOL``'s physical-design optimization.
"""

from __future__ import annotations

import json
import struct
import zlib
from typing import Mapping

import numpy as np

from repro.core.logical import Column

_MAGIC = b"SKYB"
_VERSION = 2


# --------------------------------------------------------------------------
# planar bitpack codec (numpy reference; kernels/codec has the Pallas twin)
# --------------------------------------------------------------------------


def bitpack_width(max_value: int) -> int:
    """Bits needed for values in [0, max_value]."""
    return max(1, int(max_value).bit_length())


def bitpack_encode(values: np.ndarray, bits: int) -> np.ndarray:
    """(n,) uint32-able -> (ceil(n/32), bits) uint32, planar layout."""
    v = np.ascontiguousarray(values, dtype=np.uint32).ravel()
    if v.size and int(v.max()) >= (1 << bits):
        raise ValueError(f"value {int(v.max())} needs more than {bits} bits")
    n = v.size
    n_groups = -(-n // 32) if n else 0
    padded = np.zeros((n_groups * 32,), np.uint32)
    padded[:n] = v
    g = padded.reshape(n_groups, 32)                       # (G, 32)
    lane = np.arange(32, dtype=np.uint32)
    out = np.zeros((n_groups, bits), np.uint32)
    for k in range(bits):
        out[:, k] = (((g >> np.uint32(k)) & np.uint32(1)) << lane).sum(
            axis=1, dtype=np.uint32)
    return out


def bitpack_decode(words: np.ndarray, bits: int, n: int) -> np.ndarray:
    """(G, bits) uint32 -> (n,) uint32."""
    w = np.ascontiguousarray(words, dtype=np.uint32).reshape(-1, bits)
    lane = np.arange(32, dtype=np.uint32)
    vals = np.zeros((w.shape[0], 32), np.uint32)
    for k in range(bits):
        vals |= (((w[:, k:k + 1] >> lane) & np.uint32(1))
                 << np.uint32(k)).astype(np.uint32)
    return vals.ravel()[:n]


# --------------------------------------------------------------------------
# per-column encode/decode
# --------------------------------------------------------------------------


def _encode_column(a: np.ndarray, codec: str) -> bytes:
    raw = np.ascontiguousarray(a)
    if codec == "none":
        return raw.tobytes()
    if codec == "zlib":
        return zlib.compress(raw.tobytes(), level=1)
    if codec.startswith("bitpack"):
        bits = int(codec[len("bitpack"):])
        if not np.issubdtype(raw.dtype, np.integer):
            raise TypeError(f"bitpack needs ints, got {raw.dtype}")
        return bitpack_encode(raw.ravel(), bits).tobytes()
    raise ValueError(f"unknown codec {codec!r}")


def _decode_column(buf: bytes, codec: str, dtype: str,
                   shape: tuple[int, ...]) -> np.ndarray:
    n = int(np.prod(shape, dtype=np.int64)) if shape else 0
    if codec == "none":
        return np.frombuffer(buf, dtype=dtype).reshape(shape).copy()
    if codec == "zlib":
        return np.frombuffer(zlib.decompress(buf), dtype=dtype).reshape(
            shape).copy()
    if codec.startswith("bitpack"):
        bits = int(codec[len("bitpack"):])
        words = np.frombuffer(buf, dtype=np.uint32)
        return bitpack_decode(words, bits, n).astype(dtype).reshape(shape)
    raise ValueError(f"unknown codec {codec!r}")


# --------------------------------------------------------------------------
# block encode/decode
# --------------------------------------------------------------------------


def zone_map(table: Mapping[str, np.ndarray]) -> dict:
    """Per-column min/max for numeric columns (object-pruning index)."""
    zm = {}
    for k, a in table.items():
        a = np.asarray(a)
        if a.size and np.issubdtype(a.dtype, np.number):
            zm[k] = [float(a.min()), float(a.max())]
    return zm


def encode_block(
    table: Mapping[str, np.ndarray],
    *,
    layout: str = "col",
    codecs: Mapping[str, str] | None = None,
) -> bytes:
    """Serialize a column table into a block."""
    if layout not in ("row", "col"):
        raise ValueError(layout)
    codecs = dict(codecs or {})
    cols = []
    n_rows = None
    for name, a in table.items():
        a = np.asarray(a)
        if n_rows is None:
            n_rows = a.shape[0] if a.ndim else 0
        elif a.shape[0] != n_rows:
            raise ValueError(f"ragged block: {name}")
        cols.append({"name": name, "dtype": str(a.dtype),
                     "shape": list(a.shape),
                     "codec": codecs.get(name, "none")})

    bufs: list[bytes] = []
    if layout == "col":
        for c in cols:
            bufs.append(_encode_column(np.asarray(table[c["name"]]),
                                       c["codec"]))
    else:  # row layout: interleave via a structured scratch array
        if any(c["codec"] != "none" for c in cols):
            raise ValueError("row layout supports codec 'none' only")
        fields = [(c["name"], c["dtype"],
                   tuple(c["shape"][1:]) or ()) for c in cols]
        rec = np.zeros(n_rows or 0, dtype=np.dtype(fields))
        for c in cols:
            rec[c["name"]] = table[c["name"]]
        bufs.append(rec.tobytes())

    header = {"v": _VERSION, "layout": layout, "n_rows": int(n_rows or 0),
              "columns": cols, "zone_map": zone_map(table),
              "lens": [len(b) for b in bufs]}
    hjson = json.dumps(header).encode()
    return b"".join([_MAGIC, struct.pack("<I", len(hjson)), hjson, *bufs])


def block_header(blob: bytes) -> dict:
    if blob[:4] != _MAGIC:
        raise ValueError("not a block")
    (hlen,) = struct.unpack("<I", blob[4:8])
    return json.loads(blob[8:8 + hlen])


def decode_block(blob: bytes,
                 columns: list[str] | None = None) -> dict[str, np.ndarray]:
    """Deserialize (optionally projecting a column subset without touching
    other columns' bytes — col layout only reads what it needs)."""
    header = block_header(blob)
    (hlen,) = struct.unpack("<I", blob[4:8])
    off = 8 + hlen
    out: dict[str, np.ndarray] = {}
    if header["layout"] == "col":
        for c, blen in zip(header["columns"], header["lens"]):
            if columns is None or c["name"] in columns:
                out[c["name"]] = _decode_column(
                    blob[off:off + blen], c["codec"], c["dtype"],
                    tuple(c["shape"]))
            off += blen
    else:
        fields = [(c["name"], c["dtype"],
                   tuple(c["shape"][1:]) or ()) for c in header["columns"]]
        rec = np.frombuffer(blob[off:off + header["lens"][0]],
                            dtype=np.dtype(fields))
        for c in header["columns"]:
            if columns is None or c["name"] in columns:
                out[c["name"]] = np.ascontiguousarray(rec[c["name"]])
    if columns is not None:
        missing = set(columns) - set(out)
        if missing:
            raise KeyError(f"columns not in block: {sorted(missing)}")
    return out


def transform_layout(blob: bytes, to: str,
                     codecs: Mapping[str, str] | None = None) -> bytes:
    """Row<->col physical transformation (paper §5 'physical design')."""
    table = decode_block(blob)
    return encode_block(table, layout=to, codecs=codecs)


def schema_columns(blob: bytes) -> list[Column]:
    return [Column(c["name"], c["dtype"], tuple(c["shape"][1:]))
            for c in block_header(blob)["columns"]]
