"""The paper's contribution: dataset -> object-storage mapping with
storage-side computation (SkyhookDM / HDF5-VOL, in JAX-native form).

Layering (bottom up):
  placement  — CRUSH-like PG/HRW placement from a compact cluster map
  store      — RADOS-like replicated object store + objclass execution,
               digest scrub/heal and the deadline/backoff request layer
  faults     — fault-injection harness (bit rot, torn writes, slow or
               transiently failing OSDs) for the self-healing plane
  format     — physical block format, codecs, layout transformation
  logical    — access-library-facing datasets (rows, columns, units)
  partition  — logical units -> objects (grouping/splitting/sizing)
  expr       — predicate-expression algebra: one tree for evaluation,
               zone-map interval pruning, and the wire form
  objclass   — storage-side op registry (select/project/filter/agg/...)
  scan       — the ONE query surface: Scan builder -> PhysicalPlan ->
               ScanEngine (prune pushdown, per-OSD combine/concat)
  cache      — byte-bounded LRU result cache (one per OSD, version-keyed)
  maintenance — background daemons: continuous scrub walker, small-
               object compaction, live rebalance, versioned GC
  session    — ScanSession: many-client admission front-end
               (single-flight dedup + projection coalescing)
  vol        — GlobalVOL (client plugin) / LocalVOL (storage plugin)
  skyhook    — driver/worker scheduling over the scan engine
  pushdown_jax — the TPU data plane: compute-at-shard via shard_map
"""

from repro.core.expr import (  # noqa: F401
    And, Between, Cmp, Const, In, Not, Or, StrPrefix, normalize)
from repro.core.logical import (  # noqa: F401
    Column, Dataspace, Hyperslab, LogicalDataset, RowRange)
from repro.core.partition import (  # noqa: F401
    ArrayObjectMap, ObjectMap, PartitionPolicy, load_objmap,
    plan_array_partition, plan_partition)
from repro.core.placement import ClusterMap  # noqa: F401
from repro.core.store import (  # noqa: F401
    CorruptObject, DataLossError, ObjectStore, PartialWriteError,
    RetryPolicy, TokenBucket, TransientOSDError, make_store)
from repro.core.faults import FaultInjector  # noqa: F401
from repro.core.maintenance import MaintenancePlane  # noqa: F401
from repro.core.cache import ResultCache  # noqa: F401
from repro.core.scan import PhysicalPlan, Scan, ScanEngine  # noqa: F401
from repro.core.session import ScanSession  # noqa: F401
from repro.core.vol import ArrayView, GlobalVOL, LocalVOL  # noqa: F401
from repro.core.skyhook import Query, SkyhookDriver  # noqa: F401
