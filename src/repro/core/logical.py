"""Logical datasets — the access-library-facing data model (paper §2 Fig 1).

This is the "application facing" half of an access library: named, typed,
table/array datasets addressed by a row coordinate system, independent of
any storage-system assumption.  The unit of storage mapping is the
*logical unit* (HDF5 chunk / ROOT basket / Parquet row group): a
contiguous slab of rows.  ``core.partition`` maps logical units to
objects; nothing in this module knows about objects or OSDs — that is the
point of the paper's split.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Column:
    """A named, typed column.  ``shape`` is the per-row trailing shape —
    e.g. a token-sequence table has Column("tokens", "int32", (4096,))."""

    name: str
    dtype: str
    shape: tuple[int, ...] = ()

    @property
    def row_nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * int(np.prod(self.shape, dtype=np.int64)) if self.shape else np.dtype(self.dtype).itemsize)

    def to_json(self) -> dict:
        return {"name": self.name, "dtype": self.dtype,
                "shape": list(self.shape)}

    @staticmethod
    def from_json(d: dict) -> "Column":
        return Column(d["name"], d["dtype"], tuple(d["shape"]))


@dataclasses.dataclass(frozen=True)
class RowRange:
    """Half-open row interval [start, stop)."""

    start: int
    stop: int

    def __post_init__(self):
        if self.start < 0 or self.stop < self.start:
            raise ValueError(f"bad RowRange [{self.start}, {self.stop})")

    def __len__(self) -> int:
        return self.stop - self.start

    def intersect(self, other: "RowRange") -> "RowRange | None":
        s, e = max(self.start, other.start), min(self.stop, other.stop)
        return RowRange(s, e) if s < e else None

    def shift(self, delta: int) -> "RowRange":
        return RowRange(self.start + delta, self.stop + delta)


@dataclasses.dataclass(frozen=True)
class LogicalDataset:
    """A table of ``n_rows`` rows split into logical units of
    ``unit_rows`` rows (last unit may be short)."""

    name: str
    columns: tuple[Column, ...]
    n_rows: int
    unit_rows: int

    def __post_init__(self):
        if self.unit_rows <= 0:
            raise ValueError("unit_rows must be positive")
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names: {names}")

    # ------------------------------------------------------------ columns
    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"{self.name}: no column {name!r}")

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def row_nbytes(self) -> int:
        return sum(c.row_nbytes for c in self.columns)

    # ------------------------------------------------------------ units
    @property
    def n_units(self) -> int:
        return max(1, -(-self.n_rows // self.unit_rows))

    def unit_range(self, unit_id: int) -> RowRange:
        if not 0 <= unit_id < self.n_units:
            raise IndexError(unit_id)
        start = unit_id * self.unit_rows
        return RowRange(start, min(start + self.unit_rows, self.n_rows))

    def unit_nbytes(self, unit_id: int) -> int:
        return len(self.unit_range(unit_id)) * self.row_nbytes

    def units_overlapping(self, rows: RowRange) -> range:
        """Unit ids whose ranges intersect ``rows``."""
        rows = RowRange(max(rows.start, 0), min(rows.stop, self.n_rows))
        if len(rows) == 0:
            return range(0)
        return range(rows.start // self.unit_rows,
                     (rows.stop - 1) // self.unit_rows + 1)

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"name": self.name,
                "columns": [c.to_json() for c in self.columns],
                "n_rows": self.n_rows, "unit_rows": self.unit_rows}

    @staticmethod
    def from_json(d: dict) -> "LogicalDataset":
        return LogicalDataset(
            d["name"], tuple(Column.from_json(c) for c in d["columns"]),
            d["n_rows"], d["unit_rows"])


# --------------------------------------------------------------------------
# N-dimensional dataspaces (paper §2: "coordinate systems and associated
# slicing operations" — the HDF5/ROOT abstraction the token table lacks)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Dataspace:
    """An N-d array dataset: ``shape`` cells of ``dtype`` split into a
    regular grid of ``chunk``-shaped chunks (HDF5 chunked layout).  The
    chunk is the logical unit of storage mapping — ``core.partition``
    groups consecutive chunk ids (row-major over the grid) into objects
    the way it groups row units for tables.  Edge chunks are logically
    clipped to ``shape``; physically every stored chunk is padded to
    the full chunk shape so all chunks of an object stack into one
    ``(k, *chunk)`` block (selections never reach the pad — they are
    clipped against ``shape``)."""

    name: str
    shape: tuple[int, ...]
    dtype: str
    chunk: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(d) for d in self.shape))
        object.__setattr__(self, "chunk", tuple(int(c) for c in self.chunk))
        if not self.shape:
            raise ValueError("Dataspace needs at least one axis")
        if len(self.chunk) != len(self.shape):
            raise ValueError(f"chunk rank {len(self.chunk)} != "
                             f"shape rank {len(self.shape)}")
        if any(d <= 0 for d in self.shape):
            raise ValueError(f"non-positive dims in shape {self.shape}")
        if any(c <= 0 for c in self.chunk):
            raise ValueError(f"non-positive dims in chunk {self.chunk}")

    # ------------------------------------------------------------ grid
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def grid(self) -> tuple[int, ...]:
        """Chunks per axis (edge chunks clipped)."""
        return tuple(-(-d // c) for d, c in zip(self.shape, self.chunk))

    @property
    def n_chunks(self) -> int:
        return int(np.prod(self.grid, dtype=np.int64))

    @property
    def chunk_nbytes(self) -> int:
        """Stored (padded) bytes of one chunk."""
        return int(np.dtype(self.dtype).itemsize
                   * np.prod(self.chunk, dtype=np.int64))

    def chunk_coords(self, cid: int) -> tuple[int, ...]:
        """Row-major chunk id -> grid coordinates."""
        if not 0 <= cid < self.n_chunks:
            raise IndexError(cid)
        out = []
        for g in reversed(self.grid):
            out.append(cid % g)
            cid //= g
        return tuple(reversed(out))

    def chunk_id(self, coords) -> int:
        cid = 0
        for x, g in zip(coords, self.grid):
            if not 0 <= x < g:
                raise IndexError(tuple(coords))
            cid = cid * g + int(x)
        return cid

    def chunk_slab(self, cid: int) -> tuple[tuple[int, int], ...]:
        """The half-open cell slab of one chunk, clipped to ``shape``."""
        return tuple(
            (x * c, min((x + 1) * c, d))
            for x, c, d in zip(self.chunk_coords(cid), self.chunk,
                               self.shape))

    def chunk_ids_overlapping(self, hs: "Hyperslab") -> list[int]:
        """Sorted chunk ids holding at least one selected cell.  Exact
        per axis (a stride longer than the chunk skips whole chunks),
        so object targeting and OSD-side resolution agree."""
        per_axis: list[list[int]] = []
        for s, e, t, c, g in zip(hs.starts, hs.stops, hs.steps,
                                 self.chunk, self.grid):
            ks = []
            for k in range(min(s // c, g - 1) if e > s else 0, g):
                c0, c1 = k * c, (k + 1) * c
                if c0 >= e:
                    break
                if _axis_intersect(s, e, t, c0, c1) is not None:
                    ks.append(k)
            per_axis.append(ks)
        if any(not ks for ks in per_axis):
            return []
        out: list[int] = []

        def walk(axis: int, prefix: list[int]) -> None:
            if axis == self.ndim:
                out.append(self.chunk_id(prefix))
                return
            for k in per_axis[axis]:
                walk(axis + 1, prefix + [k])

        walk(0, [])
        out.sort()
        return out

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"name": self.name, "shape": list(self.shape),
                "dtype": self.dtype, "chunk": list(self.chunk)}

    @staticmethod
    def from_json(d: dict) -> "Dataspace":
        return Dataspace(d["name"], tuple(d["shape"]), d["dtype"],
                         tuple(d["chunk"]))


def _axis_intersect(s: int, e: int, t: int, c0: int,
                    c1: int) -> tuple[int, int, int] | None:
    """One axis of a hyperslab∩chunk intersection: the selected indices
    ``{s + k*t} ∩ [c0, c1)`` as ``(first, stop, n)`` in GLOBAL cell
    coordinates, or None when empty.  ``(first - s) // t`` is the output
    offset — strided selections land dense in output space."""
    lo, hi = max(s, c0), min(e, c1)
    if lo >= hi:
        return None
    first = s + -(-(lo - s) // t) * t
    if first >= hi:
        return None
    n = -(-(hi - first) // t)
    return first, hi, n


@dataclasses.dataclass(frozen=True)
class Hyperslab:
    """An h5py-style N-d selection: ``start/stop/step`` per axis
    (``dset[10:200:2, :, 3]``), already normalized against a shape —
    every axis has explicit non-negative bounds and a positive step.
    ``squeeze`` lists the axes selected by a scalar index (dropped from
    the client-side result, exactly like numpy basic indexing); the
    wire form carries only the per-axis bounds, squeezing is client
    assembly."""

    starts: tuple[int, ...]
    stops: tuple[int, ...]
    steps: tuple[int, ...]
    squeeze: tuple[int, ...] = ()

    def __post_init__(self):
        for tup in (self.starts, self.stops, self.steps):
            if len(tup) != len(self.starts):
                raise ValueError("axis count mismatch")
        if any(t <= 0 for t in self.steps):
            raise ValueError(f"steps must be positive: {self.steps}")
        if any(s < 0 or e < s for s, e in zip(self.starts, self.stops)):
            raise ValueError("bad selection bounds")

    @staticmethod
    def from_key(shape: Sequence[int], key) -> "Hyperslab":
        """Build a normalized selection from a numpy basic-indexing key:
        slices (with negatives / omitted bounds), scalar ints (squeeze
        axes), ``...`` filling to rank.  Negative steps are rejected —
        a storage-side selection serves monotone coordinates."""
        if not isinstance(key, tuple):
            key = (key,)
        if sum(1 for k in key if k is Ellipsis) > 1:
            raise IndexError("an index can only have one ellipsis")
        if Ellipsis in key:
            i = key.index(Ellipsis)
            fill = len(shape) - (len(key) - 1)
            key = key[:i] + (slice(None),) * fill + key[i + 1:]
        if len(key) > len(shape):
            raise IndexError(f"too many indices ({len(key)}) for shape "
                             f"{tuple(shape)}")
        key = key + (slice(None),) * (len(shape) - len(key))
        starts, stops, steps, squeeze = [], [], [], []
        for ax, (k, d) in enumerate(zip(key, shape)):
            if isinstance(k, slice):
                if k.step is not None and k.step < 0:
                    raise ValueError("negative steps are not supported "
                                     "in hyperslab selections")
                s, e, t = k.indices(d)
            else:
                i = int(k)
                if i < 0:
                    i += d
                if not 0 <= i < d:
                    raise IndexError(f"index {k} out of range for axis "
                                     f"{ax} with size {d}")
                s, e, t = i, i + 1, 1
                squeeze.append(ax)
            starts.append(s)
            stops.append(max(s, e))
            steps.append(t)
        return Hyperslab(tuple(starts), tuple(stops), tuple(steps),
                         tuple(squeeze))

    @property
    def ndim(self) -> int:
        return len(self.starts)

    def out_shape(self) -> tuple[int, ...]:
        """Dense output shape BEFORE squeeze (selected count per axis)."""
        return tuple(max(0, -(-(e - s) // t))
                     for s, e, t in zip(self.starts, self.stops,
                                        self.steps))

    def n_cells(self) -> int:
        return int(np.prod(self.out_shape(), dtype=np.int64))

    def intersect_slab(
            self, slab: Sequence[tuple[int, int]]
    ) -> tuple[tuple, tuple, tuple] | None:
        """Intersect this selection with a cell slab (a chunk): returns
        ``(locals, offs, counts)`` — per-axis ``(start, stop, step)``
        slices LOCAL to the slab origin, the per-axis offsets of the
        piece in dense output coordinates, and its per-axis counts —
        or None when no cell of the slab is selected.  The piece is
        always a dense block in output space: output index
        ``(i - start) // step`` maps the strided selection to
        consecutive cells."""
        locals_, offs, counts = [], [], []
        for (s, e, t), (c0, c1) in zip(
                zip(self.starts, self.stops, self.steps), slab):
            hit = _axis_intersect(s, e, t, c0, c1)
            if hit is None:
                return None
            first, stop, n = hit
            locals_.append((first - c0, stop - c0, t))
            offs.append((first - s) // t)
            counts.append(n)
        return tuple(locals_), tuple(offs), tuple(counts)

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"starts": list(self.starts), "stops": list(self.stops),
                "steps": list(self.steps),
                "squeeze": list(self.squeeze)}

    @staticmethod
    def from_json(d: dict) -> "Hyperslab":
        return Hyperslab(tuple(d["starts"]), tuple(d["stops"]),
                         tuple(d["steps"]),
                         tuple(d.get("squeeze", ())))


def validate_table(ds: LogicalDataset,
                   table: Mapping[str, np.ndarray],
                   rows: RowRange | None = None) -> None:
    """Check a concrete column dict against the dataset schema."""
    n = len(rows) if rows is not None else ds.n_rows
    for c in ds.columns:
        if c.name not in table:
            raise KeyError(f"missing column {c.name!r}")
        a = table[c.name]
        want = (n, *c.shape)
        if tuple(a.shape) != want:
            raise ValueError(f"{c.name}: shape {a.shape} != {want}")
        if a.dtype != np.dtype(c.dtype):
            raise TypeError(f"{c.name}: dtype {a.dtype} != {c.dtype}")


def concat_tables(parts: Sequence[Mapping[str, np.ndarray]]) -> dict:
    if not parts:
        return {}
    keys = parts[0].keys()
    return {k: np.concatenate([np.asarray(p[k]) for p in parts], axis=0)
            for k in keys}
