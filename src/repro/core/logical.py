"""Logical datasets — the access-library-facing data model (paper §2 Fig 1).

This is the "application facing" half of an access library: named, typed,
table/array datasets addressed by a row coordinate system, independent of
any storage-system assumption.  The unit of storage mapping is the
*logical unit* (HDF5 chunk / ROOT basket / Parquet row group): a
contiguous slab of rows.  ``core.partition`` maps logical units to
objects; nothing in this module knows about objects or OSDs — that is the
point of the paper's split.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Column:
    """A named, typed column.  ``shape`` is the per-row trailing shape —
    e.g. a token-sequence table has Column("tokens", "int32", (4096,))."""

    name: str
    dtype: str
    shape: tuple[int, ...] = ()

    @property
    def row_nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * int(np.prod(self.shape, dtype=np.int64)) if self.shape else np.dtype(self.dtype).itemsize)

    def to_json(self) -> dict:
        return {"name": self.name, "dtype": self.dtype,
                "shape": list(self.shape)}

    @staticmethod
    def from_json(d: dict) -> "Column":
        return Column(d["name"], d["dtype"], tuple(d["shape"]))


@dataclasses.dataclass(frozen=True)
class RowRange:
    """Half-open row interval [start, stop)."""

    start: int
    stop: int

    def __post_init__(self):
        if self.start < 0 or self.stop < self.start:
            raise ValueError(f"bad RowRange [{self.start}, {self.stop})")

    def __len__(self) -> int:
        return self.stop - self.start

    def intersect(self, other: "RowRange") -> "RowRange | None":
        s, e = max(self.start, other.start), min(self.stop, other.stop)
        return RowRange(s, e) if s < e else None

    def shift(self, delta: int) -> "RowRange":
        return RowRange(self.start + delta, self.stop + delta)


@dataclasses.dataclass(frozen=True)
class LogicalDataset:
    """A table of ``n_rows`` rows split into logical units of
    ``unit_rows`` rows (last unit may be short)."""

    name: str
    columns: tuple[Column, ...]
    n_rows: int
    unit_rows: int

    def __post_init__(self):
        if self.unit_rows <= 0:
            raise ValueError("unit_rows must be positive")
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names: {names}")

    # ------------------------------------------------------------ columns
    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"{self.name}: no column {name!r}")

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def row_nbytes(self) -> int:
        return sum(c.row_nbytes for c in self.columns)

    # ------------------------------------------------------------ units
    @property
    def n_units(self) -> int:
        return max(1, -(-self.n_rows // self.unit_rows))

    def unit_range(self, unit_id: int) -> RowRange:
        if not 0 <= unit_id < self.n_units:
            raise IndexError(unit_id)
        start = unit_id * self.unit_rows
        return RowRange(start, min(start + self.unit_rows, self.n_rows))

    def unit_nbytes(self, unit_id: int) -> int:
        return len(self.unit_range(unit_id)) * self.row_nbytes

    def units_overlapping(self, rows: RowRange) -> range:
        """Unit ids whose ranges intersect ``rows``."""
        rows = RowRange(max(rows.start, 0), min(rows.stop, self.n_rows))
        if len(rows) == 0:
            return range(0)
        return range(rows.start // self.unit_rows,
                     (rows.stop - 1) // self.unit_rows + 1)

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"name": self.name,
                "columns": [c.to_json() for c in self.columns],
                "n_rows": self.n_rows, "unit_rows": self.unit_rows}

    @staticmethod
    def from_json(d: dict) -> "LogicalDataset":
        return LogicalDataset(
            d["name"], tuple(Column.from_json(c) for c in d["columns"]),
            d["n_rows"], d["unit_rows"])


def validate_table(ds: LogicalDataset,
                   table: Mapping[str, np.ndarray],
                   rows: RowRange | None = None) -> None:
    """Check a concrete column dict against the dataset schema."""
    n = len(rows) if rows is not None else ds.n_rows
    for c in ds.columns:
        if c.name not in table:
            raise KeyError(f"missing column {c.name!r}")
        a = table[c.name]
        want = (n, *c.shape)
        if tuple(a.shape) != want:
            raise ValueError(f"{c.name}: shape {a.shape} != {want}")
        if a.dtype != np.dtype(c.dtype):
            raise TypeError(f"{c.name}: dtype {a.dtype} != {c.dtype}")


def concat_tables(parts: Sequence[Mapping[str, np.ndarray]]) -> dict:
    if not parts:
        return {}
    keys = parts[0].keys()
    return {k: np.concatenate([np.asarray(p[k]) for p in parts], axis=0)
            for k in keys}
