"""Device data-plane pushdown — the paper's offload idea, TPU-native.

On a TPU pod there is no storage-server CPU to push object-class code
into; the analogue of "the server that holds the object" is *the device
that holds the shard*.  "Offload to storage" therefore becomes "compute
where the shard lives, move only results": these helpers run objclass-
style operators inside ``shard_map`` regions over the data axes, so the
only bytes entering collectives are the (tiny) partials — the paper's
O(data) -> O(result) traffic reduction, visible directly in the
collective-bytes roofline term of the compiled HLO.

``unpack_bitpacked`` is the storage-side *decompress* offload: objects
hold planar-bitpacked tokens (core.format codec, kernels/codec Pallas
twin); the unpack runs shard-locally inside the compiled train step, so
the host->device and HBM input path carries b/32 of the raw bytes.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

_PRED = {
    "<": jax.lax.lt, "<=": jax.lax.le, ">": jax.lax.gt,
    ">=": jax.lax.ge, "==": jax.lax.eq, "!=": jax.lax.ne,
}


# --------------------------------------------------------------------------
# codec offload: planar bitunpack (pure-jnp; kernels/codec has the Pallas
# version — this one is the GSPMD-partitionable reference the steps use)
# --------------------------------------------------------------------------


def unpack_bitpacked(words: jax.Array, bits: int) -> jax.Array:
    """(..., G, bits) uint32 planar words -> (..., G*32) int32 values.

    Elementwise + tiny reduction: GSPMD partitions it over any batch
    sharding with zero collectives, so the decompress truly runs where
    the shard lives.
    """
    if words.shape[-1] != bits:
        raise ValueError(f"last dim {words.shape[-1]} != bits {bits}")
    lane = jnp.arange(32, dtype=jnp.uint32)
    # (..., G, bits, 32): bit k of each of the 32 lane values
    sliced = (words[..., None] >> lane) & jnp.uint32(1)
    weights = (jnp.uint32(1) << jnp.arange(bits, dtype=jnp.uint32))
    vals = jnp.sum(sliced * weights[:, None], axis=-2, dtype=jnp.uint32)
    return vals.reshape(*words.shape[:-2], -1).astype(jnp.int32)


def packed_shape(n_values: int, bits: int) -> tuple[int, int]:
    """Shape of the packed representation of n_values values."""
    return (-(-n_values // 32), bits)


# --------------------------------------------------------------------------
# shard-local filter/aggregate (objclass ops as shard_map regions)
# --------------------------------------------------------------------------


def _partial_filter_agg(values, filter_col, cmp: str, threshold,
                        dp_axes) -> dict:
    """Per-shard objclass pipeline: filter(col cmp thr) -> agg partials.
    Output is O(1) — only these scalars cross the ICI."""
    mask = _PRED[cmp](filter_col, threshold)
    vf = values.astype(jnp.float32)
    big = jnp.float32(3.4e38)
    sel = jnp.where(mask, vf, 0.0)
    partial = {
        "sum": jnp.sum(sel),
        "count": jnp.sum(mask.astype(jnp.float32)),
        "min": jnp.min(jnp.where(mask, vf, big)),
        "max": jnp.max(jnp.where(mask, vf, -big)),
    }
    if dp_axes:
        partial = {
            "sum": jax.lax.psum(partial["sum"], dp_axes),
            "count": jax.lax.psum(partial["count"], dp_axes),
            "min": jax.lax.pmin(partial["min"], dp_axes),
            "max": jax.lax.pmax(partial["max"], dp_axes),
        }
    return partial


def pushdown_filter_aggregate(values: jax.Array, filter_col: jax.Array,
                              cmp: str, threshold) -> dict:
    """Distributed filter+aggregate with O(result) collective bytes.

    ``values``/``filter_col``: (N,) arrays sharded over the data axes.
    Without an active mesh this runs unsharded (smoke tests).
    """
    rules = shd.active_rules()
    if rules is None:
        return _partial_filter_agg(values, filter_col, cmp, threshold, None)
    dp = rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0]
    fn = functools.partial(_partial_filter_agg, cmp=cmp,
                           threshold=threshold, dp_axes=rules.dp_axes)
    return shard_map(
        lambda v, f: fn(v, f),
        mesh=rules.mesh,
        in_specs=(P(dp), P(dp)),
        out_specs={k: P() for k in ("sum", "count", "min", "max")},
        check_rep=False,
    )(values, filter_col)


# --------------------------------------------------------------------------
# generic compute-at-shard combinator
# --------------------------------------------------------------------------


def shard_local(fn: Callable, *, out_specs, in_axes: str = "dp"):
    """Wrap ``fn(shard_inputs...) -> partials`` to run where the data
    shards live.  ``fn`` receives per-shard blocks and must emit already-
    combined outputs (use ``jax.lax.psum`` etc. with axis name(s) given by
    ``repro.distributed.sharding.active_rules().dp_axes``).

    The deliberate contract mirrors the paper's objclass API: the local
    function sees only its object's bytes; anything global must go
    through an explicit (accounted) collective.
    """
    rules = shd.active_rules()
    if rules is None:
        return fn
    dp = rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0]
    spec = P(dp) if in_axes == "dp" else P(*in_axes)
    return shard_map(fn, mesh=rules.mesh,
                     in_specs=spec, out_specs=out_specs, check_rep=False)
