"""Online maintenance plane: the background services a long-lived
cluster needs to stay healthy WHILE the serve plane keeps answering —
the other half of ROADMAP item 2, and the paper's claim that mapping
datasets onto an extensible object store lets access libraries lean on
the store's own "load balancing, elasticity, and failure management"
instead of reimplementing them per format.

:class:`MaintenancePlane` owns four long-lived daemon workers over one
:class:`~repro.core.store.ObjectStore`:

* **continuous scrub walker** — incrementally walks every OSD's
  inventory in small batches (``batch_objects`` per step), reusing the
  store's per-object classify/quarantine/heal step
  (``ObjectStore._scrub_object`` — the same logic as on-demand
  ``scrub()``) under a ``scrub_rate_bytes_s`` token bucket, so
  foreground ``queue_wait_s`` stays bounded.  The walk keys on a NAME
  cursor, not indices, so it survives ``fail_osd``/``add_osds`` churn
  mid-round: the inventory and acting sets are re-resolved every step.
* **small-object compactor** — folds runs of under-target neighbors
  (the one-blob-per-append ``ckpt``/kvcache pattern) into target-sized
  objects via the OSD-side ``compact_merge`` objclass op, then rewrites
  the dataset's ``.objmap`` with a version bump so compiled plans
  re-target through the existing ``_refresh`` path.  The replaced
  members are NOT deleted — they enter the versioned-GC ledger and stay
  servable until the retention window closes, so in-flight scans stay
  bit-exact.
* **live rebalancer** — after ``fail_osd``/``add_osds`` bumps the
  epoch, walks objects toward their CURRENT placement in digest-
  verified, rate-limited steps (``ObjectStore.rebalance_object``: the
  old copy is retained until every acting copy verifies), relying on
  OSD-resolved extents so compiled plans survive the move.
* **versioned GC** — reclaims dead versions (compaction leftovers) and
  quarantined copies once they have aged past the operator-confirmed
  ``gc_retention_s`` window.  It re-checks that a dead name is not
  referenced by the dataset's CURRENT map before collecting, and never
  purges a quarantined copy unless a digest-verified copy of that
  object survives elsewhere — the sole remaining copy, however
  suspect, is evidence, not garbage.

Counter ownership: each maintenance ``Fabric`` counter has ONE writer —
the daemon that owns that work (the walker owns ``scrub_bytes``/
``corruptions_detected``/``heals``, the compactor ``compactions``/
``compaction_bytes``, the rebalancer ``rebalance_bytes``, GC
``gc_objects``/``gc_bytes``) — preserving the store's accounting-thread
contract without cross-thread ``+=`` races.
"""

from __future__ import annotations

import threading
import time

from repro.core.partition import (
    ArrayObjectMap, PartitionPolicy, compact_plan, load_objmap,
    merge_run, objmap_key)
from repro.core.store import DataLossError, ObjectStore, TokenBucket

_DAEMONS = ("scrub", "compact", "rebalance", "gc")

_OBJMAP_SUFFIX = "/.objmap"


class MaintenancePlane:
    """Background maintenance daemons for one store.  Construct, then
    ``start()`` — or drive the ``*_step`` methods synchronously (tests,
    operator one-shots).  ``pause()``/``resume()`` gate all daemons
    without losing cursors; ``stop()`` joins them.  Attaches itself as
    ``store.maintenance`` so topology changes wake the rebalancer and
    ``store.close()`` tears the plane down."""

    # lock-discipline contract (see ``repro.analysis``): the ledger and
    # the walk cursors are shared between the daemons and client
    # threads (``note_topology_change`` fires from ``fail_osd``/
    # ``add_osds``), so every access goes through ``_lock``
    _GUARDED_BY = {"_dead": "_lock", "_quar_seen": "_lock",
                   "_scrub_cursor": "_lock", "_rebal_cursor": "_lock",
                   "_compact_idx": "_lock"}

    def __init__(self, store: ObjectStore, *,
                 scrub_rate_bytes_s: float | None = None,
                 rebalance_rate_bytes_s: float | None = None,
                 compact_rate_bytes_s: float | None = None,
                 compact_policy: PartitionPolicy | None = None,
                 compact_datasets: list[str] | None = None,
                 gc_retention_s: float = 60.0,
                 gc_confirmed: bool = False,
                 batch_objects: int = 8,
                 interval_s: float = 0.001):
        self.store = store
        self.scrub_limiter = TokenBucket(scrub_rate_bytes_s)
        self.rebalance_limiter = TokenBucket(rebalance_rate_bytes_s)
        self.compact_limiter = TokenBucket(compact_rate_bytes_s)
        self.compact_policy = compact_policy or PartitionPolicy()
        self.compact_datasets = list(compact_datasets) \
            if compact_datasets is not None else None
        self.gc_retention_s = float(gc_retention_s)
        self.gc_confirmed = bool(gc_confirmed)
        self.batch_objects = max(1, int(batch_objects))
        self.interval_s = float(interval_s)

        # versioned-GC ledger: retired object name -> monotonic retire
        # time.  Entries are added by the compactor (replaced members,
        # aborted merge outputs) and collected by GC after retention.
        self._dead: dict[str, float] = {}
        # quarantined-copy ages: (name, osd_id) -> first-seen time
        self._quar_seen: dict[tuple[str, str], float] = {}
        self._lock = threading.Lock()

        # walk cursors (object NAMES — survive inventory churn)
        self._scrub_cursor = ""
        self._rebal_cursor = ""
        self._compact_idx = 0

        # observability (plane-local; Fabric holds the byte counters)
        self.scrub_objects = 0
        self.scrub_corrupt = 0
        self.scrub_healed = 0
        self.scrub_rounds = 0
        self.rebalance_rounds = 0
        self.compact_runs = 0
        self.gc_reclaimed = 0
        self.topology_changes = 0
        self.errors: list[tuple[str, str]] = []

        self._pause = threading.Event()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        store.maintenance = self

    # ------------------------------------------------------------ lifecycle
    def start(self, daemons: tuple[str, ...] = _DAEMONS
              ) -> "MaintenancePlane":
        """Spawn the requested daemons (all four by default).  Each
        loops its step at ``interval_s`` cadence while not paused."""
        if self._threads:
            raise RuntimeError("maintenance plane already started")
        self._stop.clear()
        steps = {"scrub": self.scrub_step, "compact": self.compact_step,
                 "rebalance": self.rebalance_step, "gc": self.gc_step}
        for d in daemons:
            if d not in steps:
                raise ValueError(f"unknown daemon {d!r}; "
                                 f"known: {_DAEMONS}")
            t = threading.Thread(target=self._loop, args=(d, steps[d]),
                                 name=f"maint-{d}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def _loop(self, name: str, step) -> None:
        while not self._stop.is_set():
            if self._pause.is_set():
                self._stop.wait(self.interval_s)
                continue
            try:
                step()
            except Exception as e:  # a sick step must not kill the
                with self._lock:    # daemon; record and keep walking
                    self.errors.append((name, repr(e)))
            self._stop.wait(self.interval_s)

    def pause(self) -> None:
        """Suspend all daemons after their current step.  Cursors and
        the GC ledger are kept — ``resume()`` continues mid-round, so a
        pause spanning ``fail_osd``/``add_osds`` churn costs nothing
        but time."""
        self._pause.set()

    def resume(self) -> None:
        self._pause.clear()

    @property
    def paused(self) -> bool:
        return self._pause.is_set()

    def stop(self) -> None:
        """Stop and join every daemon (idempotent)."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=10.0)
        self._threads = []
        if self.store.maintenance is self:
            self.store.maintenance = None

    def note_topology_change(self) -> None:
        """Called by ``fail_osd``/``add_osds``: restart the rebalance
        walk from the top of the (new) inventory so every object gets
        re-examined against the fresh placement."""
        with self._lock:
            self._rebal_cursor = ""
            self.topology_changes += 1

    def confirm_gc(self) -> None:
        """Operator confirmation: versioned GC may reclaim entries that
        have aged past ``gc_retention_s``.  Without it ``gc_step`` only
        ages the ledger and never deletes."""
        self.gc_confirmed = True

    # ------------------------------------------------------------ inventory
    def _inventory(self) -> list[str]:
        """Current scrub-walk universe: every live object plus every
        quarantined name, minus the dead ledger (retired versions are
        read-only history awaiting GC — healing or re-replicating them
        would resurrect garbage)."""
        store = self.store
        names = set(store.list_objects()) | store._quarantined_names()
        with self._lock:
            names -= set(self._dead)
        return sorted(names)

    def _next_batch(self, names: list[str], cursor: str,
                    n: int) -> tuple[list[str], str, bool]:
        """The next ``n`` names after ``cursor`` — ``(batch, new_cursor,
        wrapped)``.  An exhausted cursor resets to the top and reports
        the wrap (one completed round)."""
        batch = [m for m in names if m > cursor][:n]
        if not batch:
            return [], "", bool(names)
        return batch, batch[-1], False

    # ------------------------------------------------------------ scrub
    def scrub_step(self) -> dict:
        """One walker increment: classify/quarantine/heal the next
        ``batch_objects`` names, paying verified bytes into the scrub
        rate limiter so a full-inventory round trickles instead of
        bursting."""
        names = self._inventory()
        with self._lock:
            cursor = self._scrub_cursor
        batch, cursor, wrapped = self._next_batch(
            names, cursor, self.batch_objects)
        with self._lock:
            self._scrub_cursor = cursor
        if wrapped:
            self.scrub_rounds += 1
        out = {"objects": 0, "corrupt": 0, "healed": 0}
        for name in batch:
            res = self.store._scrub_object(name, heal=True)
            self.scrub_limiter.consume(res["bytes"])
            out["objects"] += 1
            out["corrupt"] += res["corrupt"]
            out["healed"] += res["healed"]
        self.scrub_objects += out["objects"]
        self.scrub_corrupt += out["corrupt"]
        self.scrub_healed += out["healed"]
        return out

    # ------------------------------------------------------------ compact
    def _discover_datasets(self) -> list[str]:
        if self.compact_datasets is not None:
            return self.compact_datasets
        return [n[:-len(_OBJMAP_SUFFIX)]
                for n in self.store.list_objects()
                if n.endswith(_OBJMAP_SUFFIX)]

    def _objmap_blob(self, ds: str) -> tuple[bytes, int] | None:
        """The dataset's ``.objmap`` from its best local copy — no
        client fabric accounting; maintenance reads are cluster-
        internal."""
        verified, _, bare = self.store._verified_copies(objmap_key(ds))
        if verified:
            v, _, blob, _ = verified[0]
            return blob, int(v)
        if bare:
            _, blob, xattr = bare[0]
            return blob, int(xattr.get("version", -1))
        return None

    def _sizes(self, names: list[str]) -> dict[str, int]:
        """Stored size per object from the first up holder (OSD-local
        inspection).  Missing objects are absent from the result, which
        breaks compaction runs over them (mid-write or gone)."""
        store = self.store
        out: dict[str, int] = {}
        for name in names:
            for osd_id in store.cluster.up_osds:
                osd = store.osds[osd_id]
                with osd.lock:
                    blob = osd.data.get(name)
                if blob is not None:
                    out[name] = len(blob)
                    break
        return out

    def compact_step(self) -> dict | None:
        """One compaction increment: pick the next dataset round-robin,
        fold its FIRST under-target run into a fresh target-sized
        object (OSD-side ``compact_merge``), persist the rewritten map
        with a version bump (compiled plans re-target via ``_refresh``)
        and retire the replaced members into the GC ledger.  Returns
        what it did, or None when nothing needed compacting.

        Atomicity: the map rewrite is last, and only lands if the map's
        version is still the one the run was planned against — a racing
        metadata writer aborts the rewrite and the orphaned merge
        output goes straight to the GC ledger."""
        datasets = self._discover_datasets()
        if not datasets:
            return None
        for _ in range(len(datasets)):
            with self._lock:
                idx = self._compact_idx
                self._compact_idx = idx + 1
            ds = datasets[idx % len(datasets)]
            got = self._objmap_blob(ds)
            if got is None:
                continue
            blob, version = got
            omap = load_objmap(blob)
            if isinstance(omap, ArrayObjectMap):
                continue  # chunk granules are the access unit: skip
            with self._lock:
                dead = set(self._dead)
            live = [e.name for e in omap.extents if e.name not in dead]
            sizes = self._sizes(live)
            runs = compact_plan(omap, sizes, self.compact_policy)
            if not runs:
                continue
            start, stop = runs[0]
            members = [e.name for e in omap.extents[start:stop]]
            rows = (omap.extents[start].row_start,
                    omap.extents[stop - 1].row_stop)
            out_name = f"{ds}/cmp.{self.store._next_version():08d}"
            try:
                _, nbytes = self.store.compact_run(
                    members, out_name, rows=rows)
            except DataLossError:
                continue  # a member died mid-plan; scrub/heal first
            key = objmap_key(ds)
            cur = self._objmap_blob(ds)
            if cur is None or cur[1] != version:
                # the map moved under us: abort, GC the orphaned merge
                with self._lock:
                    self._dead[out_name] = time.monotonic()
                continue
            new_map = merge_run(omap, start, stop, out_name)
            _, moved = self.store._maint_put(key, new_map.to_bytes())
            self.compact_limiter.consume(nbytes + moved)
            now = time.monotonic()
            with self._lock:
                for m in members:
                    self._dead[m] = now
            self.compact_runs += 1
            return {"dataset": ds, "members": members,
                    "out": out_name, "bytes": nbytes}
        return None

    # ------------------------------------------------------------ rebalance
    def rebalance_step(self) -> dict:
        """One rebalance increment: nudge the next ``batch_objects``
        live objects toward their CURRENT acting sets (copy-verify-drop
        inside ``rebalance_object``), rate-limited by moved bytes."""
        names = [n for n in self._inventory() if self.store.exists(n)]
        with self._lock:
            start = self._rebal_cursor
        batch, cursor, wrapped = self._next_batch(
            names, start, self.batch_objects)
        if wrapped:
            self.rebalance_rounds += 1
        moved = 0
        for name in batch:
            nbytes = self.store.rebalance_object(name)
            self.rebalance_limiter.consume(nbytes)
            moved += nbytes
        with self._lock:
            if self._rebal_cursor == start:
                # advance only if no topology change reset the walk
                # mid-step — the reset must win, or churn during a
                # batch would skip the restart it asked for
                self._rebal_cursor = cursor
        return {"objects": len(batch), "bytes": moved}

    # ------------------------------------------------------------ gc
    def _referenced(self, name: str) -> bool:
        """Is ``name`` referenced by any dataset's CURRENT object map?
        The collect-time safety recheck: a retired name that came back
        into a live map (however unlikely) must never be deleted."""
        for ds in self._discover_datasets():
            got = self._objmap_blob(ds)
            if got is None:
                continue
            try:
                omap = load_objmap(got[0])
            except Exception:
                continue
            if name in omap.object_names():
                return True
        return False

    def gc_step(self) -> dict:
        """One GC sweep: reclaim dead-ledger entries and quarantined
        copies older than the retention window — only once the operator
        has confirmed (``confirm_gc``), and never the sole surviving
        copy of anything."""
        store = self.store
        now = time.monotonic()
        out = {"dead_reclaimed": 0, "quarantine_purged": 0, "bytes": 0}
        # age the quarantine ledger (first-seen timestamps)
        current: set[tuple[str, str]] = set()
        for osd_id in store.cluster.up_osds:
            osd = store.osds[osd_id]
            with osd.lock:
                quarantined = list(osd.quarantine)
            for name in quarantined:
                current.add((name, osd_id))
        with self._lock:
            for key in current:
                self._quar_seen.setdefault(key, now)
            for key in list(self._quar_seen):
                if key not in current:
                    del self._quar_seen[key]
        if not self.gc_confirmed:
            return out
        # dead versions past retention
        with self._lock:
            ripe = [n for n, t in self._dead.items()
                    if now - t >= self.gc_retention_s]
        for name in ripe:
            if self._referenced(name):
                with self._lock:
                    self._dead.pop(name, None)
                continue
            size = 0
            for osd_id in store.cluster.up_osds:
                osd = store.osds[osd_id]
                with osd.lock:
                    blob = osd.data.get(name)
                if blob is not None:
                    size += len(blob)
            store.delete(name)
            size += store.purge_quarantined(name)
            with self._lock:
                self._dead.pop(name, None)
            out["dead_reclaimed"] += 1
            out["bytes"] += size
            store.fabric.gc_objects += 1
            store.fabric.gc_bytes += size
        # quarantined copies of LIVE objects past retention — purge a
        # copy only when a digest-verified copy survives elsewhere
        with self._lock:
            quar_ripe = [k for k, t in self._quar_seen.items()
                         if now - t >= self.gc_retention_s]
        purged_names: set[str] = set()
        for name, _osd in quar_ripe:
            if name in purged_names:
                continue
            verified, _, _ = store._verified_copies(name)
            if not verified:
                continue  # sole remaining evidence: keep it
            freed = store.purge_quarantined(name)
            if freed:
                purged_names.add(name)
                out["quarantine_purged"] += 1
                out["bytes"] += freed
                store.fabric.gc_objects += 1
                store.fabric.gc_bytes += freed
        if purged_names:
            with self._lock:
                for key in list(self._quar_seen):
                    if key[0] in purged_names:
                        del self._quar_seen[key]
        self.gc_reclaimed += out["dead_reclaimed"] + \
            out["quarantine_purged"]
        return out

    # ------------------------------------------------------------ one-shots
    def run_once(self) -> dict:
        """One synchronous full pass of all four services (tests and
        operator one-shots): scrub the whole inventory, compact until
        no run remains, rebalance everything, then one GC sweep."""
        scrub = {"objects": 0, "corrupt": 0, "healed": 0}
        with self._lock:
            self._scrub_cursor = ""
        while True:
            got = self.scrub_step()
            if not got["objects"]:
                break
            for k in scrub:
                scrub[k] += got[k]
        compacted = []
        while True:
            got = self.compact_step()
            if got is None:
                break
            compacted.append(got)
        with self._lock:
            self._rebal_cursor = ""
        rebalanced = {"objects": 0, "bytes": 0}
        while True:
            got = self.rebalance_step()
            if not got["objects"]:
                break
            rebalanced["objects"] += got["objects"]
            rebalanced["bytes"] += got["bytes"]
        gc = self.gc_step()
        return {"scrub": scrub, "compacted": compacted,
                "rebalance": rebalanced, "gc": gc}

    # ------------------------------------------------------------ observe
    def stats(self) -> dict:
        with self._lock:
            return {
                "scrub_objects": self.scrub_objects,
                "scrub_corrupt": self.scrub_corrupt,
                "scrub_healed": self.scrub_healed,
                "scrub_rounds": self.scrub_rounds,
                "rebalance_rounds": self.rebalance_rounds,
                "compact_runs": self.compact_runs,
                "gc_reclaimed": self.gc_reclaimed,
                "dead_pending": len(self._dead),
                "topology_changes": self.topology_changes,
                "paused": self.paused,
                "gc_confirmed": self.gc_confirmed,
                "errors": list(self.errors),
            }
