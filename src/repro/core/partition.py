"""Dataset -> object partitioning (paper §3.1 and §5 'future work' item 1).

Maps logical units to objects of *proper sizes*:

  * grouping: contiguous small units are packed into one object until the
    target object size is reached (amortizes per-object metadata);
  * splitting: units larger than ``max_object_bytes`` are split into
    row sub-ranges across several objects (bounded object size);
  * co-location: grouping is contiguous in row order, so rows that are
    accessed together (same logical neighborhood) land in the same object
    — and an optional ``colocate_rows`` quantum forbids groups from
    crossing that boundary (e.g. training-batch stripes);
  * minimum metadata: the resulting ObjectMap stores only the row
    boundaries and object names — O(n_objects), independent of n_rows.

The ObjectMap is itself serializable and is stored in the object store as
``<dataset>/.objmap`` so any client can bootstrap from the store alone.
"""

from __future__ import annotations

import bisect
import dataclasses
import json
from typing import Iterator

from repro.core.logical import Dataspace, Hyperslab, LogicalDataset, RowRange


@dataclasses.dataclass(frozen=True)
class PartitionPolicy:
    target_object_bytes: int = 8 << 20     # Ceph-typical 4-32 MiB sweet spot
    max_object_bytes: int = 64 << 20       # RADOS-style hard cap
    colocate_rows: int = 0                 # group boundary quantum (0 = none)

    def __post_init__(self):
        if self.target_object_bytes <= 0:
            raise ValueError("target_object_bytes must be positive")
        if self.max_object_bytes < self.target_object_bytes:
            raise ValueError("max < target object bytes")


@dataclasses.dataclass(frozen=True)
class ObjectExtent:
    """One object's slice of the dataset: rows [row_start, row_stop)."""

    name: str
    row_start: int
    row_stop: int

    @property
    def rows(self) -> RowRange:
        return RowRange(self.row_start, self.row_stop)

    def __len__(self) -> int:
        return self.row_stop - self.row_start


@dataclasses.dataclass(frozen=True)
class ObjectMap:
    """Row-boundary index: object i covers [starts[i], starts[i+1]).

    ``version`` is the store version of the ``<dataset>/.objmap`` object
    this map was read from (-1 = not yet persisted / unknown).  Compiled
    plans stamp it so execute-time can detect that the map moved under
    them (re-partition) and re-derive their target objects; it is
    provenance, not content — excluded from equality and serialization.
    """

    dataset: LogicalDataset
    extents: tuple[ObjectExtent, ...]
    version: int = dataclasses.field(default=-1, compare=False)

    def __post_init__(self):
        prev = 0
        for e in self.extents:
            if e.row_start != prev:
                raise ValueError(f"gap/overlap at row {prev} ({e})")
            prev = e.row_stop
        if self.extents and prev != self.dataset.n_rows:
            raise ValueError(f"coverage ends at {prev} != "
                             f"{self.dataset.n_rows}")

    # ------------------------------------------------------------ lookup
    @property
    def n_objects(self) -> int:
        return len(self.extents)

    def lookup(self, rows: RowRange) -> list[tuple[ObjectExtent, RowRange]]:
        """Objects intersecting ``rows`` + the intersection *local* to the
        object (row 0 = object's first row)."""
        rows = RowRange(max(0, rows.start),
                        min(rows.stop, self.dataset.n_rows))
        if len(rows) == 0:
            return []
        starts = [e.row_start for e in self.extents]
        i = bisect.bisect_right(starts, rows.start) - 1
        out = []
        while i < len(self.extents) and self.extents[i].row_start < rows.stop:
            e = self.extents[i]
            inter = e.rows.intersect(rows)
            if inter is not None:
                out.append((e, inter.shift(-e.row_start)))
            i += 1
        return out

    def object_names(self) -> list[str]:
        return [e.name for e in self.extents]

    def __iter__(self) -> Iterator[ObjectExtent]:
        return iter(self.extents)

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"dataset": self.dataset.to_json(),
                "extents": [[e.name, e.row_start, e.row_stop]
                            for e in self.extents]}

    @staticmethod
    def from_json(d: dict) -> "ObjectMap":
        return ObjectMap(
            LogicalDataset.from_json(d["dataset"]),
            tuple(ObjectExtent(n, a, b) for n, a, b in d["extents"]))

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json()).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "ObjectMap":
        return ObjectMap.from_json(json.loads(b.decode()))


@dataclasses.dataclass(frozen=True)
class ArrayExtent:
    """One object's slice of a chunked array: chunk ids
    [chunk_start, chunk_stop) in row-major grid order."""

    name: str
    chunk_start: int
    chunk_stop: int

    def __len__(self) -> int:
        return self.chunk_stop - self.chunk_start


@dataclasses.dataclass(frozen=True)
class ArrayObjectMap:
    """Chunk-boundary index for an N-d Dataspace: object i covers chunk
    ids [extents[i].chunk_start, extents[i].chunk_stop).  Same provenance
    contract as ObjectMap: ``version`` is the store version of the
    ``.objmap`` object this was read from, excluded from equality."""

    space: Dataspace
    extents: tuple[ArrayExtent, ...]
    version: int = dataclasses.field(default=-1, compare=False)

    def __post_init__(self):
        prev = 0
        for e in self.extents:
            if e.chunk_start != prev:
                raise ValueError(f"gap/overlap at chunk {prev} ({e})")
            prev = e.chunk_stop
        if self.extents and prev != self.space.n_chunks:
            raise ValueError(f"coverage ends at chunk {prev} != "
                             f"{self.space.n_chunks}")

    @property
    def n_objects(self) -> int:
        return len(self.extents)

    def lookup_chunks(self, cids: list[int]) -> list[tuple[ArrayExtent,
                                                           list[int]]]:
        """Objects holding any of the (sorted) chunk ids, with the ids
        each object holds."""
        out: list[tuple[ArrayExtent, list[int]]] = []
        starts = [e.chunk_start for e in self.extents]
        for cid in cids:
            i = bisect.bisect_right(starts, cid) - 1
            if not 0 <= i < len(self.extents):
                continue
            e = self.extents[i]
            if not e.chunk_start <= cid < e.chunk_stop:
                continue
            if out and out[-1][0] is e:
                out[-1][1].append(cid)
            else:
                out.append((e, [cid]))
        return out

    def lookup(self, hs: Hyperslab) -> list[tuple[ArrayExtent, list[int]]]:
        return self.lookup_chunks(self.space.chunk_ids_overlapping(hs))

    def object_names(self) -> list[str]:
        return [e.name for e in self.extents]

    def __iter__(self) -> Iterator[ArrayExtent]:
        return iter(self.extents)

    # ------------------------------------------------------------ (de)ser
    def to_json(self) -> dict:
        return {"kind": "array", "space": self.space.to_json(),
                "extents": [[e.name, e.chunk_start, e.chunk_stop]
                            for e in self.extents]}

    @staticmethod
    def from_json(d: dict) -> "ArrayObjectMap":
        return ArrayObjectMap(
            Dataspace.from_json(d["space"]),
            tuple(ArrayExtent(n, a, b) for n, a, b in d["extents"]))

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_json()).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "ArrayObjectMap":
        return ArrayObjectMap.from_json(json.loads(b.decode()))


def load_objmap(b: bytes) -> "ObjectMap | ArrayObjectMap":
    """Deserialize a ``.objmap`` blob of either kind.  Table maps have no
    "kind" field (back-compat with every already-stored map)."""
    d = json.loads(b.decode())
    if d.get("kind") == "array":
        return ArrayObjectMap.from_json(d)
    return ObjectMap.from_json(d)


def objmap_key(dataset_name: str) -> str:
    return f"{dataset_name}/.objmap"


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------


def plan_partition(ds: LogicalDataset,
                   policy: PartitionPolicy = PartitionPolicy()) -> ObjectMap:
    """Group/split logical units into object extents under the policy."""
    rb = ds.row_nbytes
    if rb <= 0:
        raise ValueError("zero-byte rows")
    target_rows = max(1, policy.target_object_bytes // rb)
    max_rows = max(1, policy.max_object_bytes // rb)

    extents: list[ObjectExtent] = []

    def emit(start: int, stop: int) -> None:
        extents.append(ObjectExtent(
            f"{ds.name}/obj.{len(extents):06d}", start, stop))

    row = 0
    acc_start = row
    for uid in range(ds.n_units):
        ur = ds.unit_range(uid)
        # unit bigger than max object: flush accumulator, split the unit
        if len(ur) > max_rows:
            if ur.start > acc_start:
                emit(acc_start, ur.start)
            s = ur.start
            while s < ur.stop:
                e = min(s + max_rows, ur.stop)
                emit(s, e)
                s = e
            acc_start = ur.stop
            continue
        # group boundary (co-location quantum): never straddle it
        if policy.colocate_rows:
            q = policy.colocate_rows
            if (ur.stop - 1) // q != acc_start // q and ur.start > acc_start:
                emit(acc_start, ur.start)
                acc_start = ur.start
        # grouping: flush when adding this unit would exceed target
        if (ur.stop - acc_start) * rb > policy.target_object_bytes \
                and ur.start > acc_start:
            emit(acc_start, ur.start)
            acc_start = ur.start
    if acc_start < ds.n_rows:
        emit(acc_start, ds.n_rows)
    if not extents and ds.n_rows == 0:
        emit(0, 0)
    return ObjectMap(ds, tuple(extents))


def compact_plan(omap: ObjectMap, sizes: dict[str, int],
                 policy: PartitionPolicy = PartitionPolicy()
                 ) -> list[tuple[int, int]]:
    """Runs of consecutive under-target extents worth folding into one
    object — ``[(start, stop), ...]`` extent-index ranges, each >= 2
    members.  ``sizes`` maps object name -> stored bytes; an absent or
    zero size breaks the run (the object is mid-write or gone — never
    compact it).  Greedy left-to-right: a run accumulates small
    neighbors until it reaches ``target_object_bytes`` (good enough —
    stop growing) and never exceeds ``max_object_bytes``.  This is the
    read side of the one-blob-per-append pattern: N tiny ``ckpt``/
    kvcache appends become ceil(total/target) proper objects."""
    runs: list[tuple[int, int]] = []
    n = len(omap.extents)

    def small(k: int) -> bool:
        s = sizes.get(omap.extents[k].name)
        return s is not None and 0 < s < policy.target_object_bytes

    i = 0
    while i < n:
        if not small(i):
            i += 1
            continue
        j, acc = i, 0
        while j < n and small(j):
            s = sizes[omap.extents[j].name]
            if acc and acc + s > policy.max_object_bytes:
                break
            acc += s
            j += 1
            if acc >= policy.target_object_bytes:
                break
        if j - i >= 2:
            runs.append((i, j))
        i = max(j, i + 1)
    return runs


def merge_run(omap: ObjectMap, start: int, stop: int,
              name: str) -> ObjectMap:
    """The map rewrite for one compacted run: extents [start, stop)
    collapse into a single extent ``name`` covering their combined row
    range.  Contiguity is preserved by construction (the run was
    consecutive), so the returned map revalidates; ``version`` carries
    over as provenance until the rewritten map is persisted (which
    stamps the real store version)."""
    if not (0 <= start < stop <= len(omap.extents)) or stop - start < 2:
        raise ValueError(f"bad merge run [{start}, {stop}) over "
                         f"{len(omap.extents)} extents")
    run = omap.extents[start:stop]
    merged = ObjectExtent(name, run[0].row_start, run[-1].row_stop)
    return ObjectMap(
        omap.dataset,
        omap.extents[:start] + (merged,) + omap.extents[stop:],
        version=omap.version)


def plan_array_partition(
        space: Dataspace,
        policy: PartitionPolicy = PartitionPolicy()) -> ArrayObjectMap:
    """Group row-major-consecutive chunks into objects of proper sizes —
    the array twin of ``plan_partition`` with the chunk as the logical
    unit.  A chunk is never split (it is the access/pruning granule), so
    one oversized chunk makes a one-chunk object."""
    cb = space.chunk_nbytes
    per_obj = max(1, min(policy.target_object_bytes // cb,
                         policy.max_object_bytes // cb) or 1)
    extents: list[ArrayExtent] = []
    c = 0
    while c < space.n_chunks:
        stop = min(c + per_obj, space.n_chunks)
        extents.append(ArrayExtent(
            f"{space.name}/obj.{len(extents):06d}", c, stop))
        c = stop
    return ArrayObjectMap(space, tuple(extents))
